"""The paper's four science workloads end to end (Fig. 3/4/6-7/Table 4).

    PYTHONPATH=src python examples/science_kernels.py

Runs each proxy app through the portable registry on both backends and
prints the paper's figure of merit for each, plus Phi-bar (Table 5).
"""

import jax.numpy as jnp
import numpy as np

import repro.kernels.babelstream.ops  # noqa: F401 (registration)
import repro.kernels.stencil7.ops  # noqa: F401
import repro.kernels.minibude.ops as mb_ops
import repro.kernels.hartree_fock.ops as hf_ops
from repro.core import (Efficiency, babelstream_bytes, get_kernel,
                        minibude_ops, phi_bar, stencil7_effective_bytes)
from repro.kernels.hartree_fock import ref as hf_ref


def main() -> None:
    rng = np.random.default_rng(0)
    terms = []

    # --- seven-point stencil (Eq. 1) ---
    u = jnp.asarray(rng.standard_normal((64, 64, 128)), jnp.float32)
    k = get_kernel("stencil7")
    t_x = k.time_backend(u, backend="xla")
    t_p = k.time_backend(u, backend="pallas_interpret", iters=3)
    bw = stencil7_effective_bytes(64, 4) / t_x / 1e9
    print(f"stencil7      xla {t_x*1e3:7.2f}ms ({bw:6.2f} GB/s eff)  "
          f"pallas-interp {t_p*1e3:7.2f}ms")
    terms.append(Efficiency("cpu", "stencil7", 1/t_p, 1/t_x))

    # --- BabelStream (Eq. 2) ---
    n = 1 << 20
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    for op, args in (("triad", (a, b)), ("dot", (a, b))):
        k = get_kernel(f"babelstream.{op}")
        t_x = k.time_backend(*args, backend="xla")
        t_p = k.time_backend(*args, backend="pallas_interpret", iters=3)
        bw = babelstream_bytes(op, n, 4) / t_x / 1e9
        print(f"stream.{op:6s} xla {t_x*1e3:7.2f}ms ({bw:6.2f} GB/s)      "
              f"pallas-interp {t_p*1e3:7.2f}ms")
        terms.append(Efficiency("cpu", op, 1/t_p, 1/t_x))

    # --- miniBUDE (Eq. 3) ---
    deck = mb_ops.make_deck(natpro=128, natlig=8, nposes=1024, seed=0)
    k = get_kernel("minibude.fasten")
    t_x = k.time_backend(*deck, backend="xla")
    t_p = k.time_backend(*deck, backend="pallas_interpret", iters=3)
    gf = minibude_ops(128, 8, 128, 1024) / t_x / 1e9
    print(f"minibude      xla {t_x*1e3:7.2f}ms ({gf:6.2f} GFLOP/s)    "
          f"pallas-interp {t_p*1e3:7.2f}ms")
    terms.append(Efficiency("cpu", "minibude", 1/t_p, 1/t_x))

    # --- Hartree-Fock (Table 4: wall-clock) ---
    pos = hf_ref.helium_lattice(16)
    dens = hf_ref.initial_density(16)
    k = get_kernel("hartree_fock.twoel")
    t_x = k.time_backend(pos, dens, backend="xla", iters=5)
    t_p = k.time_backend(pos, dens, backend="pallas_interpret", iters=2)
    print(f"hartree-fock  xla {t_x*1e3:7.2f}ms                     "
          f"pallas-interp {t_p*1e3:7.2f}ms")
    terms.append(Efficiency("cpu", "hartree_fock", 1/t_p, 1/t_x))

    print(f"\nPhi-bar across workloads on this host (Eq. 4): "
          f"{phi_bar(terms):.3f}")
    print("(interpret-mode wall-clock != TPU perf; see EXPERIMENTS.md "
          "§Roofline for TPU-projected numbers)")


if __name__ == "__main__":
    main()
