"""End-to-end training driver: ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch granite-3-8b
    PYTHONPATH=src python examples/train_lm.py --resume   # restart from ckpt

Demonstrates the full production loop on whatever devices this host has:
sharded params (policy), deterministic seekable data, checkpoint/restart
(preemption-safe), straggler monitoring, heartbeat, grad accumulation.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import (Heartbeat, PreemptionGuard,
                                               StragglerMonitor)
from repro.distributed.sharding import ShardingPolicy
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, make_train_state, train_step


def scale_config(cfg, d_model=512, n_layers=8):
    """~100M-parameter variant of an assigned arch (same family)."""
    heads = max(d_model // 128, 4)
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=d_model, n_heads=heads,
        n_kv_heads=max(heads // 4, 1), d_ff=d_model * 3,
        head_dim=d_model // heads, vocab_size=32768,
        global_layers=tuple(g for g in cfg.global_layers if g < n_layers))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    cfg = scale_config(get_config(args.arch), args.d_model, args.layers)
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, cfg)
    tcfg = TrainConfig(
        microbatches=2, remat=True,
        opt=AdamWConfig(lr_peak=3e-4, warmup_steps=20,
                        decay_steps=args.steps))

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(a.size) for a in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={mesh.size}")

    state = make_train_state(params, tcfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state)
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")
    with mesh:
        state = jax.device_put(state, policy.tree_shardings(state))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    data = SyntheticLM(data_cfg)
    data.seek(start_step)                 # replay-free restart
    pipe = Prefetcher(data, depth=2)

    guard = PreemptionGuard().install()
    hb = Heartbeat("/tmp/repro_heartbeat", interval_s=10.0)
    straggler = StragglerMonitor()
    step_fn = jax.jit(lambda s, b: train_step(s, b, cfg=cfg, tcfg=tcfg,
                                              hints=policy.hints()),
                      donate_argnums=0)

    step = start_step
    with mesh:
        for batch_np in pipe:
            if step >= args.steps or guard.should_stop:
                break
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, batch_np)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            if straggler.observe(step, dt):
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(ema {straggler.ema:.2f}s)")
            hb.beat(step)
            step += 1
            if step % 10 == 0:
                print(f"step {step:4d} loss {float(metrics['loss']):7.4f} "
                      f"acc {float(metrics['accuracy']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:6.1f}ms")
            if step % args.ckpt_every == 0 or guard.should_stop:
                mgr.save(step, jax.device_get(state),
                         metadata={"arch": cfg.name}, blocking=False)
    pipe.close()
    mgr.wait()
    mgr.save(step, jax.device_get(state), metadata={"arch": cfg.name})
    print(f"finished at step {step}; checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
