"""The science kernels multi-device: domain decomposition through the registry.

    PYTHONPATH=src python examples/distributed_kernels.py [--devices 8]

Simulates a multi-device host (the flag must be set before jax initializes,
which is why this script — not the library — does it), then runs each
science family on its single-device oracle and on the sharded backends the
distributed subsystem registered, checking every distributed result against
the oracle:

  * ``xla_shard`` — the oracle arithmetic under shard_map:
      - stencil7     1-D z slabs AND 2-D (sz, sy) pencils + per-axis
                     ppermute halo exchange, each with the double-buffered
                     halo/compute-overlap variant (interior computes while
                     halos are in flight)
      - babelstream  block-partitioned triad (elementwise) + psum dot
      - minibude     pose-parallel energies
      - hartree_fock l-slab quartet contributions accumulated with psum
  * ``shard_pallas`` — the *unchanged Pallas kernels* under shard_map
    (interpret mode off-TPU), the shard grid composing with each family's
    tile tunables (stencil ``by``, stream ``block_rows``, pose/i tiles);
    the stencil/stream/pose results are additionally bitwise identical to
    the single-device Pallas backend — sharding does not change the
    kernel's output.

CPU caveat: the "devices" are threads of one host (and shard_pallas runs
interpret-mode kernels there), so the timings prove the decomposition
machinery, not hardware scaling — see benchmarks/scaling.py for the
weak/strong curves and BENCH_scaling.json (per-backend since v3).
"""

import argparse

from repro.launch.hostsim import ensure_host_device_count

_args = argparse.ArgumentParser()
_args.add_argument("--devices", type=int, default=8)
ARGS = _args.parse_args()
ensure_host_device_count(ARGS.devices)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.kernels  # noqa: E402,F401  (registers xla_shard backends)
from repro.core.portable import get_kernel  # noqa: E402
from repro.kernels.hartree_fock import ref as hf_ref  # noqa: E402
from repro.kernels.minibude import ops as mb_ops  # noqa: E402


def show(name, kernel, args, exact=True, label=None, backend="xla_shard",
         against="xla", **shard_kw):
    t_x = kernel.time_backend(*args, backend="xla", iters=3)
    t_s = kernel.time_backend(*args, backend=backend, iters=3, **shard_kw)
    want = np.asarray(kernel(*args, backend=against))
    got = np.asarray(kernel(*args, backend=backend, **shard_kw))
    if exact:
        assert np.array_equal(want, got), \
            f"{name}: {backend} != {against}"
        match = f"bitwise vs {against}"
    else:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        match = f"~1e-4 vs {against}"
    label = label or ",".join(f"{k}={v}" for k, v in shard_kw.items())
    print(f"{name:18s} xla {t_x * 1e3:8.2f}ms   {backend}[{label}] "
          f"{t_s * 1e3:8.2f}ms   match: {match}")


def main() -> None:
    n = jax.device_count()
    if n < 2:
        raise SystemExit(
            f"need >= 2 devices, got {n}: XLA_FLAGS already pinned a "
            f"1-device topology before this script could append the flag")
    shards = min(4, n)
    print(f"{n} simulated {jax.devices()[0].platform} devices; "
          f"running every family at num_shards={shards}\n")
    rng = np.random.default_rng(0)

    # (ny, nx) = (64, 128): the Pallas lane width and default y-tile, so
    # the same array feeds both sharded backends AND the single-device
    # Pallas baseline at its defaults
    u = jnp.asarray(rng.standard_normal((32, 64, 128)), jnp.float32)
    s7 = get_kernel("stencil7")
    show("stencil7", s7, (u,), label=f"slab {shards}x1",
         num_shards=shards)
    show("stencil7", s7, (u,), label=f"slab {shards}x1 +overlap",
         num_shards=shards, overlap=True)
    if n >= 4:
        show("stencil7", s7, (u,), label="pencil 2x2", decomp="pencil",
             shard_grid=(2, 2))
        show("stencil7", s7, (u,), label="pencil 2x2 +overlap",
             decomp="pencil", shard_grid=(2, 2), overlap=True)

    a = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    b = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    show("babelstream.triad", get_kernel("babelstream.triad"), (a, b),
         num_shards=shards)
    show("babelstream.dot", get_kernel("babelstream.dot"), (a, b),
         exact=False, num_shards=shards)

    deck = mb_ops.make_deck(natpro=32, natlig=4, nposes=256, seed=0)
    show("minibude.fasten", get_kernel("minibude.fasten"), deck,
         num_shards=shards)

    pos, dens = hf_ref.helium_lattice(8), hf_ref.initial_density(8)
    show("hartree_fock", get_kernel("hartree_fock.twoel"), (pos, dens),
         exact=False, num_shards=shards)

    # the shard_pallas composites: the SAME Pallas kernel source, sharded
    # (interpret mode on these simulated host devices) — bitwise against
    # the single-device Pallas backend where the math is reduction-free
    print()
    show("stencil7", s7, (u,), label=f"slab {shards}x1",
         backend="shard_pallas", against="pallas_interpret",
         num_shards=shards)
    if n >= 4:
        show("stencil7", s7, (u,), label="pencil 2x2",
             backend="shard_pallas", against="pallas_interpret",
             decomp="pencil", shard_grid=(2, 2))
    show("babelstream.triad", get_kernel("babelstream.triad"), (a, b),
         backend="shard_pallas", against="pallas_interpret",
         num_shards=shards)
    show("babelstream.dot", get_kernel("babelstream.dot"), (a, b),
         exact=False, backend="shard_pallas", num_shards=shards)
    show("minibude.fasten", get_kernel("minibude.fasten"), deck,
         backend="shard_pallas", against="pallas_interpret",
         num_shards=shards)
    show("hartree_fock", get_kernel("hartree_fock.twoel"), (pos, dens),
         exact=False, backend="shard_pallas", num_shards=shards)

    print("\nevery sharded backend validated against its oracle (and the "
          "shard_pallas composites against their single-device Pallas "
          "kernels); see BENCH_scaling.json for the per-backend efficiency "
          "curves")


if __name__ == "__main__":
    main()
