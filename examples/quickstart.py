"""Quickstart: the paper's portable-kernel workflow in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Run a science kernel through the portable registry on two backends.
2. Validate the Pallas kernel against the oracle (the paper's C1).
3. Compute the performance-portability metric Phi-bar (the paper's C3).
4. Run one LM train step + one decode step on a reduced config.
"""

import jax
import jax.numpy as jnp
import numpy as np

# importing ops registers the kernels
import repro.kernels.babelstream.ops  # noqa: F401
import repro.kernels.stencil7.ops  # noqa: F401
from repro.core import Efficiency, get_kernel, phi_bar
from repro.configs import get_config
from repro.models import transformer as T
from repro.training.serve_step import generate
from repro.training.train_step import TrainConfig, make_train_state, train_step


def science_kernels():
    print("== 1-3. portable kernels, validation, Phi-bar ==")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal(1 << 18), jnp.float32)
    b = jnp.asarray(rng.standard_normal(1 << 18), jnp.float32)

    triad = get_kernel("babelstream.triad")
    print("backends:", sorted(triad.backends))
    out_ref = triad(a, b, backend="xla")
    out_pal = triad(a, b, backend="pallas_interpret")
    triad.validate(a, b, backend="pallas_interpret", rtol=1e-5, atol=1e-5)
    print("triad validated; |diff| =",
          float(jnp.max(jnp.abs(out_ref - out_pal))))

    t_ref = triad.time_backend(a, b, backend="xla")
    t_pal = triad.time_backend(a, b, backend="pallas_interpret", iters=3)
    e = Efficiency("cpu-host", "triad", 1 / t_pal, 1 / t_ref)
    print(f"Eq.2 FoM (xla): {triad.figure_of_merit(t_ref, a, b)}")
    print(f"Eq.4 Phi-bar (single platform): {phi_bar([e]):.3f}")


def lm_steps():
    print("\n== 4. LM framework: one train step + generation ==")
    cfg = get_config("granite-3-8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    tcfg = TrainConfig(microbatches=2)
    state = make_train_state(params, tcfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    state, metrics = jax.jit(
        lambda s, b: train_step(s, b, cfg=cfg, tcfg=tcfg))(state, batch)
    print(f"train loss {float(metrics['loss']):.3f} "
          f"grad_norm {float(metrics['grad_norm']):.3f}")

    prompt = batch["tokens"][:2, :8]
    toks = generate(state["params"], cfg, prompt, max_new_tokens=8,
                    cache_len=64)
    print("generated token ids:", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    science_kernels()
    lm_steps()
    print("\nquickstart OK")
