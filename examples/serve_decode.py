"""Serving driver: batched prefill + decode with continuous batching slots.

    PYTHONPATH=src python examples/serve_decode.py --requests 12 --batch 4

Serves a reduced-config model: requests arrive with different prompt
lengths, are left-packed into fixed decode slots, prefilled, then decoded
step-by-step; finished sequences release their slot to queued requests
(continuous batching at slot granularity).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.training.serve_step import decode_step, prefill, sample


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # synthetic request queue: (id, prompt)
    queue = [(i, rng.integers(2, cfg.vocab_size,
                              rng.integers(4, 17)).astype(np.int32))
             for i in range(args.requests)]
    done = {}
    t_start = time.time()
    total_tokens = 0

    dec = jax.jit(lambda p, t, po, c: decode_step(p, cfg, t, po, c))

    while queue:
        # fill a batch of slots
        active = queue[:args.batch]
        queue = queue[args.batch:]
        plen = max(len(p) for _, p in active)
        prompts = np.zeros((len(active), plen), np.int32)
        for j, (_, p) in enumerate(active):
            prompts[j, plen - len(p):] = p      # left-pad
        last, caches, _ = prefill(params, cfg, jnp.asarray(prompts),
                                  cache_len=args.cache_len)
        toks = sample(last, jax.random.PRNGKey(1))[:, None]
        outs = [toks]
        for i in range(1, args.max_new):
            pos = jnp.full((len(active), 1), plen + i - 1, jnp.int32)
            logits, caches = dec(params, toks, pos, caches)
            toks = sample(logits, jax.random.PRNGKey(i))[:, None]
            outs.append(toks)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        for j, (rid, _) in enumerate(active):
            done[rid] = gen[j]
            total_tokens += gen.shape[1]
        print(f"batch of {len(active)} served; "
              f"{len(done)}/{args.requests} requests complete")

    dt = time.time() - t_start
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on this host)")
    print("sample output:", done[0][:10].tolist())


if __name__ == "__main__":
    main()
