"""Serving driver: fixed-shape continuous batching on the slot engine.

    PYTHONPATH=src python examples/serve_decode.py --requests 12 --slots 4

Requests arrive on a Poisson-ish trace with ragged prompt lengths and are
admitted into freed KV-cache slots mid-decode (continuous batching at slot
granularity).  The engine compiles exactly two programs — one (1,
prefill_len) masked prefill and one (num_slots, 1) decode step — and never
recompiles as requests arrive/finish: prompts are left-padded to the fixed
prefill shape with pads masked out of attention (no attending over pad
token 0), decode positions track each request's TRUE prompt length, and
every request samples from its own PRNG key stream (no repeated
continuations across batches).

``--attn-backend`` picks the registry attention backend the two compiled
programs dispatch to (``xla`` oracle / ``pallas`` on TPU /
``pallas_interpret`` host-sim — see models/attention).  After serving, two
finished greedy requests are replayed through the *unbatched*
``serve_step.generate`` loop under the same backend and the token-level
bit-match result is printed.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import ServingEngine, latency_summary, synthetic_trace
from repro.training import serve_step as SS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="mean request arrival rate (requests/second)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-backend", default=None,
                    choices=["xla", "pallas", "pallas_interpret"],
                    help="registry attention backend (default: plain-XLA "
                         "oracle path; REPRO_ATTN_BACKEND overrides)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(params, cfg, num_slots=args.slots,
                           cache_len=args.cache_len,
                           prefill_len=args.prefill_len,
                           temperature=args.temperature,
                           attn_backend=args.attn_backend)
    print(f"attention dispatch: requested={args.attn_backend or 'auto'} "
          f"resolved prefill={engine.attn_backends['prefill']} "
          f"decode={engine.attn_backends['decode']}")

    trace = synthetic_trace(args.requests, vocab_size=cfg.vocab_size,
                            rate=args.rate, max_prompt=args.prefill_len,
                            max_new_tokens=args.max_new)
    t_start = time.time()
    done = engine.run(trace)
    dt = time.time() - t_start

    for req in sorted(done, key=lambda r: r.uid):
        print(f"req {req.uid:3d} prompt_len {req.prompt_len:2d} "
              f"latency {req.latency() * 1e3:7.1f} ms "
              f"tokens {req.generated[:8]}...")
    lat = latency_summary(done)
    s = engine.stats
    print(f"\nserved {len(done)} requests, {s['tokens_generated']} tokens "
          f"in {dt:.2f}s ({s['tokens_generated'] / dt:.1f} tok/s)")
    print(f"latency p50 {lat['p50_latency_s'] * 1e3:.1f} ms "
          f"p95 {lat['p95_latency_s'] * 1e3:.1f} ms "
          f"p99 {lat['p99_latency_s'] * 1e3:.1f} ms; "
          f"ttft p50 {lat['p50_ttft_s'] * 1e3:.1f} ms "
          f"p99 {lat['p99_ttft_s'] * 1e3:.1f} ms"
          + (f"; itl p50 {lat['p50_itl_s'] * 1e3:.2f} ms "
             f"p99 {lat['p99_itl_s'] * 1e3:.2f} ms"
             if "p99_itl_s" in lat else ""))
    print(f"compiled shapes: prefill x{s['prefill_traces']} "
          f"decode x{s['decode_traces']} "
          f"({s['prefill_calls']} prefills, {s['decode_steps']} decode steps)")
    assert s["prefill_traces"] == 1 and s["decode_traces"] == 1, \
        "engine recompiled — fixed-shape contract violated"

    if args.temperature == 0.0:
        # oracle-vs-Pallas dispatch demo: replay two finished requests
        # through the unbatched generate loop under the SAME backend — the
        # batched↔unbatched greedy bit-match must hold per backend
        for req in sorted(done, key=lambda r: r.uid)[:2]:
            want = SS.generate(params, engine.cfg,
                               jnp.asarray(np.asarray(req.prompt)[None]),
                               max_new_tokens=len(req.generated),
                               cache_len=args.cache_len,
                               attn_backend=args.attn_backend)
            match = req.generated == list(np.asarray(want[0]))
            print(f"bit-match vs unbatched greedy (req {req.uid}, "
                  f"backend={engine.attn_backends['decode']}): "
                  f"{'OK' if match else 'MISMATCH'}")
            assert match, "batched decode diverged from unbatched"


if __name__ == "__main__":
    main()
