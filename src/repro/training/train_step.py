"""Training step: remat + microbatch gradient accumulation + AdamW.

The microbatch loop is a lax.scan, which (a) bounds live activation memory to
one microbatch, and (b) lets XLA overlap each microbatch's gradient
reduce-scatter with the next microbatch's compute (latency hiding at the
pod scale).  Optional error-feedback int8 compression decimates cross-pod
gradient bytes (optim/compression.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import NO_HINTS, ShardingHints, forward
from repro.optim import adamw, compression
from repro.training.losses import softmax_xent


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    moe_aux_weight: float = 0.01
    z_loss: float = 1e-4
    compress_pod_grads: bool = False
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


def make_train_state(params, tcfg: TrainConfig) -> Dict[str, Any]:
    state = {"params": params, "opt": adamw.init_state(params)}
    if tcfg.compress_pod_grads:
        state["residual"] = compression.init_residual(params)
    return state


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            tcfg: TrainConfig, hints: ShardingHints = NO_HINTS):
    logits, _, aux = forward(
        params, cfg, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
        hints=hints, remat=tcfg.remat)
    loss, metrics = softmax_xent(logits, batch["targets"],
                                 batch.get("mask"), z_loss=tcfg.z_loss)
    total = loss + tcfg.moe_aux_weight * aux
    metrics = dict(metrics, loss=loss, moe_aux=aux)
    return total, metrics


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def split(a):
        b = a.shape[0]
        if b % k:
            raise ValueError(f"batch {b} not divisible into {k} microbatches")
        return a.reshape(k, b // k, *a.shape[1:])
    return jax.tree.map(split, batch)


def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray], *,
               cfg: ModelConfig, tcfg: TrainConfig,
               hints: ShardingHints = NO_HINTS,
               ) -> Tuple[Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One optimizer step over `batch` (global batch on axis 0)."""
    params = state["params"]
    if cfg.zero1_weights:
        # beyond-paper lever (DESIGN.md §8 / EXPERIMENTS §Perf): one bf16
        # cast + FSDP gather per STEP, hoisted out of the microbatch loop;
        # gradients flow through the cast back to the fp32 masters.
        from repro.models.common import cast_tree
        compute_params = hints.params_compute(
            cast_tree(params, cfg.cdtype()))
    else:
        compute_params = params
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if tcfg.microbatches > 1:
        mbs = _split_microbatches(batch, tcfg.microbatches)

        def mb_body(carry, mb):
            g_acc, l_acc, m_acc = carry
            (_, metrics), grads = grad_fn(compute_params, cfg, mb, tcfg,
                                          hints)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, l_acc + metrics["loss"],
                    jax.tree.map(jnp.add, m_acc, metrics)), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"nll": 0., "accuracy": 0., "z_loss": 0., "loss": 0.,
              "moe_aux": 0.}
        m0 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), m0)
        (grads, _, metrics), _ = jax.lax.scan(
            mb_body, (g0, jnp.asarray(0.0, jnp.float32), m0), mbs)
        inv = 1.0 / tcfg.microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, metrics)
    else:
        (_, metrics), grads = grad_fn(compute_params, cfg, batch, tcfg,
                                      hints)

    if tcfg.compress_pod_grads:
        grads, new_residual = compression.ef_compress_tree(
            grads, state["residual"])

    new_params, new_opt, opt_metrics = adamw.apply_updates(
        params, grads, state["opt"], tcfg.opt)
    new_state = {"params": new_params, "opt": new_opt}
    if tcfg.compress_pod_grads:
        new_state["residual"] = new_residual
    return new_state, dict(metrics, **opt_metrics)
