"""training subsystem."""
