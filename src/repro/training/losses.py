"""Losses: causal-LM cross entropy with fp32 logsumexp and z-loss."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray,
                 mask: jnp.ndarray | None = None, z_loss: float = 1e-4,
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """logits (B, S, V) any float dtype; targets (B, S) int32.

    mask (B, S) float weights (1 = real token).  Returns (scalar, metrics).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones(per_tok.shape, jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_tok * mask) / denom
    acc = jnp.sum((jnp.argmax(lf, -1) == targets) * mask) / denom
    return loss, {"nll": jnp.sum(nll * mask) / denom, "accuracy": acc,
                  "z_loss": jnp.sum(zl * mask) / denom}
