"""Serving: prefill + KV-cache decode steps (batched requests)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (NO_HINTS, ShardingHints, encode,
                                      forward, init_caches)


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            cache_len: int, lengths=None, frames=None, patches=None,
            hints: ShardingHints = NO_HINTS, attn_backend=None):
    """Process the prompt, fill caches. Returns (last_logits, caches, memory).

    lengths: (B,) true prompt lengths for a LEFT-padded mixed batch.  Without
    it, every token (pads included) is attended and positions assume no
    padding — only correct for unpadded batches.  With it, pads are masked
    out of attention and the KV cache, and the returned last-position logits
    are each row's true final-token logits (left-padding puts the final token
    at index -1).  Subsequent decode positions must start at `lengths[b]`.
    attn_backend: registry attention backend override (see models/attention).
    """
    b, s = tokens.shape
    caches = init_caches(cfg, b, cache_len)
    memory = None
    if cfg.is_encoder_decoder:
        memory, _ = encode(params, cfg, frames, hints)
    logits, caches, _ = forward(params, cfg, tokens, caches=caches,
                                patches=patches, memory=memory, hints=hints,
                                last_only=True, lengths=lengths,
                                attn_backend=attn_backend)
    return logits[:, -1], caches, memory


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray,
                positions: jnp.ndarray, caches, *, memory=None,
                hints: ShardingHints = NO_HINTS, attn_backend=None):
    """One token for every sequence. tokens/positions (B, 1)."""
    logits, caches, _ = forward(params, cfg, tokens, positions=positions,
                                caches=caches, memory=memory, hints=hints,
                                attn_backend=attn_backend)
    return logits[:, -1], caches


def sample(logits: jnp.ndarray, key, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits (B, V) -> token ids (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        lf = jnp.where(lf < vals[..., -1:], -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def sample_per_slot(logits: jnp.ndarray, keys: jnp.ndarray,
                    temperature: float = 0.0, top_k: int = 0) -> jnp.ndarray:
    """logits (B, V), keys (B, 2): one independent PRNG key per row.

    Continuous-batching slots each belong to a different request, so rows
    must not share a key (and a request's key stream must not restart when
    its slot-mates change).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(
        lambda lg, k: sample(lg[None], k, temperature, top_k)[0]
    )(logits, keys)


def generate(params, cfg: ModelConfig, prompt: jnp.ndarray, *,
             max_new_tokens: int, cache_len: int, key=None,
             temperature: float = 0.0, frames=None, patches=None,
             hints: ShardingHints = NO_HINTS, attn_backend=None) -> jnp.ndarray:
    """Greedy/temperature generation loop (host-driven, jit per step)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    b, s = prompt.shape
    last, caches, memory = prefill(params, cfg, prompt, cache_len=cache_len,
                                   frames=frames, patches=patches,
                                   hints=hints, attn_backend=attn_backend)
    out = []
    tok = sample(last, key, temperature)
    out.append(tok)
    for i in range(1, max_new_tokens):
        key, sub = jax.random.split(key)
        pos = jnp.full((b, 1), s + i - 1, jnp.int32)
        logits, caches = decode_step(params, cfg, tok[:, None], pos, caches,
                                     memory=memory, hints=hints,
                                     attn_backend=attn_backend)
        tok = sample(logits, sub, temperature)
        out.append(tok)
    return jnp.stack(out, axis=1)
