"""Sharded checkpointing: npz-per-host + JSON manifest, async, elastic.

Arrays are saved in *logical* (unsharded) form, so a checkpoint written on a
256-chip mesh restores onto any other topology (elastic resume) — the caller
re-device_puts with the new mesh's shardings.  Writes are atomic
(tmp + rename) and a retention policy prunes old steps.  SIGTERM-safe when
used through distributed.fault_tolerance.TrainSupervisor.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ---- paths ---------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    # ---- save ----------------------------------------------------------
    def save(self, step: int, state: Any, metadata: Optional[Dict] = None,
             blocking: bool = True) -> None:
        # snapshot to host memory synchronously (cheap), write async if asked
        flat = _flatten(state)
        if blocking:
            self._write(step, flat, metadata or {})
        else:
            self.wait()  # one in flight at a time
            self._async_thread = threading.Thread(
                target=self._write, args=(step, flat, metadata or {}),
                daemon=True)
            self._async_thread.start()

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               metadata: Dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"), **flat)
        manifest = {"step": step, "time": time.time(),
                    "n_leaves": len(flat), **metadata}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _prune(self) -> None:
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore -------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                ) -> Tuple[Any, Dict]:
        """Restore into the structure/dtypes of `template` (any topology)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, f"host_{self.host_id}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(template, flat), manifest
