"""checkpoint subsystem."""
