"""Fixed-shape continuous-batching decode engine (v2).

The engine owns the KV cache for ``num_slots`` concurrent requests and a
small, bounded set of compiled programs that never grows as requests
arrive/finish:

  * prefill — one compiled shape PER BUCKET of the prefill ladder
    (``prefill_buckets``, default a single bucket).  Each prompt is
    left-padded to the smallest bucket that fits and masked via position -1
    (models/transformer.leftpad_positions), so a short prompt no longer pays
    for the maximum prefill shape and the compile count stays bounded at the
    ladder size.  The freshly-built single-row cache is scattered into the
    engine cache at the assigned slot (MaxText-style prefill-insert).
  * decode — ONE shape for ALL slots, (num_slots, 1).  Inactive slots decode
    garbage whose sampled tokens are ignored and whose cache writes land in
    storage no active request reads — the shape never changes, so requests
    joining or leaving mid-decode cause no recompilation.

Two KV-cache layouts (``cache_layout=``), bitwise-identical in their greedy
outputs:

  * ``"contiguous"`` — one (num_slots, cache_len) row per slot (engine v1).
  * ``"paged"``      — a shared (num_blocks, block_size) page pool with
    per-slot block tables (vLLM idiom; see serving/paged.py).  Requests own
    only the pages their positions need, admission is gated on free pages,
    and the decode program gathers the pool through the tables into the
    same contiguous view the v1 program consumed — still one compiled
    decode shape.

Scheduling is slot-granular continuous batching: a FIFO queue admits work
into freed slots between decode steps (head-of-line: if the head request
does not fit — no slot, or not enough free pages — nothing behind it jumps
ahead), each slot tracks its own absolute position, and every request owns
an independent PRNG key stream folded from its uid.

Two driver loops share the same admission/decode core:

  * ``run``          — synchronous: admit-then-decode per step.
  * ``run_threaded`` — producer/consumer (MaxText JetThread+queue idiom):
    an injector thread sleeps until each arrival and feeds a BOUNDED
    backpressure queue, an admission thread blocks on capacity and prefills
    under the engine lock, and the decode loop runs on the calling thread.
    Greedy tokens are bitwise-identical to the synchronous loop because
    per-request sampling is independent of interleaving.

Supported models: decoder-only attention archs (dense / MoE / SWA).  RWKV
and SSM/hybrid state caches and encoder-decoder memory are per-request state
this slot scatter does not yet carry; MoE capacity routing is batch-coupled,
so MoE outputs can differ from unbatched decode.

Telemetry: when ``REPRO_TELEMETRY`` is on, the engine emits a full request
lifecycle on the ``engine`` track — ``serving.enqueue`` ->
``serving.slot_assign`` -> a ``serving.prefill`` span -> ``serving.first_token``
-> per-step ``serving.decode_step`` spans -> ``serving.finish`` — plus
``serving.queue_depth`` / ``serving.slot_occupancy`` gauges sampled per
step.  All events fire at the Python driver level around the compiled
programs, never inside them: enabling telemetry changes no compiled shape
and no sampled token (bitwise-neutral by construction).
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import telemetry as tel
from repro.models.attention import resolve_attention_backend
from repro.models.transformer import forward, init_caches
from repro.training.serve_step import decode_step, sample, sample_per_slot
from repro.serving.paged import (gather_caches, init_paged_caches,
                                 scatter_decode, scatter_prefill)
from repro.serving.request import Request, RequestQueue
from repro.serving.slots import (RESERVED_BLOCKS, TRASH_BLOCK, BlockAllocator,
                                 SENTINEL_BLOCK, SlotAllocator)


def scatter_slot_cache(big, small, slot):
    """Insert a batch=1 cache pytree into the engine cache at `slot`.

    Eager-layer leaves are (batch, ...); scan-segment leaves are stacked
    (n_layers, batch, ...) — the batch axis is 0 vs 1 respectively.
    """
    def upd(axis):
        return lambda b, s: jax.lax.dynamic_update_slice_in_dim(
            b, s.astype(b.dtype), slot, axis)

    return {
        "eager": jax.tree.map(upd(0), big["eager"], small["eager"]),
        "segments": [jax.tree.map(upd(1), bg, sm)
                     for bg, sm in zip(big["segments"], small["segments"])],
    }


class JetThread(threading.Thread):
    """Thread that records its exception instead of dying silently (MaxText
    offline-inference idiom) — the driver re-raises after join."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.error: Optional[BaseException] = None

    def run(self) -> None:
        try:
            super().run()
        except BaseException as exc:        # noqa: BLE001 — surfaced on join
            self.error = exc


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 4,
                 cache_len: int = 128, prefill_len: int = 32,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 temperature: float = 0.0, seed: int = 0,
                 attn_backend: Optional[str] = None,
                 cache_layout: str = "contiguous", block_size: int = 16,
                 num_blocks: Optional[int] = None):
        if cfg.rwkv or cfg.ssm_state or cfg.is_encoder_decoder:
            raise NotImplementedError(
                "slot engine supports decoder-only attention archs; "
                f"{cfg.name} carries per-request recurrent/encoder state")
        if prefill_buckets is None:
            buckets: Tuple[int, ...] = (int(prefill_len),)
        else:
            buckets = tuple(sorted({int(b) for b in prefill_buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError("prefill buckets must be positive")
        if buckets[-1] > cache_len:
            raise ValueError("prefill_len must fit in cache_len")
        if attn_backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=attn_backend)
        if cache_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        self.params = params
        self.cfg = cfg
        # what the compiled programs will actually dispatch to (env var /
        # availability fallback applied) — benchmark rows report this
        self.attn_backends = {
            kind: resolve_attention_backend(kind, cfg.attn_backend)
            for kind in ("prefill", "decode")}
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.prefill_buckets = buckets
        self.prefill_len = buckets[-1]       # largest admissible prompt
        self.temperature = temperature
        self.cache_layout = cache_layout

        if cache_layout == "paged":
            if num_blocks is None:
                # default: same KV footprint as the contiguous layout
                num_blocks = (num_slots * (cache_len // max(1, block_size))
                              + RESERVED_BLOCKS)
            self.block_size = block_size
            self.num_blocks = num_blocks
            self.pages_per_slot = cache_len // block_size
            self.balloc = BlockAllocator(num_blocks, block_size)
            self.block_tables = np.full(
                (num_slots, self.pages_per_slot), TRASH_BLOCK, np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in range(num_slots)]
            self.caches = init_paged_caches(
                cfg, num_slots=num_slots, cache_len=cache_len,
                block_size=block_size, num_blocks=num_blocks)
        else:
            self.caches = init_caches(cfg, num_slots, cache_len)
        self.tok_buf = np.zeros((num_slots, 1), np.int32)
        self.pos_buf = np.zeros((num_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slots = SlotAllocator(num_slots)
        self.queue = RequestQueue()
        self._base_key = jax.random.PRNGKey(seed)
        self._t0 = time.perf_counter()
        # run_threaded: every engine mutation happens under this lock; the
        # condition signals capacity changes (finish) and admissions
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)

        self.stats: Dict[str, int] = {
            "prefill_traces": 0, "decode_traces": 0,
            "prefill_calls": 0, "decode_steps": 0,
            "requests_finished": 0, "tokens_generated": 0,
        }
        self._build_fns()

    # ------------------------------------------------------------------
    def _build_fns(self) -> None:
        cfg, cache_len, temp = self.cfg, self.cache_len, self.temperature
        stats = self.stats

        if self.cache_layout == "paged":
            ns, bs = self.num_slots, self.block_size

            def prefill_fn(params, tokens, lengths, table_row, slot, key,
                           caches):
                stats["prefill_traces"] += 1    # runs only when (re)traced
                small = init_caches(cfg, 1, cache_len)
                logits, small, _ = forward(params, cfg, tokens, caches=small,
                                           lengths=lengths, last_only=True)
                caches = scatter_prefill(caches, small, table_row, slot, cfg,
                                         cache_len=cache_len, block_size=bs)
                return sample(logits[:, -1], key, temp)[0], caches

            def decode_fn(params, tokens, positions, keys, caches, tables):
                stats["decode_traces"] += 1
                contig = gather_caches(caches, tables, cfg, num_slots=ns,
                                       cache_len=cache_len, block_size=bs)
                logits, contig = decode_step(params, cfg, tokens, positions,
                                             contig)
                caches = scatter_decode(caches, contig, positions[:, 0],
                                        tables, cfg, cache_len=cache_len,
                                        block_size=bs)
                return sample_per_slot(logits, keys, temp), caches
        else:
            def prefill_fn(params, tokens, lengths, slot, key, caches):
                stats["prefill_traces"] += 1    # runs only when (re)traced
                small = init_caches(cfg, 1, cache_len)
                logits, small, _ = forward(params, cfg, tokens, caches=small,
                                           lengths=lengths, last_only=True)
                caches = scatter_slot_cache(caches, small, slot)
                return sample(logits[:, -1], key, temp)[0], caches

            def decode_fn(params, tokens, positions, keys, caches):
                stats["decode_traces"] += 1
                logits, caches = decode_step(params, cfg, tokens, positions,
                                             caches)
                return sample_per_slot(logits, keys, temp), caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def active_count(self) -> int:
        return self.slots.in_use()

    def _bucket_for(self, prompt_len: int) -> int:
        """Smallest ladder bucket that fits the prompt."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        raise AssertionError("unreachable: submit validated prompt_len")

    # ------------------------------------------------------------------
    def _validate(self, req: Request) -> None:
        if req.prompt_len < 1 or req.prompt_len > self.prefill_len:
            raise ValueError(
                f"prompt length {req.prompt_len} outside [1, "
                f"{self.prefill_len}]")
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            raise ValueError("prompt + max_new_tokens exceeds cache_len")
        if self.cache_layout == "paged":
            need = self.balloc.blocks_for(req.prompt_len, req.max_new_tokens)
            if need > self.balloc.capacity():
                raise ValueError(
                    f"request needs {need} KV pages but the pool holds only "
                    f"{self.balloc.capacity()}")

    def submit(self, req: Request) -> None:
        self._validate(req)
        if req.key is None:
            req.key = jax.random.fold_in(self._base_key, req.uid)
        self.queue.submit(req)
        tel.instant("serving.enqueue", proc="engine", uid=req.uid,
                    prompt_len=req.prompt_len,
                    max_new_tokens=req.max_new_tokens,
                    queue_depth=len(self.queue))

    def _has_capacity(self, req: Request) -> bool:
        """Can `req` be admitted right now?  A free slot always; the paged
        layout additionally needs the request's full page reservation."""
        if not self.slots.available():
            return False
        if self.cache_layout == "paged":
            return (self.balloc.available()
                    >= self.balloc.blocks_for(req.prompt_len,
                                              req.max_new_tokens))
        return True

    def _finish(self, slot: int, req: Request, now: float,
                finished: List[Request]) -> None:
        req.t_done = now
        self.slot_req[slot] = None
        self.slots.free(slot)
        if self.cache_layout == "paged":
            self.balloc.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            # inactive again: route this slot's garbage decode writes to
            # the trash page so they never land in a mapped page
            self.block_tables[slot] = TRASH_BLOCK
        self.stats["requests_finished"] += 1
        finished.append(req)
        tel.instant("serving.finish", proc="engine", uid=req.uid, slot=slot,
                    tokens=len(req.generated),
                    latency_s=req.t_done - req.arrival_time)
        tel.counter("serving.requests_finished", proc="engine")

    def _admit(self, req: Request, now: float,
               finished: List[Request]) -> None:
        slot = self.slots.alloc()
        self.slot_req[slot] = req
        req.t_admitted = now
        tel.instant("serving.slot_assign", proc="engine", uid=req.uid,
                    slot=slot, queued_s=now - req.arrival_time)
        L = req.prompt_len
        bucket = self._bucket_for(L)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - L:] = req.prompt                # left-pad
        if self.temperature > 0.0:
            req.key, sub = jax.random.split(req.key)
        else:
            sub = req.key       # greedy: sample() never consumes the key
        if self.cache_layout == "paged":
            n_pages = self.balloc.blocks_for(L, req.max_new_tokens)
            pages = self.balloc.alloc(n_pages)           # full lifetime up
            self._slot_blocks[slot] = pages              # front: decode never
            row = np.full(self.pages_per_slot, SENTINEL_BLOCK, np.int32)
            row[:n_pages] = pages                        # hits an unowned page
            self.block_tables[slot] = row
        with tel.span("serving.prefill", proc="engine", uid=req.uid,
                      slot=slot, prompt_len=L, bucket=bucket):
            if self.cache_layout == "paged":
                tok0, self.caches = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([L], jnp.int32), jnp.asarray(row),
                    np.int32(slot), sub, self.caches)
            else:
                tok0, self.caches = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([L], jnp.int32), np.int32(slot), sub,
                    self.caches)
            tok0 = int(tok0)     # device sync: the span covers the wait
        self.stats["prefill_calls"] += 1
        now = self._clock()
        req.t_first_token = now
        req.t_tokens.append(now)
        tel.instant("serving.first_token", proc="engine", uid=req.uid,
                    slot=slot, ttft_s=now - req.arrival_time)
        req.generated.append(tok0)
        self.stats["tokens_generated"] += 1
        if len(req.generated) >= req.max_new_tokens or tok0 == req.eos_id:
            self._finish(slot, req, now, finished)
            return
        self.tok_buf[slot, 0] = tok0
        self.pos_buf[slot, 0] = L        # true length, not padded length

    # ------------------------------------------------------------------
    def _decode_once(self, finished: List[Request]) -> int:
        """Decode one token for every slot; appends newly finished requests
        to `finished` and returns how many finished."""
        active = self.active_count()
        if active == 0:
            return 0
        n0 = len(finished)
        tel.gauge("serving.queue_depth", len(self.queue), proc="engine")
        tel.gauge("serving.slot_occupancy", active / self.num_slots,
                  proc="engine")
        keys = np.zeros((self.num_slots, 2), np.uint32)
        if self.temperature > 0.0:      # greedy path never reads the keys
            for s, req in enumerate(self.slot_req):
                if req is not None:
                    req.key, sub = jax.random.split(req.key)
                    keys[s] = np.asarray(sub)
        with tel.span("serving.decode_step", proc="engine", active=active,
                      step=self.stats["decode_steps"]):
            if self.cache_layout == "paged":
                toks, self.caches = self._decode(
                    self.params, jnp.asarray(self.tok_buf),
                    jnp.asarray(self.pos_buf), jnp.asarray(keys),
                    self.caches, jnp.asarray(self.block_tables))
            else:
                toks, self.caches = self._decode(
                    self.params, jnp.asarray(self.tok_buf),
                    jnp.asarray(self.pos_buf), jnp.asarray(keys), self.caches)
            toks = np.asarray(toks)      # device sync inside the span
        self.stats["decode_steps"] += 1
        now = self._clock()
        for s, req in enumerate(self.slot_req):
            if req is None:                      # inactive slot: token ignored
                continue
            t = int(toks[s])
            req.generated.append(t)
            req.t_tokens.append(now)
            self.stats["tokens_generated"] += 1
            if len(req.generated) >= req.max_new_tokens or t == req.eos_id:
                self._finish(s, req, now, finished)
            else:
                self.tok_buf[s, 0] = t
                self.pos_buf[s, 0] += 1
        return len(finished) - n0

    def step(self, now: Optional[float] = None) -> List[Request]:
        """Admit ready requests into free slots, then decode one token for
        every slot.  Returns the requests that finished this step."""
        if now is None:
            now = self._clock()
        finished: List[Request] = []
        first = True
        while self.slots.available():
            if not first:
                # prefill takes real time: recompute the clock so later
                # admits in the same step get honest t_admitted/queued_s and
                # requests that arrived mid-prefill are checked now, not
                # next step (stale-`now` admission bug)
                now = max(now, self._clock())
            head = self.queue.peek_ready(now)
            if head is None or not self._has_capacity(head):
                break                    # FIFO head-of-line: no queue jumping
            self._admit(self.queue.pop_ready(now), now, finished)
            first = False
        self._decode_once(finished)
        return finished

    def run(self, requests: Sequence[Request]) -> List[Request]:
        """Serve a trace to completion, synchronously.  Resets the engine
        clock to 0, so `arrival_time` fields are relative to this call."""
        self._t0 = time.perf_counter()
        with tel.span("serving.run", proc="engine",
                      requests=len(requests), num_slots=self.num_slots):
            for req in sorted(requests, key=lambda r: r.arrival_time):
                self.submit(req)
            finished: List[Request] = []
            while self.queue or self.active_count():
                now = self._clock()
                if self.active_count() == 0 and not self.queue.has_ready(now):
                    # idle: sleep until the next arrival (capped so clock
                    # drift can't oversleep), not a 1 ms busy-spin
                    nxt = self.queue.next_arrival()
                    time.sleep(min(max(0.0, nxt - now), 0.05))
                    continue
                finished.extend(self.step(now))
        return finished

    # ------------------------------------------------------------------
    def run_threaded(self, requests: Sequence[Request], *,
                     backpressure: Optional[int] = None,
                     poll_s: float = 0.02) -> List[Request]:
        """Serve a trace with concurrent arrival injection, admission, and
        decode (MaxText JetThread+queue idiom).

        * injector thread — sleeps until each request's wall-clock arrival,
          then puts it on a BOUNDED queue (default ``2 * num_slots``); a put
          into a full queue blocks, which is the backpressure.
        * admission thread — pops arrivals, waits on the engine condition
          until the request fits (free slot + free pages), then prefills
          under the engine lock.
        * decode loop — runs here on the calling thread, also under the
          lock; finishing a request notifies the admission thread.

        Greedy tokens are bitwise-identical to ``run`` on the same trace:
        each request's continuation depends only on its own prompt and key
        stream, never on which step admitted it.
        """
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        for r in reqs:                   # fail on the caller, not a thread
            self._validate(r)
            if r.key is None:
                r.key = jax.random.fold_in(self._base_key, r.uid)
        if backpressure is None:
            backpressure = max(2, 2 * self.num_slots)
        arrivals: _queue.Queue = _queue.Queue(maxsize=backpressure)
        finished: List[Request] = []
        admission_done = threading.Event()
        abort = threading.Event()
        self._t0 = time.perf_counter()

        def _put(item) -> bool:
            while not abort.is_set():
                try:
                    arrivals.put(item, timeout=poll_s)
                    return True
                except _queue.Full:
                    continue
            return False

        def inject() -> None:
            for r in reqs:
                wait = r.arrival_time - self._clock()
                if wait > 0:
                    time.sleep(wait)
                tel.instant("serving.enqueue", proc="engine", uid=r.uid,
                            prompt_len=r.prompt_len,
                            max_new_tokens=r.max_new_tokens,
                            queue_depth=arrivals.qsize())
                if not _put(r):
                    return
            _put(None)                   # sentinel: trace fully injected

        def admit() -> None:
            while not abort.is_set():
                try:
                    r = arrivals.get(timeout=poll_s)
                except _queue.Empty:
                    continue
                if r is None:
                    break
                with self._cond:
                    while not self._has_capacity(r):
                        if abort.is_set():
                            return
                        self._cond.wait(poll_s)
                    self._admit(r, self._clock(), finished)
                    self._cond.notify_all()
            admission_done.set()

        threads = [JetThread(target=inject, name="serving-inject",
                             daemon=True),
                   JetThread(target=admit, name="serving-admit",
                             daemon=True)]
        with tel.span("serving.run", proc="engine", requests=len(reqs),
                      num_slots=self.num_slots, mode="threaded",
                      backpressure=backpressure):
            for t in threads:
                t.start()
            while True:
                with self._cond:
                    if self.active_count():
                        if self._decode_once(finished):
                            self._cond.notify_all()   # capacity freed
                    elif admission_done.is_set():
                        break
                    else:
                        self._cond.wait(poll_s)
                if any(t.error is not None for t in threads):
                    break
            abort.set()
            for t in threads:
                t.join()
        for t in threads:
            if t.error is not None:
                raise t.error
        return finished
