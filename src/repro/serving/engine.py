"""Fixed-shape continuous-batching decode engine.

The engine owns a (num_slots, cache_len) KV cache and exactly TWO compiled
programs, hit once each and never again as requests arrive/finish:

  * prefill: (1, prefill_len) left-padded prompt -> per-slot cache insert.
    Prompts are padded to one fixed length and masked via position -1
    (models/transformer.leftpad_positions), so every prompt length shares a
    single compiled shape and pad tokens never corrupt logits or KV entries.
    The freshly-built single-row cache is scattered into the engine cache at
    the assigned slot (MaxText-style prefill-insert).
  * decode: one token for ALL num_slots slots, (num_slots, 1).  Inactive
    slots decode garbage into their own (about-to-be-overwritten) cache rows
    and their sampled tokens are ignored — the shape never changes, so
    requests joining or leaving mid-decode cause no recompilation.

Scheduling is slot-granular continuous batching (vLLM-style): a request
queue admits work into freed slots between decode steps, each slot tracks
its own absolute position (= true prompt length + tokens generated, never
the padded length), and every request owns an independent PRNG key stream
folded from its uid so sampled continuations never repeat across requests
or batches.

Supported models: decoder-only attention archs (dense / MoE / SWA).  RWKV
and SSM/hybrid state caches and encoder-decoder memory are per-request state
this slot scatter does not yet carry; MoE capacity routing is batch-coupled,
so MoE outputs can differ from unbatched decode.

Telemetry: when ``REPRO_TELEMETRY`` is on, the engine emits a full request
lifecycle on the ``engine`` track — ``serving.enqueue`` ->
``serving.slot_assign`` -> a ``serving.prefill`` span -> ``serving.first_token``
-> per-step ``serving.decode_step`` spans -> ``serving.finish`` — plus
``serving.queue_depth`` / ``serving.slot_occupancy`` gauges sampled per
step.  All events fire at the Python driver level around the two compiled
programs, never inside them: enabling telemetry changes no compiled shape
and no sampled token (bitwise-neutral by construction).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import telemetry as tel
from repro.models.attention import resolve_attention_backend
from repro.models.transformer import forward, init_caches
from repro.training.serve_step import decode_step, sample, sample_per_slot
from repro.serving.request import Request, RequestQueue
from repro.serving.slots import SlotAllocator


def scatter_slot_cache(big, small, slot):
    """Insert a batch=1 cache pytree into the engine cache at `slot`.

    Eager-layer leaves are (batch, ...); scan-segment leaves are stacked
    (n_layers, batch, ...) — the batch axis is 0 vs 1 respectively.
    """
    def upd(axis):
        return lambda b, s: jax.lax.dynamic_update_slice_in_dim(
            b, s.astype(b.dtype), slot, axis)

    return {
        "eager": jax.tree.map(upd(0), big["eager"], small["eager"]),
        "segments": [jax.tree.map(upd(1), bg, sm)
                     for bg, sm in zip(big["segments"], small["segments"])],
    }


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 4,
                 cache_len: int = 128, prefill_len: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 attn_backend: Optional[str] = None):
        if cfg.rwkv or cfg.ssm_state or cfg.is_encoder_decoder:
            raise NotImplementedError(
                "slot engine supports decoder-only attention archs; "
                f"{cfg.name} carries per-request recurrent/encoder state")
        if prefill_len > cache_len:
            raise ValueError("prefill_len must fit in cache_len")
        if attn_backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=attn_backend)
        self.params = params
        self.cfg = cfg
        # what the two compiled programs will actually dispatch to (env var /
        # availability fallback applied) — benchmark rows report this
        self.attn_backends = {
            kind: resolve_attention_backend(kind, cfg.attn_backend)
            for kind in ("prefill", "decode")}
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.prefill_len = prefill_len
        self.temperature = temperature

        self.caches = init_caches(cfg, num_slots, cache_len)
        self.tok_buf = np.zeros((num_slots, 1), np.int32)
        self.pos_buf = np.zeros((num_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slots = SlotAllocator(num_slots)
        self.queue = RequestQueue()
        self._base_key = jax.random.PRNGKey(seed)
        self._t0 = time.perf_counter()

        self.stats: Dict[str, int] = {
            "prefill_traces": 0, "decode_traces": 0,
            "prefill_calls": 0, "decode_steps": 0,
            "requests_finished": 0, "tokens_generated": 0,
        }
        self._build_fns()

    # ------------------------------------------------------------------
    def _build_fns(self) -> None:
        cfg, cache_len, temp = self.cfg, self.cache_len, self.temperature
        stats = self.stats

        def prefill_fn(params, tokens, lengths, slot, key, caches):
            stats["prefill_traces"] += 1        # runs only when (re)traced
            small = init_caches(cfg, 1, cache_len)
            logits, small, _ = forward(params, cfg, tokens, caches=small,
                                       lengths=lengths, last_only=True)
            caches = scatter_slot_cache(caches, small, slot)
            return sample(logits[:, -1], key, temp)[0], caches

        def decode_fn(params, tokens, positions, keys, caches):
            stats["decode_traces"] += 1
            logits, caches = decode_step(params, cfg, tokens, positions,
                                         caches)
            return sample_per_slot(logits, keys, temp), caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def active_count(self) -> int:
        return self.slots.in_use()

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.prompt_len < 1 or req.prompt_len > self.prefill_len:
            raise ValueError(
                f"prompt length {req.prompt_len} outside [1, "
                f"{self.prefill_len}]")
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            raise ValueError("prompt + max_new_tokens exceeds cache_len")
        if req.key is None:
            req.key = jax.random.fold_in(self._base_key, req.uid)
        self.queue.submit(req)
        tel.instant("serving.enqueue", proc="engine", uid=req.uid,
                    prompt_len=req.prompt_len,
                    max_new_tokens=req.max_new_tokens,
                    queue_depth=len(self.queue))

    def _finish(self, slot: int, req: Request, now: float,
                finished: List[Request]) -> None:
        req.t_done = now
        self.slot_req[slot] = None
        self.slots.free(slot)
        self.stats["requests_finished"] += 1
        finished.append(req)
        tel.instant("serving.finish", proc="engine", uid=req.uid, slot=slot,
                    tokens=len(req.generated),
                    latency_s=req.t_done - req.arrival_time)
        tel.counter("serving.requests_finished", proc="engine")

    def _admit(self, req: Request, now: float,
               finished: List[Request]) -> None:
        slot = self.slots.alloc()
        self.slot_req[slot] = req
        req.t_admitted = now
        tel.instant("serving.slot_assign", proc="engine", uid=req.uid,
                    slot=slot, queued_s=now - req.arrival_time)
        L = req.prompt_len
        toks = np.zeros((1, self.prefill_len), np.int32)
        toks[0, self.prefill_len - L:] = req.prompt        # left-pad
        if self.temperature > 0.0:
            req.key, sub = jax.random.split(req.key)
        else:
            sub = req.key       # greedy: sample() never consumes the key
        with tel.span("serving.prefill", proc="engine", uid=req.uid,
                      slot=slot, prompt_len=L):
            tok0, self.caches = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray([L], jnp.int32), np.int32(slot), sub,
                self.caches)
            tok0 = int(tok0)     # device sync: the span covers the wait
        self.stats["prefill_calls"] += 1
        now = self._clock()
        req.t_first_token = now
        req.t_tokens.append(now)
        tel.instant("serving.first_token", proc="engine", uid=req.uid,
                    slot=slot, ttft_s=now - req.arrival_time)
        req.generated.append(tok0)
        self.stats["tokens_generated"] += 1
        if len(req.generated) >= req.max_new_tokens or tok0 == req.eos_id:
            self._finish(slot, req, now, finished)
            return
        self.tok_buf[slot, 0] = tok0
        self.pos_buf[slot, 0] = L        # true length, not padded length

    # ------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> List[Request]:
        """Admit ready requests into free slots, then decode one token for
        every slot.  Returns the requests that finished this step."""
        if now is None:
            now = self._clock()
        finished: List[Request] = []
        while self.slots.available() and self.queue.has_ready(now):
            self._admit(self.queue.pop_ready(now), now, finished)
        if self.active_count() == 0:
            return finished

        active = self.active_count()
        tel.gauge("serving.queue_depth", len(self.queue), proc="engine")
        tel.gauge("serving.slot_occupancy", active / self.num_slots,
                  proc="engine")
        keys = np.zeros((self.num_slots, 2), np.uint32)
        if self.temperature > 0.0:      # greedy path never reads the keys
            for s, req in enumerate(self.slot_req):
                if req is not None:
                    req.key, sub = jax.random.split(req.key)
                    keys[s] = np.asarray(sub)
        with tel.span("serving.decode_step", proc="engine", active=active,
                      step=self.stats["decode_steps"]):
            toks, self.caches = self._decode(
                self.params, jnp.asarray(self.tok_buf),
                jnp.asarray(self.pos_buf), jnp.asarray(keys), self.caches)
            toks = np.asarray(toks)      # device sync inside the span
        self.stats["decode_steps"] += 1
        now = self._clock()
        for s, req in enumerate(self.slot_req):
            if req is None:                      # inactive slot: token ignored
                continue
            t = int(toks[s])
            req.generated.append(t)
            req.t_tokens.append(now)
            self.stats["tokens_generated"] += 1
            if len(req.generated) >= req.max_new_tokens or t == req.eos_id:
                self._finish(s, req, now, finished)
            else:
                self.tok_buf[s, 0] = t
                self.pos_buf[s, 0] += 1
        return finished

    def run(self, requests: Sequence[Request]) -> List[Request]:
        """Serve a trace to completion.  Resets the engine clock to 0, so
        `arrival_time` fields are relative to the start of this call."""
        self._t0 = time.perf_counter()
        with tel.span("serving.run", proc="engine",
                      requests=len(requests), num_slots=self.num_slots):
            for req in sorted(requests, key=lambda r: r.arrival_time):
                self.submit(req)
            finished: List[Request] = []
            while self.queue or self.active_count():
                now = self._clock()
                if self.active_count() == 0 and not self.queue.has_ready(now):
                    nxt = self.queue.next_arrival()
                    time.sleep(min(1e-3, max(0.0, nxt - now)))
                    continue
                finished.extend(self.step(now))
        return finished
