"""Synthetic arrival traces + latency aggregation for the serving benchmark.

Arrivals are Poisson-ish: exponential inter-arrival gaps at `rate` requests
per second, ragged prompt lengths, fixed generation budget.  Times are
relative to `ServingEngine.run`'s clock start.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.serving.request import Request


def synthetic_trace(n_requests: int, *, vocab_size: int, rate: float = 50.0,
                    min_prompt: int = 4, max_prompt: int = 16,
                    max_new_tokens: int = 16, seed: int = 0,
                    uid_base: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    min_prompt = max(1, min(min_prompt, max_prompt))    # tiny --prefill-len
    t = 0.0
    out: List[Request] = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        length = int(rng.integers(min_prompt, max_prompt + 1))
        prompt = rng.integers(2, vocab_size, length).astype(np.int32)
        out.append(Request(uid=uid_base + i, prompt=prompt,
                           max_new_tokens=max_new_tokens, arrival_time=t))
    return out


def latency_summary(requests: Sequence[Request]) -> Dict[str, float]:
    """SLO percentiles over the *completed* requests (seconds).

    p50/p95/p99 of end-to-end latency and time-to-first-token, plus
    p50/p95/p99 inter-token latency pooled across every request's
    consecutive-token gaps (``Request.inter_token_gaps``; requests without
    per-token timestamps — e.g. hand-built test fixtures — contribute no
    gaps, and the ``itl`` keys are omitted when no request has any).

    Unfinished requests are excluded from the percentiles but NOT hidden:
    ``submitted`` counts every request handed in and ``unfinished`` the ones
    that never completed, so a half-drained trace can't masquerade as a
    clean SLO report (the serving benchmark fails any row with
    ``unfinished > 0``).

    A trace where nothing finished returns the explicit empty summary
    (``requests == 0``) instead of crashing ``np.percentile`` on an empty
    list.
    """
    done = [r for r in requests if r.finished]
    out: Dict[str, float] = {
        "requests": len(done),
        "submitted": len(requests),
        "unfinished": len(requests) - len(done),
    }
    if not done:
        return out
    lats = np.asarray([r.latency() for r in done])
    ttfts = np.asarray([r.ttft() for r in done])
    for q in (50, 95, 99):
        out[f"p{q}_latency_s"] = float(np.percentile(lats, q))
        out[f"p{q}_ttft_s"] = float(np.percentile(ttfts, q))
    gaps = [g for r in done for g in r.inter_token_gaps()]
    if gaps:
        arr = np.asarray(gaps)
        for q in (50, 95, 99):
            out[f"p{q}_itl_s"] = float(np.percentile(arr, q))
    return out
