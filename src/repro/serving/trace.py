"""Synthetic arrival traces + latency aggregation for the serving benchmark.

Arrivals are Poisson-ish: exponential inter-arrival gaps at `rate` requests
per second, ragged prompt lengths, fixed generation budget.  Times are
relative to `ServingEngine.run`'s clock start.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.serving.request import Request


def synthetic_trace(n_requests: int, *, vocab_size: int, rate: float = 50.0,
                    min_prompt: int = 4, max_prompt: int = 16,
                    max_new_tokens: int = 16, seed: int = 0,
                    uid_base: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    min_prompt = max(1, min(min_prompt, max_prompt))    # tiny --prefill-len
    t = 0.0
    out: List[Request] = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        length = int(rng.integers(min_prompt, max_prompt + 1))
        prompt = rng.integers(2, vocab_size, length).astype(np.int32)
        out.append(Request(uid=uid_base + i, prompt=prompt,
                           max_new_tokens=max_new_tokens, arrival_time=t))
    return out


def latency_summary(requests: Sequence[Request]) -> Dict[str, float]:
    """p50/p95 of end-to-end latency and time-to-first-token (seconds)."""
    lats = np.asarray([r.latency() for r in requests])
    ttfts = np.asarray([r.ttft() for r in requests])
    return {
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p95_ttft_s": float(np.percentile(ttfts, 95)),
    }
