"""Request lifecycle objects for the serving engine.

A `Request` carries its prompt plus the timing fields the latency benchmark
reads (all times are seconds on the engine's clock, which starts at 0 when
`ServingEngine.run` begins).  `RequestQueue` is a FIFO admission queue gated
on arrival time: a request only becomes visible to the scheduler once the
engine clock passes `arrival_time`, which is how synthetic Poisson traces
inject load mid-decode.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None

    # filled in by the engine
    generated: List[int] = dataclasses.field(default_factory=list)
    t_admitted: float = math.nan         # slot assigned (prefill start)
    t_first_token: float = math.nan
    t_done: float = math.nan
    # engine-clock timestamp of every generated token (t_tokens[0] is the
    # first token) — inter-token-latency percentiles come from the diffs
    t_tokens: List[float] = dataclasses.field(default_factory=list)
    key: object = None                   # per-request PRNG key stream

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def finished(self) -> bool:
        return not math.isnan(self.t_done)

    def latency(self) -> float:
        """Arrival -> last token (what p50/p95 report)."""
        return self.t_done - self.arrival_time

    def ttft(self) -> float:
        """Arrival -> first token (queueing + prefill)."""
        return self.t_first_token - self.arrival_time

    def inter_token_gaps(self) -> List[float]:
        """Seconds between consecutive generated tokens (empty for
        single-token generations or requests not served by the engine)."""
        return [b - a for a, b in zip(self.t_tokens, self.t_tokens[1:])]


class RequestQueue:
    def __init__(self) -> None:
        self._q: Deque[Request] = deque()

    def submit(self, req: Request) -> None:
        if self._q and req.arrival_time < self._q[-1].arrival_time:
            raise ValueError("requests must be submitted in arrival order")
        self._q.append(req)

    def has_ready(self, now: float) -> bool:
        return bool(self._q) and self._q[0].arrival_time <= now

    def peek_ready(self, now: float) -> Optional[Request]:
        """The request the scheduler would admit next, without popping —
        admission gates (free slot AND, when paged, enough free KV pages
        for *this* request) inspect it first."""
        if self.has_ready(now):
            return self._q[0]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        if self.has_ready(now):
            return self._q.popleft()
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival_time if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
