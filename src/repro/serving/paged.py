"""Paged KV-cache layout: block pool + block-table gather/scatter.

The contiguous engine owns one ``(num_slots, cache_len)`` KV row per slot.
This module implements the vLLM-style alternative: the KV store is a shared
pool of ``(num_blocks, block_size)`` pages and each slot carries a *block
table* — ``(num_slots, pages_per_slot)`` physical page ids, where
``pages_per_slot = cache_len // block_size`` — mapping logical page ``j``
(positions ``j*block_size .. (j+1)*block_size-1``) to its physical page.

Because attention in this codebase is *purely position-masked* (cache
``pos`` annotations, -1 = empty; ring order is arbitrary by contract), the
paged layout composes with the existing compiled decode program by
construction:

  * ``gather_caches``   pool + tables -> a contiguous ``(num_slots,
    cache_len)`` cache pytree, bit-identical to what the contiguous engine
    would hold (unallocated table entries point at the sentinel page, whose
    ``pos`` is always -1 and whose K/V are always zeros — exactly the
    untouched tail of a contiguous row).
  * ``scatter_prefill`` a freshly prefilled single-row cache, split into
    pages and written to the request's allocated pages (all-empty tail
    pages land on the sentinel, which keeps its invariant because they are
    all-empty).
  * ``scatter_decode``  after a decode step over the gathered view, the one
    newly written cache entry per slot is copied back to
    ``tables[slot, pos // block_size]`` at offset ``pos % block_size``
    (inactive slots' tables point every entry at the trash page, so their
    garbage writes never land in a mapped page).

All three are pure jax functions traced inside the engine's compiled
programs — the paged engine still compiles exactly one decode shape.

Sliding-window layers keep their per-slot ``(num_slots, window)`` ring
buffers (a ring is already bounded and dense — paging it buys nothing);
only full-``cache_len`` caches page.  ``repro.models.transformer.
cache_seq_lens`` is the source of truth for which is which.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import cache_seq_lens, init_caches
from repro.serving.slots import RESERVED_BLOCKS, SENTINEL_BLOCK, TRASH_BLOCK

__all__ = ["RESERVED_BLOCKS", "SENTINEL_BLOCK", "TRASH_BLOCK",
           "check_paged_geometry", "init_paged_caches", "gather_caches",
           "scatter_prefill", "scatter_decode"]


def check_paged_geometry(cache_len: int, block_size: int,
                         num_blocks: int) -> int:
    """Validate the paged layout and return ``pages_per_slot``."""
    if block_size < 1:
        raise ValueError("block_size must be >= 1")
    if cache_len % block_size:
        raise ValueError(
            f"cache_len {cache_len} must be a multiple of block_size "
            f"{block_size} (logical pages tile the cache exactly)")
    if num_blocks <= RESERVED_BLOCKS:
        raise ValueError(
            f"num_blocks {num_blocks} leaves no allocatable pages "
            f"({RESERVED_BLOCKS} reserved)")
    return cache_len // block_size


def _map_caches(caches: Any, fn: Callable[[Any, int, bool], Any],
                cfg: ModelConfig, cache_len: int) -> Any:
    """Apply ``fn(leaf, batch_axis, paged)`` over a cache pytree.

    Engine caches hold only attention ``{"k","v","pos"}`` dicts (the engine
    rejects rwkv/ssm/enc-dec archs): eager leaves are ``(batch, seq, ...)``
    (batch axis 0), scan-segment leaves are stacked ``(n_layers, batch,
    seq, ...)`` (batch axis 1).  ``paged`` is True when the entry's KV
    length is the full ``cache_len`` (see ``cache_seq_lens``).
    """
    lens = cache_seq_lens(cfg, cache_len)
    is_leaf = lambda x: isinstance(x, tuple)     # zipped (pool, new) pairs
    out = {"eager": {}, "segments": []}
    for idx, c in caches["eager"].items():
        paged = lens["eager"][idx] == cache_len
        out["eager"][idx] = jax.tree.map(
            lambda leaf, p=paged: fn(leaf, 0, p), c, is_leaf=is_leaf)
    for seg, c in zip(lens["segments"], caches["segments"]):
        paged = seg == cache_len
        out["segments"].append(jax.tree.map(
            lambda leaf, p=paged: fn(leaf, 1, p), c, is_leaf=is_leaf))
    return out


def init_paged_caches(cfg: ModelConfig, *, num_slots: int, cache_len: int,
                      block_size: int, num_blocks: int) -> Any:
    """The pool pytree: paged leaves become ``(num_blocks, block_size,
    ...)`` pages (``pos`` pages filled with -1 — the sentinel invariant
    holds from step zero); window leaves keep their per-slot layout."""
    check_paged_geometry(cache_len, block_size, num_blocks)

    def one(leaf, axis, paged):
        if not paged:
            return leaf
        shape = (leaf.shape[:axis] + (num_blocks, block_size)
                 + leaf.shape[axis + 2:])
        if leaf.dtype == jnp.int32:          # the pos annotations
            return jnp.full(shape, -1, jnp.int32)
        return jnp.zeros(shape, leaf.dtype)

    return _map_caches(init_caches(cfg, num_slots, cache_len), one, cfg,
                       cache_len)


def gather_caches(pool: Any, tables: jnp.ndarray, cfg: ModelConfig, *,
                  num_slots: int, cache_len: int, block_size: int) -> Any:
    """pool + ``(num_slots, pages_per_slot)`` tables -> contiguous caches."""
    flat = tables.reshape(-1)                # (num_slots * pages,)

    def one(leaf, axis, paged):
        if not paged:
            return leaf
        g = jnp.take(leaf, flat, axis=axis)  # (.., S*P, bs, ..)
        shape = (leaf.shape[:axis] + (num_slots, cache_len)
                 + leaf.shape[axis + 2:])
        return g.reshape(shape)

    return _map_caches(pool, one, cfg, cache_len)


def scatter_prefill(pool: Any, small: Any, table_row: jnp.ndarray,
                    slot, cfg: ModelConfig, *, cache_len: int,
                    block_size: int) -> Any:
    """Insert a batch=1 prefilled cache into the pool at ``table_row``.

    ``table_row`` is ``(pages_per_slot,)`` physical ids — the request's
    allocated pages followed by SENTINEL_BLOCK entries for the unallocated
    tail.  The whole row is paged and written: allocated pages get the
    prompt's K/V/pos, sentinel entries receive only all-empty pages
    (``pos == -1``, zero K/V — the fresh cache's untouched tail), which is
    what the sentinel already holds.  Window leaves insert at ``slot``
    like the contiguous engine.
    """
    pages = cache_len // block_size

    def one(args, axis, paged):
        big, sm = args
        if not paged:
            return jax.lax.dynamic_update_slice_in_dim(
                big, sm.astype(big.dtype), slot, axis)
        # (.., 1, cache_len, ..) -> (.., pages, block_size, ..)
        shape = (sm.shape[:axis] + (pages, block_size)
                 + sm.shape[axis + 2:])
        paged_sm = sm.reshape(shape).astype(big.dtype)
        if axis == 0:
            return big.at[table_row].set(paged_sm)
        return big.at[:, table_row].set(paged_sm)

    zipped = jax.tree.map(lambda b, s: (b, s), pool, small)
    return _map_caches(zipped, one, cfg, cache_len)


def scatter_decode(pool: Any, new_contig: Any, positions: jnp.ndarray,
                   tables: jnp.ndarray, cfg: ModelConfig, *,
                   cache_len: int, block_size: int) -> Any:
    """Copy each slot's newly written cache entry back into its page.

    ``positions`` is ``(num_slots,)`` — the absolute position each slot's
    decode step just wrote (its input token's position).  Active slots hit
    a page they own by the reservation invariant; inactive slots hit the
    trash page via their all-TRASH table row.  Window leaves were updated
    in place by the decode step and replace the pool leaf directly.
    """
    page = positions // block_size                    # (num_slots,)
    off = positions % block_size
    blk = jnp.take_along_axis(tables, page[:, None], axis=1)[:, 0]

    def one(args, axis, paged):
        big, new = args
        if not paged:
            return new
        # entry written this step: new[.., slot, pos, ..] per slot
        idx = positions.reshape((1,) * axis + (-1, 1)
                                + (1,) * (new.ndim - axis - 2))
        ent = jnp.take_along_axis(new, idx, axis=axis + 1)
        ent = jnp.squeeze(ent, axis=axis + 1).astype(big.dtype)
        if axis == 0:
            return big.at[blk, off].set(ent)
        return big.at[:, blk, off].set(ent)

    zipped = jax.tree.map(lambda b, n: (b, n), pool, new_contig)
    return _map_caches(zipped, one, cfg, cache_len)
