"""The serving engine as a registry kernel: ``serving.engine``.

Unlike every other registry entry, the "kernel" here is a host-side driver
loop (prefill/decode dispatch, slot scheduling, KV-cache bookkeeping), not a
single jax function — so the kernel is registered with
``jaxpr_traceable=False`` and the static auditor's jaxpr passes skip it.
What conformance CAN check — and the contract engine v2 must keep — is the
end-to-end token stream:

  * ``unbatched`` (oracle) — each request of a fixed deterministic trace
    decoded alone through ``training.serve_step.generate``;
  * ``engine_contiguous`` — the synchronous engine loop, v1 contiguous
    (num_slots, cache_len) KV rows;
  * ``engine_paged``      — the synchronous loop over the paged KV pool +
    block tables (serving/paged.py);
  * ``engine_threaded``   — the threaded producer/consumer loop
    (``run_threaded``) over the paged layout.

All three engine backends must reproduce the oracle's greedy tokens
BITWISE (`ORACLE_TOL["serving.engine"] = "bitwise"`): continuous batching,
the cache layout, and the driver threading are scheduling concerns that may
never change a single sampled token.  Every backend builds its own engine
and its own fresh trace (engines mutate Request objects in place).

The trace exercises the paged admission gate (six requests through two
slots, prompts spanning both prefill buckets) and the bucket ladder (two
compiled prefill shapes).
"""

from __future__ import annotations

from typing import Any, List, Tuple

import numpy as np

from repro.core.portable import register_kernel

ARCH = "granite-3-8b"
NUM_SLOTS = 2
CACHE_LEN = 32
PREFILL_BUCKETS = (8, 16)
BLOCK_SIZE = 8
MAX_NEW = 4
PROMPT_LENS = (3, 9, 12, 5, 16, 1)


def conformance_trace(cfg) -> List[Any]:
    """Fresh deterministic request trace (engines mutate requests)."""
    from repro.serving.request import Request
    rng = np.random.default_rng(42)
    return [
        Request(uid=i,
                prompt=rng.integers(2, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=MAX_NEW, arrival_time=0.0)
        for i, L in enumerate(PROMPT_LENS)]


def case_args() -> Tuple[Any, Any]:
    """(params, cfg) for the conformance case — smoke-sized weights."""
    import jax
    from repro.configs import get_config
    from repro.models.transformer import init_params
    cfg = get_config(ARCH, smoke=True)
    return init_params(cfg, jax.random.PRNGKey(0)), cfg


def _tokens(finished, n_expected: int) -> np.ndarray:
    if len(finished) != n_expected:
        raise AssertionError(
            f"engine drained {len(finished)}/{n_expected} requests")
    rows = [r.generated for r in sorted(finished, key=lambda r: r.uid)]
    return np.asarray(rows, np.int32)          # (n_requests, MAX_NEW)


def _unbatched(params, cfg) -> np.ndarray:
    from repro.training.serve_step import generate
    rows = []
    for r in conformance_trace(cfg):
        toks = generate(params, cfg, r.prompt[None, :],
                        max_new_tokens=MAX_NEW, cache_len=CACHE_LEN)
        rows.append(np.asarray(toks)[0])
    return np.asarray(rows, np.int32)


def _run_engine(params, cfg, *, cache_layout: str,
                threaded: bool = False) -> np.ndarray:
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(params, cfg, num_slots=NUM_SLOTS,
                        cache_len=CACHE_LEN,
                        prefill_buckets=PREFILL_BUCKETS,
                        cache_layout=cache_layout, block_size=BLOCK_SIZE)
    trace = conformance_trace(cfg)
    finished = eng.run_threaded(trace) if threaded else eng.run(trace)
    return _tokens(finished, len(trace))


kernel = register_kernel(
    "serving.engine", oracle="unbatched", jaxpr_traceable=False,
    doc="continuous-batching serving engine — greedy token streams must "
        "bit-match unbatched decode across cache layouts and driver loops")
kernel.add_backend("unbatched", _unbatched)
kernel.add_backend(
    "engine_contiguous",
    lambda params, cfg: _run_engine(params, cfg, cache_layout="contiguous"))
kernel.add_backend(
    "engine_paged",
    lambda params, cfg: _run_engine(params, cfg, cache_layout="paged"))
kernel.add_backend(
    "engine_threaded",
    lambda params, cfg: _run_engine(params, cfg, cache_layout="paged",
                                    threaded=True))
