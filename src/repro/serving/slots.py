"""Slot + KV-block allocators for the continuous-batching engine.

Two granularities of cache ownership:

  * ``SlotAllocator`` — a slot is one *batch row* of the engine's decode
    program.  Requests borrow a slot for their whole lifetime (prefill
    through last decode step) and return it on completion; the allocator is
    a plain free heap — lowest id first, so rows are reused densely.  A
    set shadows the heap so double-free detection is O(1) instead of an
    O(n) heap scan.

  * ``BlockAllocator`` — engine v2's paged KV layout (vLLM idiom): the KV
    cache is a shared pool of ``(num_blocks, block_size)`` pages and each
    request owns just the pages its positions actually need
    (``ceil((prompt_len + max_new - 1) / block_size)``), recorded in a
    per-slot *block table*.  Long and short requests share the pool without
    per-row padding waste, and admission is gated on free pages rather
    than a whole ``cache_len`` row.

    Two physical blocks are reserved and never enter the free pool:

      - ``SENTINEL_BLOCK`` (0): every *unallocated* block-table entry points
        here.  Its position annotations are always -1 ("empty" to the
        position-masked attention), so gathering an unallocated page
        contributes nothing to any request's attention.  The only writes it
        ever receives are the all-empty tail pages of a fresh prefill
        insert (pos == -1 by construction), so the invariant holds without
        explicit wipes.
      - ``TRASH_BLOCK`` (1): the block table of an *inactive* slot points
        here, so the decode program's unconditional per-slot cache write
        (inactive slots decode garbage whose output is ignored) lands in a
        page no active request ever maps.
"""

from __future__ import annotations

import heapq
from typing import List, Sequence, Set

#: physical page every unallocated block-table entry points at (pos == -1
#: everywhere, so it reads as empty cache); never allocated, never carries
#: a real position.
SENTINEL_BLOCK = 0
#: physical page inactive slots' decode writes land in; never allocated,
#: never mapped by an active request's table row.
TRASH_BLOCK = 1
#: ids below this are reserved (see above) and never enter the free pool
RESERVED_BLOCKS = 2


class SlotAllocator:
    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self._free: List[int] = list(range(num_slots))
        heapq.heapify(self._free)
        self._free_set: Set[int] = set(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = heapq.heappop(self._free)
        self._free_set.discard(slot)
        return slot

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free_set:          # O(1), not an O(n) heap scan
            raise ValueError(f"slot {slot} already free")
        heapq.heappush(self._free, slot)
        self._free_set.add(slot)

    def available(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.num_slots - len(self._free)


class BlockAllocator:
    """Free list over the physical pages of a paged KV pool.

    ``num_blocks`` counts *all* physical pages including the two reserved
    ids; ``capacity()`` is what requests can actually own.  Like
    ``SlotAllocator``, lowest ids first (dense reuse) with a set-backed
    double-free check.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if num_blocks <= RESERVED_BLOCKS:
            raise ValueError(
                f"need more than {RESERVED_BLOCKS} blocks "
                f"({RESERVED_BLOCKS} are reserved), got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(RESERVED_BLOCKS, num_blocks))
        heapq.heapify(self._free)
        self._free_set: Set[int] = set(self._free)

    def blocks_for(self, prompt_len: int, max_new_tokens: int) -> int:
        """Pages a request needs for its whole lifetime.

        Cache entries are written for positions ``0 .. prompt_len +
        max_new_tokens - 2`` (the final sampled token is never written
        back), so ``prompt_len + max_new_tokens - 1`` positions must be
        mapped.
        """
        need = max(1, prompt_len + max_new_tokens - 1)
        return -(-need // self.block_size)

    def alloc(self, n: int) -> List[int]:
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} pages, {len(self._free)} free")
        out = [heapq.heappop(self._free) for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if not RESERVED_BLOCKS <= b < self.num_blocks:
                raise ValueError(f"block {b} out of range or reserved")
            if b in self._free_set:
                raise ValueError(f"block {b} already free")
        for b in blocks:
            heapq.heappush(self._free, b)
            self._free_set.add(b)

    def available(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.capacity() - len(self._free)

    def capacity(self) -> int:
        return self.num_blocks - RESERVED_BLOCKS
