"""Fixed-capacity slot allocator for the continuous-batching KV cache.

A slot is one row of the engine's (num_slots, cache_len) KV cache.  Requests
borrow a slot for their whole lifetime (prefill through last decode step) and
return it on completion; the allocator is a plain free list — lowest id
first, so cache rows are reused densely.
"""

from __future__ import annotations

import heapq
from typing import List


class SlotAllocator:
    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self._free: List[int] = list(range(num_slots))
        heapq.heapify(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        return heapq.heappop(self._free)

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free:
            raise ValueError(f"slot {slot} already free")
        heapq.heappush(self._free, slot)

    def available(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.num_slots - len(self._free)
