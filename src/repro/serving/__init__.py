"""Continuous-batching serving engine (fixed shapes, slot-granular).

Engine v2 adds a paged KV-cache layout (``cache_layout="paged"``: block
pool + per-slot block tables, see ``paged``/``slots``), a prefill bucket
ladder, and a threaded producer/consumer driver loop
(``ServingEngine.run_threaded``).
"""

from repro.serving.engine import JetThread, ServingEngine, scatter_slot_cache
from repro.serving.paged import (check_paged_geometry, gather_caches,
                                 init_paged_caches, scatter_decode,
                                 scatter_prefill)
from repro.serving.request import Request, RequestQueue
from repro.serving.slots import (RESERVED_BLOCKS, SENTINEL_BLOCK, TRASH_BLOCK,
                                 BlockAllocator, SlotAllocator)
from repro.serving.trace import latency_summary, synthetic_trace

__all__ = ["ServingEngine", "JetThread", "scatter_slot_cache", "Request",
           "RequestQueue", "SlotAllocator", "BlockAllocator",
           "SENTINEL_BLOCK", "TRASH_BLOCK", "RESERVED_BLOCKS",
           "check_paged_geometry", "init_paged_caches", "gather_caches",
           "scatter_prefill", "scatter_decode", "latency_summary",
           "synthetic_trace"]
