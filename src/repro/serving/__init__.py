"""Continuous-batching serving engine (fixed shapes, slot-granular)."""

from repro.serving.engine import ServingEngine, scatter_slot_cache
from repro.serving.request import Request, RequestQueue
from repro.serving.slots import SlotAllocator
from repro.serving.trace import latency_summary, synthetic_trace

__all__ = ["ServingEngine", "scatter_slot_cache", "Request", "RequestQueue",
           "SlotAllocator", "latency_summary", "synthetic_trace"]
