"""models subsystem."""
