"""RWKV6 ("Finch") block: data-dependent-decay linear attention.

Three WKV execution paths, all mathematically identical (tested):
  * `wkv_serial`  — exact per-token recurrence (oracle; also the decode step)
  * `wkv_chunked` — sub-quadratic chunked form used for train/prefill:
                    intra-chunk terms use a direct (C,C,Dh) contraction in
                    fp32 (unconditionally stable: every decay exponent in the
                    inter-chunk/matmul parts is <= 0), inter-chunk state flows
                    through a lax.scan
  * kernels/rwkv6 — Pallas-TPU version of the chunked form (registry backend)

Recurrence per head (state S in R^{Dh x Dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = S_{t-1}^T r_t + (r_t . (u ⊙ k_t)) v_t
with w_t = exp(-exp(w_raw_t)) data-dependent (the Finch novelty), u a learned
per-channel bonus.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, apply_norm, dense_init, norm_init

W_RAW_CLAMP = (-8.0, 1.0)   # log-log decay clamp, keeps exp() sane
LORA_RANK = 32
DECAY_LORA_RANK = 64


# --------------------------------------------------------------------------
# WKV core
# --------------------------------------------------------------------------
def wkv_serial(r, k, v, w_logdecay, u, state=None):
    """Exact recurrence. r/k/v/w: (B, H, S, Dh) fp32; u: (H, Dh).

    Returns (y (B,H,S,Dv), final_state (B,H,Dh,Dv)).
    w_logdecay is log(w) = -exp(w_raw) (<= 0).
    """
    b, h, s, dh = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dh, dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, lwt = inp          # (B,H,Dh) each
        y = jnp.einsum("bhd,bhdv->bhv", rt, S) \
            + jnp.einsum("bhd,bhd->bh", rt, u[None] * kt)[..., None] * vt
        S = jnp.exp(lwt)[..., None] * S + kt[..., None] * vt[:, :, None, :]
        return S, y

    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 2, 0), (r, k, v, w_logdecay))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2), state


def wkv_chunked(r, k, v, w_logdecay, u, state=None, chunk: int = 64):
    """Chunked form: O(S*C) intra + O(S/C * Dh*Dv) inter.

    Stability: inter-chunk uses exp(cw - lw_s) and exp(lw_{t-1}) with all
    exponents <= 0; the intra-chunk triangle uses the direct 3-tensor
    contraction exp(lw_{t-1} - lw_s) (s < t) which is also <= 0.
    """
    b, h, s, dh = r.shape
    dv = v.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not a multiple of chunk {chunk}")
    n = s // chunk
    if state is None:
        state = jnp.zeros((b, h, dh, dv), jnp.float32)

    def split(a):
        return a.reshape(b, h, n, chunk, a.shape[-1])

    rc, kc, vc, lwc = split(r), split(k), split(v), split(w_logdecay)
    # lw_cum[t] = sum_{s<=t} log w_s within chunk; (B,H,n,C,Dh)
    lw_cum = jnp.cumsum(lwc, axis=3)
    lw_before = lw_cum - lwc            # sum over s < t  (== lw_{t-1} path)
    cw = lw_cum[:, :, :, -1:, :]        # chunk total decay

    # intra-chunk strict lower triangle: direct contraction.  Valid (s < t)
    # exponents are <= 0 by construction; the (masked-out) s >= t entries
    # are positive and would overflow to inf (inf * 0 = NaN), so clamp.
    # named scope: VMEM-resident in the Pallas WKV kernel (kernels/rwkv6);
    # the roofline's kernel-adjusted mode costs these tiles at zero HBM.
    with jax.named_scope("wkv_tile"):
        expdiff = jnp.exp(jnp.minimum(
            lw_before[:, :, :, :, None, :] - lw_cum[:, :, :, None, :, :],
            0.0))                                       # (B,H,n,C,C,Dh) t,s
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        A = jnp.einsum("bhntd,bhnsd,bhntsd->bhnts", rc, kc, expdiff) \
            * tri[None, None, None]
        # diagonal bonus term
        diag = jnp.einsum("bhntd,bhntd->bhnt", rc,
                          u[None, :, None, None] * kc)
        y_intra = jnp.einsum("bhnts,bhnsv->bhntv", A, vc) \
            + diag[..., None] * vc

    # inter-chunk: scan over chunks carrying the state
    r_dec = rc * jnp.exp(lw_before)                    # decay-to-chunk-start
    k_dec = kc * jnp.exp(cw - lw_cum)                  # decay-to-chunk-end
    chunk_kv = jnp.einsum("bhnsd,bhnsv->bhndv", k_dec, vc)
    chunk_decay = jnp.exp(cw[:, :, :, 0, :])           # (B,H,n,Dh)

    def step(S, inp):
        r_d, ckv, cdec = inp
        y = jnp.einsum("bhtd,bhdv->bhtv", r_d, S)
        S = cdec[..., None] * S + ckv
        return S, y

    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 2, 0),
                      (r_dec, chunk_kv, chunk_decay))
    state, y_inter = jax.lax.scan(step, state, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 2)              # (B,H,n,C,Dv)

    y = (y_intra + y_inter).reshape(b, h, s, dv)
    return y, state


# --------------------------------------------------------------------------
# RWKV6 layer (time mix + channel mix)
# --------------------------------------------------------------------------
def rwkv_layer_init(key, d: int, d_ff: int, n_heads: int, dtype,
                    n_layers_scale: int = 1) -> Params:
    hd = d // n_heads
    ks = jax.random.split(key, 16)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    small = lambda k_, *shape: jax.random.normal(k_, shape, dtype) * 0.02
    return {
        "tm": {  # time mix
            "mu": small(ks[0], 5, d),                       # r,k,v,g,w lerps
            "lora_a": small(ks[1], d, 5 * LORA_RANK),
            "lora_b": small(ks[2], 5, LORA_RANK, d),
            "w0": jnp.full((d,), -1.5, dtype),              # base decay
            "w_a": small(ks[3], d, DECAY_LORA_RANK),
            "w_b": small(ks[4], DECAY_LORA_RANK, d),
            "u": small(ks[5], n_heads, hd),                 # bonus
            "wr": dense_init(ks[6], d, d, dtype),
            "wk": dense_init(ks[7], d, d, dtype),
            "wv": dense_init(ks[8], d, d, dtype),
            "wg": dense_init(ks[9], d, d, dtype),
            "wo": dense_init(ks[10], d, d, dtype, out_scale),
            "ln_x": norm_init(hd, "layernorm", dtype),      # per-head groupnorm
        },
        "cm": {  # channel mix
            "mu_k": small(ks[11], d),
            "mu_r": small(ks[12], d),
            "wk": dense_init(ks[13], d, d_ff, dtype),
            "wv": dense_init(ks[14], d_ff, d, dtype, out_scale),
            "wr": dense_init(ks[15], d, d, dtype),
        },
    }


def _token_shift(x, last):
    """prev-token x; `last` (B,1,D) is the final token of the previous call."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def time_mix_apply(p: Params, x, n_heads: int, *, state=None, last_x=None,
                   chunk: int = 64, use_chunked: bool = True):
    """x (B,S,D). state (B,H,Dh,Dv) or None; last_x (B,1,D) or None (zeros).
    Returns (out, (new_state, new_last_x))."""
    b, s, d = x.shape
    hd = d // n_heads
    if last_x is None:
        last_x = jnp.zeros((b, 1, d), x.dtype)
    xx = _token_shift(x, last_x) - x

    base = x + xx * 0.5
    lor = jnp.tanh(base @ p["lora_a"])                    # (B,S,5R)
    lor = lor.reshape(b, s, 5, LORA_RANK)
    mus = p["mu"][None, None] + jnp.einsum("bsir,ird->bsid", lor, p["lora_b"])
    xr, xk, xv, xg, xw = [x + xx * mus[:, :, i] for i in range(5)]

    r = (xr @ p["wr"]).reshape(b, s, n_heads, hd)
    k = (xk @ p["wk"]).reshape(b, s, n_heads, hd)
    v = (xv @ p["wv"]).reshape(b, s, n_heads, hd)
    g = jax.nn.silu(xg @ p["wg"])

    w_raw = p["w0"][None, None] + jnp.tanh(xw @ p["w_a"]) @ p["w_b"]
    w_raw = jnp.clip(w_raw.astype(jnp.float32), *W_RAW_CLAMP)
    w_logdecay = -jnp.exp(w_raw).reshape(b, s, n_heads, hd)

    to_bhsd = lambda a: jnp.moveaxis(a, 2, 1).astype(jnp.float32)
    rf, kf, vf, lw = map(to_bhsd, (r, k, v, w_logdecay))
    u = p["u"].astype(jnp.float32)
    if use_chunked and s % chunk == 0 and s > 1:
        y, new_state = wkv_chunked(rf, kf, vf, lw, u, state, chunk)
    else:
        y, new_state = wkv_serial(rf, kf, vf, lw, u, state)

    y = jnp.moveaxis(y, 1, 2)                             # (B,S,H,Dv)
    y = apply_norm(p["ln_x"], y.astype(x.dtype), "layernorm")
    y = y.reshape(b, s, d) * g
    out = y @ p["wo"]
    return out, (new_state, x[:, -1:])


def channel_mix_apply(p: Params, x, *, last_x=None):
    b, s, d = x.shape
    if last_x is None:
        last_x = jnp.zeros((b, 1, d), x.dtype)
    xx = _token_shift(x, last_x) - x
    xk = x + xx * p["mu_k"][None, None]
    xr = x + xx * p["mu_r"][None, None]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    return out, x[:, -1:]
