"""Flash-style chunked attention in pure JAX (differentiable).

Structure: a *python-unrolled* loop over query chunks (static chunk count),
each running a `lax.scan` over exactly the key chunks its causal/window mask
can reach (static trip count per query chunk, so FLOPs match the true
triangular cost), with online-softmax accumulation (peak memory
O(q_chunk x k_chunk) per head).  Fully reverse-differentiable — this is the
training path for every sequence >= 2048 and the oracle (`ref.py`) for
kernels/flash_attention.

Assumption (asserted by construction, true for train/prefill): token i of the
q/k tensors holds position base+i — the ring-buffer decode path never routes
here (its q length is 1).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attend_chunked(q, k, v, q_pos, k_pos, *, n_kv_heads: int, causal: bool,
                   window: int = 0, q_chunk: int = 1024,
                   k_chunk: int = 1024, bf16_intermediates: bool = False):
    """Same contract as attention.attend (q (B,S,H,Dh), k/v (B,T,Kv,Dh)).

    bf16_intermediates (beyond-paper lever): keep the (q_chunk x k_chunk)
    logits/probability tiles in bf16 with f32 accumulation — halves the
    attention HBM traffic at <=1e-2 output tolerance (tests).
    """
    b, s, h, dh = q.shape
    t = k.shape[1]
    kv = n_kv_heads
    g = h // kv
    q_chunk = min(q_chunk, s)
    k_chunk = min(k_chunk, t)
    if s % q_chunk or t % k_chunk:
        raise ValueError(f"seq {s}/{t} not divisible by chunks "
                         f"{q_chunk}/{k_chunk}")
    nq, nk = s // q_chunk, t // k_chunk
    scale = 1.0 / math.sqrt(dh)
    io_dtype = jnp.bfloat16 if bf16_intermediates else jnp.float32
    kf, vf = k.astype(io_dtype), v.astype(io_dtype)

    outs = []
    for qi in range(nq):
        q_lo = qi * q_chunk
        qc = q[:, q_lo:q_lo + q_chunk].astype(io_dtype) \
            .reshape(b, q_chunk, kv, g, dh)
        qp = q_pos[:, q_lo:q_lo + q_chunk]

        # static key-chunk range reachable from this query chunk
        hi = min(nk, (q_lo + q_chunk + k_chunk - 1) // k_chunk) if causal \
            else nk
        lo = max(0, (q_lo - (window - 1)) // k_chunk) if window else 0
        n_steps = hi - lo

        m0 = jnp.full((b, q_chunk, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kv, g, dh), jnp.float32)

        def k_body(carry, ki):
            # named scope: ops in here are VMEM-resident in the Pallas flash
            # kernel (kernels/flash_attention); the roofline's kernel-
            # adjusted mode costs them at zero HBM (core/hlo_cost.py).
            with jax.named_scope("attn_tile"):
                m, l, acc = carry
                start = ki * k_chunk
                kb = jax.lax.dynamic_slice_in_dim(kf, start, k_chunk, 1)
                vb = jax.lax.dynamic_slice_in_dim(vf, start, k_chunk, 1)
                kp = jax.lax.dynamic_slice_in_dim(k_pos, start, k_chunk, 1)
                logits = jnp.einsum(
                    "bqkgd,btkd->bqkgt", qc, kb,
                    preferred_element_type=jnp.float32) * scale
                pm = kp[:, None, :] >= 0
                if causal:
                    pm &= kp[:, None, :] <= qp[:, :, None]
                if window:
                    pm &= (qp[:, :, None] - kp[:, None, :]) < window
                logits = jnp.where(pm[:, :, None, None, :], logits, NEG_INF)

                m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
                corr = jnp.exp(m - m_new)
                p = jnp.exp(logits - m_new[..., None]).astype(io_dtype)
                l_new = l * corr + jnp.sum(p, axis=-1,
                                           dtype=jnp.float32)
                acc_new = acc * corr[..., None] \
                    + jnp.einsum("bqkgt,btkd->bqkgd", p, vb,
                                 preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), jnp.arange(lo, hi, dtype=jnp.int32))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.reshape(b, q_chunk, h, dh))

    return jnp.concatenate(outs, axis=1).astype(q.dtype)
