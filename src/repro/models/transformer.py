"""Model assembly: decoder-only / encoder-decoder LMs over a *layer plan*.

Layers are executed through a plan of segments:

    [("eager", idx), ("scan", [lo, hi)), ...]

Homogeneous runs of layers are stacked (leading dim = run length) and driven
by `lax.scan` — HLO stays O(1) in depth (95-layer models compile in seconds)
and the stacked layout is the canonical pipeline-parallel unit.  Layers that
differ structurally (deepseek-moe's dense first layer, hymba's three
global-attention layers whose KV cache is full-length instead of
sliding-window) run eagerly with their own parameters.

Everything is pure-functional: `init_params` -> pytree, `forward` /
`decode_step` are jit-able functions of (params, batch).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (Params, apply_mlp, apply_norm, cast_tree,
                                 dense_init, embed_init, mlp_init, norm_init)


# --------------------------------------------------------------------------
# sharding hints (kept abstract so models never import mesh machinery)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardingHints:
    """Optional with_sharding_constraint points; no-op by default."""

    activation: Callable[[jnp.ndarray], jnp.ndarray] = lambda x: x
    logits: Callable[[jnp.ndarray], jnp.ndarray] = lambda x: x
    # ZeRO-1 lever: constrain a (bf16) copy of the params to TP-only sharding
    # (data/pod axes stripped) so the FSDP gather happens once per step
    params_compute: Callable[[Any], Any] = lambda tree: tree
    # MoE expert-parallel guidance: constrain (G,E,C,D) expert buffers /
    # (G,gs,E,C) dispatch tensors so GSPMD lowers to all-to-all instead of
    # replicating (kind: "gecd" | "gtec")
    moe_constraint: Callable[[jnp.ndarray, str], jnp.ndarray] = \
        lambda x, kind: x


NO_HINTS = ShardingHints()


# --------------------------------------------------------------------------
# layer plan
# --------------------------------------------------------------------------
def eager_layer_ids(cfg: ModelConfig) -> Tuple[int, ...]:
    ids = set()
    if cfg.is_moe and cfg.dense_prefix_layers:
        ids.update(range(cfg.dense_prefix_layers))
    ids.update(cfg.global_layers)
    return tuple(sorted(ids))


def layer_plan(cfg: ModelConfig) -> List[Tuple[str, Any]]:
    eager = eager_layer_ids(cfg)
    plan: List[Tuple[str, Any]] = []
    lo = 0
    for e in eager:
        if e > lo:
            plan.append(("scan", (lo, e)))
        plan.append(("eager", e))
        lo = e + 1
    if lo < cfg.n_layers:
        plan.append(("scan", (lo, cfg.n_layers)))
    return plan


def layer_kind(cfg: ModelConfig, idx: int) -> Dict[str, Any]:
    """Structural description of layer `idx`."""
    is_global = idx in cfg.global_layers
    use_moe = cfg.is_moe and idx >= cfg.dense_prefix_layers
    window = 0 if (is_global or not cfg.window) else cfg.window
    return {"moe": use_moe, "window": window,
            "cross": cfg.is_encoder_decoder, "rwkv": cfg.rwkv,
            "ssm": cfg.ssm_state > 0}


# --------------------------------------------------------------------------
# single decoder layer
# --------------------------------------------------------------------------
def layer_init(key, cfg: ModelConfig, idx: int, *, encoder: bool = False
               ) -> Params:
    kind = layer_kind(cfg, idx)
    d, dt = cfg.d_model, cfg.pdtype()
    ks = jax.random.split(key, 10)
    if kind["rwkv"] and not encoder:
        n_heads = d // 64
        return rwkv_mod.rwkv_layer_init(ks[0], d, cfg.d_ff, n_heads, dt,
                                        cfg.n_layers)
    p: Params = {
        "ln1": norm_init(d, cfg.norm, dt),
        "attn": attn.attention_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, dt, cfg.n_layers),
        "ln2": norm_init(d, cfg.norm, dt),
    }
    if kind["moe"] and not encoder:
        p["moe"] = moe_mod.moe_init(ks[1], d, cfg.d_ff, cfg.n_experts,
                                    cfg.n_shared_experts, cfg.mlp, dt,
                                    cfg.n_layers)
    else:
        ff = cfg.dense_ff() if not encoder else cfg.d_ff
        p["mlp"] = mlp_init(ks[1], d, ff, cfg.mlp, dt, cfg.n_layers)
    if kind["ssm"] and not encoder:
        p["ssm"] = ssm_mod.ssm_init(ks[2], d, cfg.n_heads * cfg.head_dim,
                                    cfg.ssm_state, dt, cfg.n_layers)
        p["ln_attn_br"] = norm_init(d, cfg.norm, dt)
        p["ln_ssm_br"] = norm_init(d, cfg.norm, dt)
    if kind["cross"] and not encoder:
        p["ln_cross"] = norm_init(d, cfg.norm, dt)
        p["cross"] = attn.attention_init(ks[3], d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim, dt,
                                         cfg.n_layers)
    return p


def layer_apply(p: Params, x, cfg: ModelConfig, kind: Dict[str, Any], *,
                positions, cache=None, memory=None, memory_pos=None,
                hints: ShardingHints = NO_HINTS, encoder: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind["rwkv"] and not encoder:
        st = cache or {}
        n_heads = cfg.d_model // 64
        h, (wkv, tm_last) = rwkv_mod.time_mix_apply(
            p["tm"], apply_norm(p["ln_tm"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul), n_heads,
            state=st.get("wkv"), last_x=st.get("tm_last"),
            use_chunked=x.shape[1] > 1)
        x = hints.activation(x + h)
        h2, cm_last = rwkv_mod.channel_mix_apply(
            p["cm"], apply_norm(p["ln_cm"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul),
            last_x=st.get("cm_last"))
        x = hints.activation(x + h2)
        new_cache = {"wkv": wkv, "tm_last": tm_last, "cm_last": cm_last} \
            if cache is not None else None
        return x, new_cache, aux

    cache = cache or {}
    h = apply_norm(p["ln1"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul)
    attn_kwargs = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                       head_dim=cfg.head_dim, positions=positions,
                       use_rope=cfg.use_rope, rope_theta=cfg.rope_theta,
                       causal=not encoder, window=kind["window"],
                       bf16_intermediates=cfg.attn_bf16_intermediates,
                       backend=cfg.attn_backend)
    a_out, new_kv = attn.attention_apply(p["attn"], h,
                                         cache=cache.get("self"),
                                         **attn_kwargs)
    new_cache: Dict[str, Any] = {}
    if cache.get("self") is not None:
        new_cache["self"] = new_kv

    if kind["ssm"] and not encoder:
        s_out, (ssm_state, conv_state) = ssm_mod.ssm_apply(
            p["ssm"], h, state=cache.get("ssm"), conv_state=cache.get("conv"))
        # hymba fusion: mean of the two normalized branch outputs
        a_out = 0.5 * (apply_norm(p["ln_attn_br"], a_out, cfg.norm, bf16_mul=cfg.norm_bf16_mul)
                       + apply_norm(p["ln_ssm_br"], s_out, cfg.norm, bf16_mul=cfg.norm_bf16_mul))
        if "ssm" in cache or cache.get("self") is not None:
            new_cache["ssm"] = ssm_state
            new_cache["conv"] = conv_state
    x = hints.activation(x + a_out)

    if kind["cross"] and not encoder and memory is not None:
        h = apply_norm(p["ln_cross"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul)
        # project cross K/V from raw encoder memory (cheap: memory is the
        # short stub-frontend sequence; a K/V cache here is a noted opt.)
        mk, mv = attn.cross_kv(p["cross"], memory, cfg.n_kv_heads,
                               cfg.head_dim)
        c_out, _ = attn.attention_apply(
            p["cross"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions, causal=False,
            use_rope=False, memory_kv=(mk, mv), memory_pos=memory_pos,
            backend=cfg.attn_backend)
        x = hints.activation(x + c_out)

    h = apply_norm(p["ln2"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul)
    if kind["moe"] and not encoder:
        m_out, aux = moe_mod.moe_apply(
            p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
            mlp_kind=cfg.mlp, capacity_factor=cfg.moe_capacity_factor,
            stopgrad_dispatch=cfg.moe_stopgrad_dispatch,
            constraint=hints.moe_constraint)
    else:
        m_out = apply_mlp(p["mlp"], h, cfg.mlp)
    x = hints.activation(x + m_out)
    return x, (new_cache if new_cache else None), aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Params:
    dt = cfg.pdtype()
    keys = jax.random.split(key, cfg.n_layers + cfg.n_encoder_layers + 4)
    # tables padded to cfg.padded_vocab: keeps the vocab dim shardable on
    # every mesh (padded logit columns are masked to -inf in forward)
    params: Params = {"embed": embed_init(keys[0], cfg.padded_vocab,
                                          cfg.d_model, dt),
                      "final_norm": norm_init(cfg.d_model, cfg.norm, dt)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model,
                                       cfg.padded_vocab, dt)
    # rwkv needs explicit pre-norms stored with the block
    def one_layer(i):
        p = layer_init(keys[2 + i], cfg, i)
        if cfg.rwkv:
            p["ln_tm"] = norm_init(cfg.d_model, cfg.norm, dt)
            p["ln_cm"] = norm_init(cfg.d_model, cfg.norm, dt)
        return p

    plan = layer_plan(cfg)
    params["eager"] = {str(i): one_layer(i)
                       for kind, i in plan if kind == "eager"}
    params["segments"] = []
    for kind, rng_ in plan:
        if kind != "scan":
            continue
        lo, hi = rng_
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[one_layer(i) for i in range(lo, hi)])
        params["segments"].append(stacked)

    if cfg.is_encoder_decoder:
        ek = jax.random.split(keys[-1], cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[layer_init(ek[i], cfg, i, encoder=True)
                  for i in range(cfg.n_encoder_layers)]),
            "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
        }
    return params


def leftpad_positions(lengths: jnp.ndarray, seq_len: int) -> jnp.ndarray:
    """Positions for left-padded prompts: (B,) true lengths -> (B, S).

    Pad tokens get position -1, which the position-based attention mask
    treats as "empty" (`k_pos >= 0` fails): pad keys are never attended, pad
    queries produce garbage that callers must ignore, and the KV-cache write
    for a pad is dropped entirely (attention.attention_apply routes
    position < 0 out of bounds with scatter mode="drop", so pads cannot
    clobber a real slot even on sliding-window ring buffers).  Real tokens
    get positions 0..L-1 so downstream decode continues at position L.
    """
    idx = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    pos = idx - (seq_len - lengths.astype(jnp.int32))[:, None]
    return jnp.where(pos >= 0, pos, -1)


def _sinusoidal(positions, d):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _run_layers(params: Params, x, cfg: ModelConfig, *, positions,
                caches=None, memory=None, memory_pos=None,
                hints: ShardingHints = NO_HINTS, remat: bool = False):
    """Execute the layer plan. caches: {"eager": {id: c}, "segments": [c]}."""
    plan = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"eager": {}, "segments": []} if caches is not None else None
    seg_i = 0
    for kind_tag, arg in plan:
        if kind_tag == "eager":
            idx = arg
            kind = layer_kind(cfg, idx)
            c = caches["eager"].get(str(idx)) if caches is not None else None
            fn = partial(layer_apply, cfg=cfg, kind=kind, hints=hints)
            if remat:
                fn = jax.checkpoint(fn, static_argnums=())
            lp = cast_tree(params["eager"][str(idx)], cfg.cdtype())
            x, nc, aux = fn(lp, x, positions=positions, cache=c,
                            memory=memory, memory_pos=memory_pos)
            aux_total += aux
            if new_caches is not None:
                new_caches["eager"][str(idx)] = nc
        else:
            lo, hi = arg
            kind = layer_kind(cfg, lo)  # homogeneous within a segment
            seg_params = params["segments"][seg_i]
            seg_cache = caches["segments"][seg_i] if caches is not None \
                else None

            # Caches ride the scan CARRY and are updated in place with
            # dynamic_update_index — XLA aliases while-loop carries, so the
            # decode path pays 1x cache memory instead of the 2x an
            # xs->ys stacked cache would cost.
            def body(carry, xs):
                h, aux_acc, cbuf = carry
                lp, idx = xs
                lc = None if cbuf is None else jax.tree.map(
                    lambda b: jax.lax.dynamic_index_in_dim(
                        b, idx, 0, keepdims=False), cbuf)

                def inner(lp_, h_, lc_):
                    return layer_apply(cast_tree(lp_, cfg.cdtype()), h_,
                                       cfg=cfg, kind=kind,
                                       positions=positions, cache=lc_,
                                       memory=memory, memory_pos=memory_pos,
                                       hints=hints)
                if remat:
                    inner = jax.checkpoint(inner)
                h, nc, aux = inner(lp, h, lc)
                if cbuf is not None:
                    cbuf = jax.tree.map(
                        lambda b, n: jax.lax.dynamic_update_index_in_dim(
                            b, n.astype(b.dtype), idx, 0), cbuf, nc)
                return (h, aux_acc + aux, cbuf), None

            n_seg = hi - lo
            (x, aux_total, seg_new), _ = jax.lax.scan(
                body, (x, aux_total, seg_cache),
                (seg_params, jnp.arange(n_seg, dtype=jnp.int32)))
            if new_caches is not None:
                new_caches["segments"].append(seg_new)
            seg_i += 1
    return x, new_caches, aux_total


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
           hints: ShardingHints = NO_HINTS):
    """Whisper-style encoder over stub frame embeddings (B, T, D)."""
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = frames.astype(cfg.cdtype()) \
        + _sinusoidal(pos, cfg.d_model).astype(cfg.cdtype())
    enc = params["encoder"]
    kind = {"moe": False, "window": 0, "cross": False, "rwkv": False,
            "ssm": False}

    def body(h, lp):
        h, _, _ = layer_apply(cast_tree(lp, cfg.cdtype()), h, cfg=cfg,
                              kind=kind, positions=pos, hints=hints,
                              encoder=True)
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul), pos


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            positions=None, caches=None, frames=None, patches=None,
            memory=None, hints: ShardingHints = NO_HINTS,
            remat: bool = False, last_only: bool = False, lengths=None,
            attn_backend: Optional[str] = None):
    """Full forward. tokens (B, S) -> logits (B, S, V), caches', aux.

    frames: (B, T, D) stub audio frontend output (enc-dec archs).
    patches: (B, P, D) stub vision frontend output (vlm archs; added to the
    first P token positions — early fusion).
    memory: precomputed encoder output (decode steps skip re-encoding).
    last_only: project logits for the final position only (prefill serving —
    avoids materializing the (B, S, V) tensor).
    lengths: (B,) true prompt lengths for left-padded batched prefill; pads
    are masked out of attention via position -1 (see leftpad_positions).
    Ignored when explicit positions are given.
    attn_backend: per-call override of cfg.attn_backend (registry attention
    backend; see models/attention.resolve_attention_backend).
    """
    if attn_backend is not None:
        cfg = dataclasses.replace(cfg, attn_backend=attn_backend)
    cdt = cfg.cdtype()
    b, s = tokens.shape
    if positions is None:
        if lengths is not None:
            positions = leftpad_positions(lengths, s)
        else:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(cdt)[tokens]
    if patches is not None:
        p_len = patches.shape[1]
        x = x.at[:, :p_len].add(patches.astype(cdt))
    if not cfg.use_rope and not cfg.rwkv:
        x = x + _sinusoidal(positions, cfg.d_model).astype(cdt)
    x = hints.activation(x)

    memory_pos = None
    if cfg.is_encoder_decoder:
        if memory is None:
            if frames is None:
                raise ValueError("enc-dec model requires `frames` or `memory`")
            memory, memory_pos = encode(params, cfg, frames, hints)
        else:
            t = memory.shape[1]
            memory_pos = jnp.broadcast_to(
                jnp.arange(t, dtype=jnp.int32)[None], (memory.shape[0], t))
    else:
        memory = None

    x, new_caches, aux = _run_layers(
        params, x, cfg, positions=positions, caches=caches,
        memory=memory, memory_pos=memory_pos, hints=hints, remat=remat)

    x = apply_norm(params["final_norm"], x, cfg.norm, bf16_mul=cfg.norm_bf16_mul)
    if last_only:
        x = x[:, -1:]
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = hints.logits(x @ unembed.astype(cdt))
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e9, logits.dtype))
    return logits, new_caches, aux


def cache_seq_lens(cfg: ModelConfig, seq_len: int) -> Dict[str, Any]:
    """Per-plan-entry KV sequence lengths of ``init_caches(cfg, _, seq_len)``.

    Mirrors the layer plan: ``{"eager": {id: len}, "segments": [len]}``.
    A sliding-window layer's ring buffer is ``min(window, seq_len)`` long;
    everything else stores the full ``seq_len``.  The paged serving layout
    (``repro.serving.paged``) uses this to decide which cache entries page
    into the shared block pool (full-length) and which stay per-slot
    (bounded rings shorter than ``seq_len``).
    """
    def one(idx: int) -> int:
        kind = layer_kind(cfg, idx)
        return min(kind["window"], seq_len) if kind["window"] else seq_len

    out: Dict[str, Any] = {"eager": {}, "segments": []}
    for tag, arg in layer_plan(cfg):
        if tag == "eager":
            out["eager"][str(arg)] = one(arg)
        else:
            out["segments"].append(one(arg[0]))  # homogeneous segment
    return out


def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    """Decode caches per the layer plan (ring buffers for SWA layers)."""
    cdt = cfg.cdtype()
    plan = layer_plan(cfg)

    def one(idx):
        kind = layer_kind(cfg, idx)
        if kind["rwkv"]:
            h = cfg.d_model // 64
            return {"wkv": jnp.zeros((batch, h, 64, 64), jnp.float32),
                    "tm_last": jnp.zeros((batch, 1, cfg.d_model), cdt),
                    "cm_last": jnp.zeros((batch, 1, cfg.d_model), cdt)}
        cache_len = min(kind["window"], seq_len) if kind["window"] \
            else seq_len
        c = {"self": attn.init_cache(batch, cache_len, cfg.n_kv_heads,
                                     cfg.head_dim, cdt)}
        if kind["ssm"]:
            di = cfg.n_heads * cfg.head_dim
            c["ssm"] = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
            c["conv"] = jnp.zeros((batch, ssm_mod.CONV_WIDTH - 1, di), cdt)
        return c

    caches = {"eager": {}, "segments": []}
    for tag, arg in plan:
        if tag == "eager":
            caches["eager"][str(arg)] = one(arg)
        else:
            lo, hi = arg
            caches["segments"].append(
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[one(i) for i in range(lo, hi)]))
    return caches
