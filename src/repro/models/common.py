"""Shared building blocks: norms, RoPE, MLPs, initializers.

Pure-functional style: params are pytrees of jnp arrays; every module is an
(init, apply) pair.  Norm/softmax accumulate in fp32 regardless of the
compute dtype (bf16), per standard large-model numerics.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(
        std, dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return jax.random.normal(key, (vocab, d), dtype) * jnp.asarray(0.02, dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6,
               bf16_mul: bool = False) -> jnp.ndarray:
    """Norm with fp32 reductions.

    bf16_mul (beyond-paper lever): keep the elementwise path in the compute
    dtype — only the (tiny) reduction statistics are fp32.  Besides halving
    the norm's own traffic, the nonlinear fp32 square stops XLA SPMD from
    sinking upstream TP all-reduces past the fp32 upcast (measured 2x
    all-reduce bytes in the baseline; EXPERIMENTS.md §Perf).
    """
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        if bf16_mul:
            out = x * rms.astype(x.dtype) * p["scale"].astype(x.dtype)
            return out
        out = xf * rms * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        if bf16_mul:
            inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
            out = (x - mu.astype(x.dtype)) * inv \
                * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
            return out
        out = (xf - mu) * jax.lax.rsqrt(var + eps) \
            * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE (GPT-NeoX half-rotation convention)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (Dh/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, kind: str, dtype,
             n_layers_scale: int = 1) -> Params:
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    if kind == "swiglu":
        return {"w_gate": dense_init(ks[0], d, d_ff, dtype),
                "w_up": dense_init(ks[1], d, d_ff, dtype),
                "w_down": dense_init(ks[2], d_ff, d, dtype, out_scale)}
    if kind == "gelu":
        return {"w_up": dense_init(ks[0], d, d_ff, dtype),
                "w_down": dense_init(ks[1], d_ff, d, dtype, out_scale)}
    raise ValueError(f"unknown mlp {kind!r}")


def apply_mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------
def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def count_params(tree) -> int:
    return sum(int(a.size) for a in jax.tree.leaves(tree))
