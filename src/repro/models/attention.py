"""GQA attention: full / sliding-window / cross, prefill + ring-buffer decode.

Masks are position-based: the KV cache carries the absolute position of every
slot (-1 = empty), so full caches and sliding-window ring buffers share one
code path.  Softmax accumulates in fp32.

Since PR 6 this module is a *registry consumer*: ``attend`` is a dispatcher
that routes prefill-shaped calls to the ``attention.flash`` portable kernel
and single-query decode calls to ``attention.decode`` (see
``kernels/flash_attention/ops.py``), with backend selection via the
``REPRO_ATTN_BACKEND`` env var or an explicit ``backend=`` argument
(``ModelConfig.attn_backend`` threads it here), availability fallback past
unavailable Pallas backends, and tuned block sizes injected from the
persistent tuning cache.  The plain-XLA math lives in ``attend_xla`` — the
registry oracle for both kernel entries, and the path every call takes when
no backend is requested, so training and default serving are bitwise
unchanged.

Dispatch happens at trace time (all decisions are static on shapes/flags),
and each routing decision lands in a bounded dispatch stream so benchmarks
can report *which* backend and tuning provenance a timed program actually
used (``reset_dispatch_log`` / ``dispatch_log`` for the last decision per
kind, ``dispatch_records`` for the full history) — and, when telemetry is
enabled, as ``attn.dispatch`` events on the shared trace.

Soundness contract for the Pallas prefill route: positions must be
index-aligned up to a non-negative per-row left-pad offset (``pos[i] <= i``,
real tokens contiguous, -1 pads) — exactly what ``leftpad_positions`` and
training's ``arange`` produce.  ``attention_apply`` clears the
``k_index_aligned`` hint whenever the KV ring buffer can wrap
(``cache_len < s``) or cross-attention memory carries arbitrary positions,
and the dispatcher then keeps those calls on the XLA path.
"""

from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import telemetry as tel
from repro.models.common import Params, apply_rope, dense_init

NEG_INF = -1e30

ATTN_BACKEND_ENV = "REPRO_ATTN_BACKEND"

#: dispatcher kind -> registry kernel name
ATTN_KERNELS = {"prefill": "attention.flash", "decode": "attention.decode"}


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, n_layers_scale: int = 1) -> Params:
    ks = jax.random.split(key, 4)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype,
                         out_scale),
    }


def init_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
               dtype) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def _gqa_scores(q, k, n_kv_heads):
    """q (B,S,H,Dh), k (B,T,Kv,Dh) -> (B,Kv,G,S,T) fp32 logits."""
    b, s, h, dh = q.shape
    g = h // n_kv_heads
    qg = q.reshape(b, s, n_kv_heads, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    return logits * (1.0 / math.sqrt(dh))


def _gqa_combine(weights, v):
    """weights (B,Kv,G,S,T), v (B,T,Kv,Dh) -> (B,S,H,Dh)."""
    b, kv, g, s, t = weights.shape
    # keep v in its storage dtype; accumulate in f32 (avoids materializing
    # an f32 copy of the full KV cache on the decode path)
    out = jnp.einsum("bkgst,btkd->bskgd", weights.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, kv * g, v.shape[-1])


def _masked_softmax(logits, mask):
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - jax.lax.stop_gradient(m))
    return e / jnp.sum(e, axis=-1, keepdims=True)


CHUNKED_THRESHOLD = 2048  # use flash-style path when S_q * T is large


def attend_xla(q, k, v, q_pos, k_pos, *, n_kv_heads: int, causal: bool,
               window: int = 0,
               bf16_intermediates: bool = False) -> jnp.ndarray:
    """Plain-XLA position-masked GQA attention — the registry oracle.

    q (B,S,H,Dh), k/v (B,T,Kv,Dh); q_pos (B,S) / k_pos (B,T) absolute
    positions, k_pos == -1 marks empty cache slots.  window > 0 additionally
    restricts to q_pos - k_pos < window.  Long sequences dispatch to the
    flash-style chunked path automatically.
    """
    s, t = q.shape[1], k.shape[1]
    if s >= CHUNKED_THRESHOLD and t >= CHUNKED_THRESHOLD \
            and s % 512 == 0 and t % 1024 == 0:
        from repro.models.chunked_attention import attend_chunked
        return attend_chunked(q, k, v, q_pos, k_pos, n_kv_heads=n_kv_heads,
                              causal=causal, window=window,
                              bf16_intermediates=bf16_intermediates)
    logits = _gqa_scores(q, k, n_kv_heads)              # (B,Kv,G,S,T)
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    weights = _masked_softmax(logits, mask)
    return _gqa_combine(weights, v).astype(q.dtype)


# --------------------------------------------------------------------------
# registry dispatch
# --------------------------------------------------------------------------
#: how many routing decisions the bounded dispatch stream retains.  The
#: pre-PR-8 log was a dict keyed only by kind — concurrent engines or
#: repeated per-backend benchmark rows silently overwrote each other's
#: records; the stream keeps the full recent history (oldest evicted).
DISPATCH_LOG_CAP = 256

_DISPATCH_RECORDS = tel.RingLog(capacity=DISPATCH_LOG_CAP)


def reset_dispatch_log() -> None:
    """Clear the trace-time routing record (call before (re)compiling the
    program whose dispatch you want to observe)."""
    _DISPATCH_RECORDS.clear()


def dispatch_log() -> Dict[str, Dict[str, Any]]:
    """Snapshot of the *last* routing decision per dispatch kind
    (``"prefill"`` / ``"decode"``): resolved backend, tuning provenance
    (``"exhaustive"`` / ``"coordinate"`` / ``"miss-default"``), injected
    params, and the reason when a Pallas route fell back to XLA.

    Populated at *trace* time: a jit cache hit re-runs no dispatch and
    leaves the log untouched.  This is the last-decision-per-kind view the
    benchmark rows read; the full bounded history (every decision, in
    order, across engines/backends) is :func:`dispatch_records`.
    """
    out: Dict[str, Dict[str, Any]] = {}
    for rec in _DISPATCH_RECORDS.records():
        fields = dict(rec)
        out[fields.pop("kind")] = fields
    return out


def dispatch_records() -> list:
    """The full bounded dispatch stream, oldest first: each record carries
    ``kind`` plus the fields of :func:`dispatch_log`.  Survives the
    last-write-wins collapse — two engines tracing concurrently, or one
    benchmark tracing per-backend rows back to back, each keep their
    entries (up to ``DISPATCH_LOG_CAP``)."""
    return _DISPATCH_RECORDS.records()


def _log(kind: str, **fields: Any) -> None:
    _DISPATCH_RECORDS.append({"kind": kind, **fields})
    tel.instant("attn.dispatch", proc="dispatch", kind=kind, **fields)
    tel.counter(f"attn.dispatch.{kind}.{fields.get('backend', '?')}",
                proc="dispatch")
    if "fallback" in fields:
        tel.counter("attn.dispatch.fallback", proc="dispatch")


def _requested_backend(backend: Optional[str]) -> Optional[str]:
    """Explicit request for this call: env var wins over the argument;
    ``None`` / ``""`` / ``"auto"`` mean "no request" (XLA status quo)."""
    env = os.environ.get(ATTN_BACKEND_ENV, "").strip()
    if env and env.lower() != "auto":
        return env
    if backend is None or backend in ("", "auto"):
        return None
    return backend


def _get_kernel(kind: str):
    """Registry entry for one dispatch kind, importing ``repro.kernels``
    lazily (ops.py imports this module for its oracles — the registry can
    only be consulted after both are loaded)."""
    from repro.core.portable import registry
    import repro.kernels  # noqa: F401  (side effect: registers kernels)
    name = ATTN_KERNELS[kind]
    return registry.get(name) if name in registry else None


def resolve_attention_backend(kind: str,
                              backend: Optional[str] = None) -> str:
    """Resolve the attention backend for one dispatch kind.

    Precedence: ``REPRO_ATTN_BACKEND`` env var > explicit ``backend``
    argument > ``"xla"`` (the status-quo plain-XLA path).  A requested
    backend that exists but is unavailable on this host (e.g. ``"pallas"``
    off-TPU) falls back to ``"xla"`` rather than crashing; an *unknown*
    name raises so config typos surface immediately.
    """
    if kind not in ATTN_KERNELS:
        raise KeyError(f"unknown attention dispatch kind {kind!r}; "
                       f"have {sorted(ATTN_KERNELS)}")
    req = _requested_backend(backend)
    if req is None or req == "xla":
        return "xla"
    kernel = _get_kernel(kind)
    if kernel is None:
        return "xla"
    b = kernel.backends.get(req)
    if b is None:
        raise KeyError(
            f"unknown attention backend {req!r} for {ATTN_KERNELS[kind]!r}; "
            f"have {sorted(kernel.backends)}")
    if b.is_available():
        return req
    return "xla"


def _tuned_params(kernel, *args, backend: str, **kwargs):
    """(params, provenance) for this exact call from the tuning cache."""
    from repro.core import tuning
    hit = tuning.cached_entry(kernel, *args, backend=backend, **kwargs)
    if hit is None:
        return {}, "miss-default"
    return tuning.params_from_cache(hit["params"]), \
        hit.get("search", "exhaustive")


def attend(q, k, v, q_pos, k_pos, *, n_kv_heads: int, causal: bool,
           window: int = 0, bf16_intermediates: bool = False,
           backend: Optional[str] = None,
           k_index_aligned: bool = True) -> jnp.ndarray:
    """Position-masked GQA attention, dispatched through the kernel registry.

    Same contract as :func:`attend_xla` (which also remains the default
    path).  ``backend`` requests a registry backend by name (``"pallas"``,
    ``"pallas_interpret"``, ``"xla"``; env var ``REPRO_ATTN_BACKEND``
    overrides).  Single-query causal calls route to ``attention.decode``,
    prefill-shaped calls to ``attention.flash``; calls the kernels cannot
    express (block misalignment, ring-wrapped prefill caches flagged via
    ``k_index_aligned=False``) fall back to XLA and record why in the
    dispatch log.  Tuned block sizes are injected from the tuning cache
    (miss -> declared defaults).
    """
    s, t = q.shape[1], k.shape[1]
    kind = "decode" if (causal and s == 1) else "prefill"
    resolved = resolve_attention_backend(kind, backend)

    if resolved != "xla":
        kernel = _get_kernel(kind)
        if kind == "decode":
            params, prov = _tuned_params(kernel, q, k, v, q_pos, k_pos,
                                         backend=resolved, window=window)
            bkv = min(params.get("bkv", 256), t)
            if t % bkv == 0:
                _log(kind, backend=resolved, kernel=kernel.name,
                     tuning=prov, params=params)
                return kernel(q, k, v, q_pos, k_pos, backend=resolved,
                              window=window, **params)
            _log(kind, backend="xla", kernel=kernel.name, tuning="n/a",
                 params={}, fallback=f"cache_len {t} not divisible by "
                                     f"block {bkv}")
        else:
            qk = jnp.moveaxis(q, 2, 1)           # (B,H,S,Dh) kernel layout
            kk = jnp.moveaxis(k, 2, 1)           # (B,Kv,T,Dh)
            vk = jnp.moveaxis(v, 2, 1)
            params, prov = _tuned_params(kernel, qk, kk, vk, q_pos, k_pos,
                                         backend=resolved, causal=causal,
                                         window=window)
            bq = min(params.get("bq", 256), s)
            bk = min(params.get("bk", 256), t)
            aligned = s % bq == 0 and t % bk == 0
            if not (causal and not k_index_aligned) and aligned:
                _log(kind, backend=resolved, kernel=kernel.name,
                     tuning=prov, params=params)
                out = kernel(qk, kk, vk, q_pos, k_pos, backend=resolved,
                             causal=causal, window=window, **params)
                return jnp.moveaxis(out, 1, 2)
            reason = (f"S={s}/T={t} not divisible by blocks {bq}/{bk}"
                      if not aligned else
                      "causal prefill against a wrapped/unaligned KV ring")
            _log(kind, backend="xla", kernel=kernel.name, tuning="n/a",
                 params={}, fallback=reason)
    else:
        _log(kind, backend="xla", kernel=ATTN_KERNELS[kind], tuning="n/a",
             params={})

    return attend_xla(q, k, v, q_pos, k_pos, n_kv_heads=n_kv_heads,
                      causal=causal, window=window,
                      bf16_intermediates=bf16_intermediates)


def attention_apply(p: Params, x: jnp.ndarray, *, n_heads: int,
                    n_kv_heads: int, head_dim: int, positions: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    use_rope: bool = True, rope_theta: float = 1e4,
                    cache: Optional[Dict[str, jnp.ndarray]] = None,
                    memory_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    memory_pos: Optional[jnp.ndarray] = None,
                    bf16_intermediates: bool = False,
                    backend: Optional[str] = None,
                    ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One attention sublayer.

    * training / prefill: cache=None, full-sequence self attention.
    * decode: cache holds K/V/pos ring buffer; x is (B, 1, D).
    * cross attention: memory_kv=(k, v) precomputed from encoder output
      (memory_pos gives their positions; causal must be False).
    ``backend`` selects the registry attention backend (see ``attend``).
    Returns (output, updated_cache).
    """
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)

    k_index_aligned = True
    if memory_kv is not None:
        k, v = memory_kv
        k_pos = memory_pos
        new_cache = cache
        k_index_aligned = False      # encoder memory: arbitrary positions
    else:
        k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
        v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
        if use_rope:
            k = apply_rope(k, positions, rope_theta)
        if cache is None:
            k_pos = positions
            new_cache = None
        else:
            cache_len = cache["k"].shape[1]
            # ring-buffer slot for each new token; pad tokens (position -1,
            # masked prefill) are routed out of bounds and dropped — slot
            # -1 % cache_len would collide with a real token's slot on
            # sliding-window ring buffers shorter than the padded length
            slots = jnp.where(positions >= 0, positions % cache_len,
                              cache_len)                 # (B, S)
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            ck = cache["k"].at[bidx, slots].set(k, mode="drop")
            cv = cache["v"].at[bidx, slots].set(v, mode="drop")
            cpos = cache["pos"].at[bidx, slots].set(positions, mode="drop")
            new_cache = {"k": ck, "v": cv, "pos": cpos}
            k, v, k_pos = ck, cv, cpos
            # a multi-token prefill against a ring shorter than the padded
            # length can wrap: slot index no longer tracks position, so the
            # flash prefill kernel's index-based block skip is unsound
            k_index_aligned = s == 1 or cache_len >= s

    out = attend(q, k, v, positions, k_pos, n_kv_heads=n_kv_heads,
                 causal=causal, window=window,
                 bf16_intermediates=bf16_intermediates, backend=backend,
                 k_index_aligned=k_index_aligned)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"], new_cache


def cross_kv(p: Params, memory: jnp.ndarray, n_kv_heads: int,
             head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute cross-attention K/V from encoder memory (B, T, D)."""
    b, t, _ = memory.shape
    k = (memory @ p["wk"]).reshape(b, t, n_kv_heads, head_dim)
    v = (memory @ p["wv"]).reshape(b, t, n_kv_heads, head_dim)
    return k, v
