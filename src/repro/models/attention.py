"""GQA attention: full / sliding-window / cross, prefill + ring-buffer decode.

Masks are position-based: the KV cache carries the absolute position of every
slot (-1 = empty), so full caches and sliding-window ring buffers share one
code path.  Softmax accumulates in fp32.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, apply_rope, dense_init

NEG_INF = -1e30


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, n_layers_scale: int = 1) -> Params:
    ks = jax.random.split(key, 4)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype,
                         out_scale),
    }


def init_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
               dtype) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def _gqa_scores(q, k, n_kv_heads):
    """q (B,S,H,Dh), k (B,T,Kv,Dh) -> (B,Kv,G,S,T) fp32 logits."""
    b, s, h, dh = q.shape
    g = h // n_kv_heads
    qg = q.reshape(b, s, n_kv_heads, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    return logits * (1.0 / math.sqrt(dh))


def _gqa_combine(weights, v):
    """weights (B,Kv,G,S,T), v (B,T,Kv,Dh) -> (B,S,H,Dh)."""
    b, kv, g, s, t = weights.shape
    # keep v in its storage dtype; accumulate in f32 (avoids materializing
    # an f32 copy of the full KV cache on the decode path)
    out = jnp.einsum("bkgst,btkd->bskgd", weights.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, kv * g, v.shape[-1])


def _masked_softmax(logits, mask):
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - jax.lax.stop_gradient(m))
    return e / jnp.sum(e, axis=-1, keepdims=True)


CHUNKED_THRESHOLD = 2048  # use flash-style path when S_q * T is large


def attend(q, k, v, q_pos, k_pos, *, n_kv_heads: int, causal: bool,
           window: int = 0, bf16_intermediates: bool = False) -> jnp.ndarray:
    """Position-masked GQA attention.

    q_pos (B,S) / k_pos (B,T) absolute positions; k_pos == -1 marks empty
    cache slots.  window > 0 additionally restricts to q_pos - k_pos < window.
    Long sequences dispatch to the flash-style chunked path automatically.
    """
    s, t = q.shape[1], k.shape[1]
    if s >= CHUNKED_THRESHOLD and t >= CHUNKED_THRESHOLD \
            and s % 512 == 0 and t % 1024 == 0:
        from repro.models.chunked_attention import attend_chunked
        return attend_chunked(q, k, v, q_pos, k_pos, n_kv_heads=n_kv_heads,
                              causal=causal, window=window,
                              bf16_intermediates=bf16_intermediates)
    logits = _gqa_scores(q, k, n_kv_heads)              # (B,Kv,G,S,T)
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    weights = _masked_softmax(logits, mask)
    return _gqa_combine(weights, v).astype(q.dtype)


def attention_apply(p: Params, x: jnp.ndarray, *, n_heads: int,
                    n_kv_heads: int, head_dim: int, positions: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    use_rope: bool = True, rope_theta: float = 1e4,
                    cache: Optional[Dict[str, jnp.ndarray]] = None,
                    memory_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    memory_pos: Optional[jnp.ndarray] = None,
                    bf16_intermediates: bool = False,
                    ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """One attention sublayer.

    * training / prefill: cache=None, full-sequence self attention.
    * decode: cache holds K/V/pos ring buffer; x is (B, 1, D).
    * cross attention: memory_kv=(k, v) precomputed from encoder output
      (memory_pos gives their positions; causal must be False).
    Returns (output, updated_cache).
    """
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)

    if memory_kv is not None:
        k, v = memory_kv
        k_pos = memory_pos
        new_cache = cache
    else:
        k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
        v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
        if use_rope:
            k = apply_rope(k, positions, rope_theta)
        if cache is None:
            k_pos = positions
            new_cache = None
        else:
            cache_len = cache["k"].shape[1]
            # ring-buffer slot for each new token; pad tokens (position -1,
            # masked prefill) are routed out of bounds and dropped — slot
            # -1 % cache_len would collide with a real token's slot on
            # sliding-window ring buffers shorter than the padded length
            slots = jnp.where(positions >= 0, positions % cache_len,
                              cache_len)                 # (B, S)
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            ck = cache["k"].at[bidx, slots].set(k, mode="drop")
            cv = cache["v"].at[bidx, slots].set(v, mode="drop")
            cpos = cache["pos"].at[bidx, slots].set(positions, mode="drop")
            new_cache = {"k": ck, "v": cv, "pos": cpos}
            k, v, k_pos = ck, cv, cpos

    out = attend(q, k, v, positions, k_pos, n_kv_heads=n_kv_heads,
                 causal=causal, window=window,
                 bf16_intermediates=bf16_intermediates)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"], new_cache


def cross_kv(p: Params, memory: jnp.ndarray, n_kv_heads: int,
             head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute cross-attention K/V from encoder memory (B, T, D)."""
    b, t, _ = memory.shape
    k = (memory @ p["wk"]).reshape(b, t, n_kv_heads, head_dim)
    v = (memory @ p["wv"]).reshape(b, t, n_kv_heads, head_dim)
    return k, v
