"""Mamba-style selective SSM head (hymba's parallel-to-attention branch).

    h_t = exp(dt_t * A) ⊙ h_{t-1} + (dt_t * B_t) * x_t      (per channel, N states)
    y_t = C_t · h_t + D ⊙ x_t
    out = y * silu(z)

Train/prefill uses an associative scan (log-depth); decode is the O(1) state
update.  A causal depthwise conv (width 4) precedes the SSM per Mamba.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

CONV_WIDTH = 4


def ssm_init(key, d_model: int, d_inner: int, n_state: int, dtype,
             n_layers_scale: int = 1) -> Params:
    ks = jax.random.split(key, 8)
    dt_rank = max(d_model // 16, 8)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv": jax.random.normal(ks[1], (CONV_WIDTH, d_inner), dtype) * 0.2,
        "w_bc": dense_init(ks[2], d_inner, 2 * n_state, dtype),
        "w_dt1": dense_init(ks[3], d_inner, dt_rank, dtype),
        "w_dt2": dense_init(ks[4], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, n_state + 1, dtype=jnp.float32)[None],
            (d_inner, 1))).astype(dtype),                # (Di, N)
        "d_skip": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[5], d_inner, d_model, dtype, out_scale),
    }


def _causal_conv(x, w, conv_state=None):
    """depthwise conv, width CONV_WIDTH. x (B,S,Di); state (B,W-1,Di)."""
    b, s, di = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, CONV_WIDTH - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(xp[:, i:i + s] * w[i][None, None] for i in range(CONV_WIDTH))
    return out, xp[:, -(CONV_WIDTH - 1):]


def ssm_apply(p: Params, x: jnp.ndarray, *,
              state: Optional[jnp.ndarray] = None,
              conv_state: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """x (B,S,D) -> (out (B,S,D), (ssm_state (B,Di,N), conv_state))."""
    b, s, d = x.shape
    xz = x @ p["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)                   # (B,S,Di) each
    xs, new_conv = _causal_conv(xs, p["conv"], conv_state)
    xs = jax.nn.silu(xs)

    bc = xs @ p["w_bc"]
    n_state = p["a_log"].shape[1]
    B_t, C_t = jnp.split(bc.astype(jnp.float32), 2, axis=-1)   # (B,S,N)
    dt = jax.nn.softplus(
        (xs @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))        # (Di, N)

    # scan elements: h_t = a_t ⊙ h_{t-1} + b_t
    a = jnp.exp(dt[..., None] * A[None, None])          # (B,S,Di,N)
    bmat = (dt * xs.astype(jnp.float32))[..., None] \
        * B_t[:, :, None, :]                            # (B,S,Di,N)

    if state is not None:
        # fold the incoming state into the first element
        bmat = bmat.at[:, 0].add(a[:, 0] * state)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bmat), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, C_t) \
        + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, (h[:, -1], new_conv)
