"""Mixture-of-Experts layer: fine-grained routed experts + shared experts.

GShard-style capacity-based dense dispatch (DESIGN.md §3 / §8): routing is
expressed as one-hot dispatch/combine einsums — the TPU-native, atomics-free
replacement for gather/scatter token shuffling.  Under the sharding policy
the expert dim lives on the `model` mesh axis, so GSPMD lowers the dispatch
einsums to all-to-alls (expert parallelism).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init


def moe_init(key, d: int, d_ff: int, n_experts: int, n_shared: int,
             mlp_kind: str, dtype, n_layers_scale: int = 1) -> Params:
    ks = jax.random.split(key, 8)
    out_scale = 1.0 / math.sqrt(2 * n_layers_scale)
    n_mats = 3 if mlp_kind == "swiglu" else 2

    def expert_bank(key, n):
        kk = jax.random.split(key, n_mats)
        bank = {
            "w_up": jax.random.normal(kk[0], (n, d, d_ff), dtype)
            / jnp.asarray(math.sqrt(d), dtype),
            "w_down": jax.random.normal(kk[1], (n, d_ff, d), dtype)
            * jnp.asarray(out_scale / math.sqrt(d_ff), dtype),
        }
        if mlp_kind == "swiglu":
            bank["w_gate"] = jax.random.normal(kk[2], (n, d, d_ff), dtype) \
                / jnp.asarray(math.sqrt(d), dtype)
        return bank

    p = {"router": dense_init(ks[0], d, n_experts, dtype),
         "experts": expert_bank(ks[1], n_experts)}
    if n_shared:
        p["shared"] = expert_bank(ks[2], n_shared)
    return p


def _bank_ffn(bank: Params, x_e: jnp.ndarray, mlp_kind: str) -> jnp.ndarray:
    """x_e (..., E, C, D) -> same, through per-expert FFNs."""
    up = jnp.einsum("...ecd,edf->...ecf", x_e, bank["w_up"])
    if mlp_kind == "swiglu":
        gate = jnp.einsum("...ecd,edf->...ecf", x_e, bank["w_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("...ecf,efd->...ecd", h, bank["w_down"])


GROUP_SIZE = 1024  # tokens per routing group (GShard-style locality)


def moe_apply(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
              mlp_kind: str, capacity_factor: float = 1.25,
              group_size: int = GROUP_SIZE,
              stopgrad_dispatch: bool = False,
              constraint=lambda x, kind: x,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B,S,D), aux load-balance loss (scalar)).

    Tokens are routed within fixed-size *groups* (GShard): capacity and the
    dispatch/combine one-hot contractions are per-group, so dispatch memory
    is O(T * E * C_g) with C_g = ceil(group * k / E * cf) — linear in tokens,
    not quadratic.  Overflow tokens beyond capacity drop that expert's
    contribution (standard).
    """
    b, s, d = x.shape
    t = b * s
    gs = min(group_size, t)
    # dispatch/combine one-hots cost ~ T * gs * k * cf bytes: at inference-
    # prefill token counts (>128k) shrink the group so the routing tensors
    # stay within HBM (quality-neutral: capacity scales with the group).
    if t > 131072:
        gs = min(gs, 64)
    if t % gs:
        gs = math.gcd(t, gs)
    g = t // gs
    xt = x.reshape(g, gs, d)

    logits = jnp.einsum("gtd,de->gte", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)    # (G, gs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    capacity = int(math.ceil(gs * top_k / n_experts * capacity_factor))
    capacity = max(capacity, top_k)

    # one-hot expert masks per routing slot, priority = slot-major order
    mask = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (G,gs,k,E)
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(g, top_k * gs, n_experts)
    pos = jnp.cumsum(mask_flat, axis=1) - 1
    pos = pos.reshape(g, top_k, gs, n_experts).transpose(0, 2, 1, 3)
    pos_in_expert = jnp.sum(pos * mask, axis=-1)                 # (G,gs,k)
    keep = pos_in_expert < capacity

    kept_mask = (mask * keep[..., None]).astype(x.dtype)         # (G,gs,k,E)
    poh = jax.nn.one_hot(jnp.where(keep, pos_in_expert, capacity),
                         capacity, dtype=x.dtype)                # (G,gs,k,C)
    if stopgrad_dispatch:
        # exact beyond-paper lever: the one-hots are piecewise-constant, so
        # their cotangents are mathematically irrelevant — router gradients
        # flow through gate_vals in `combine` only.  Skipping their AD
        # removes the fp32 (G,gs,E,C) backward tensors + all-reduces.
        kept_mask = jax.lax.stop_gradient(kept_mask)
        poh = jax.lax.stop_gradient(poh)
    # contract k without materializing (G,gs,k,E,C)
    dispatch = constraint(
        jnp.einsum("gtke,gtkc->gtec", kept_mask, poh), "gtec")
    combine = constraint(
        jnp.einsum("gtke,gtkc->gtec",
                   kept_mask * gate_vals.astype(x.dtype)[..., None], poh),
        "gtec")

    x_e = constraint(
        jnp.einsum("gtec,gtd->gecd", dispatch, xt), "gecd")      # (G,E,C,D)
    y_e = constraint(_bank_ffn(p["experts"], x_e, mlp_kind), "gecd")
    out = jnp.einsum("gtec,gecd->gtd", combine, y_e)

    if "shared" in p:
        # shared experts act on every token: computed as direct einsums over
        # the (small) expert dim — no broadcast_to, which GSPMD propagates
        # badly (it replicated the (E_s, F, T) hidden across the mesh)
        sb = p["shared"]
        up = jnp.einsum("gtd,edf->gtef", xt, sb["w_up"])
        if mlp_kind == "swiglu":
            gate = jnp.einsum("gtd,edf->gtef", xt, sb["w_gate"])
            h_sh = jax.nn.silu(gate) * up
        else:
            h_sh = jax.nn.gelu(up)
        h_sh = constraint(h_sh, "gtec")
        out = out + jnp.einsum("gtef,efd->gtd", h_sh, sb["w_down"])

    # load-balance aux loss (Switch form): E * sum_e f_e * p_e
    importance = jnp.mean(probs.reshape(t, n_experts), axis=0)   # (E,)
    load = jnp.mean(
        jnp.max(mask, axis=2).reshape(t, n_experts).astype(jnp.float32),
        axis=0)
    aux = jnp.asarray(n_experts, jnp.float32) * jnp.sum(importance * load)
    return out.reshape(b, s, d), aux
