"""Deterministic, host-sharded, resumable synthetic token pipeline.

Production framing: every batch is a pure function of (seed, step, host) —
so a restarted or replaced host replays *no* data and elastic resizes keep
determinism (fault tolerance depends on this, see distributed/fault_
tolerance.py).  Also provides a memory-mapped binary-corpus loader with the
same interface, and double-buffered prefetch.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    eos_id: int = 1
    mean_doc_len: int = 512


class SyntheticLM:
    """counter-based RNG stream => random-access batches (seekable)."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        self._step = 0

    def seek(self, step: int) -> None:
        self._step = step

    @property
    def step(self) -> int:
        return self._step

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        # independent counter-based stream per (seed, step, host)
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b, s = self.host_batch, cfg.seq_len
        tokens = rng.integers(2, cfg.vocab_size, (b, s + 1), dtype=np.int32)
        # document packing: EOS resets at geometric boundaries
        doc_ends = rng.random((b, s + 1)) < (1.0 / cfg.mean_doc_len)
        tokens = np.where(doc_ends, cfg.eos_id, tokens)
        return {
            "tokens": tokens[:, :-1],
            "targets": tokens[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            out = self.batch_at(self._step)
            self._step += 1
            yield out


class BinaryCorpus:
    """Memory-mapped flat token file with the same seekable interface."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.int32):
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.tokens_per_batch = self.host_batch * (cfg.seq_len + 1)
        self._step = 0

    def seek(self, step: int) -> None:
        self._step = step

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        n = self.data.shape[0]
        stride = self.tokens_per_batch * cfg.n_hosts
        start = (step * stride + cfg.host_id * self.tokens_per_batch) \
            % max(n - self.tokens_per_batch, 1)
        flat = np.asarray(self.data[start:start + self.tokens_per_batch])
        tok = flat.reshape(self.host_batch, cfg.seq_len + 1)
        return {"tokens": tok[:, :-1].astype(np.int32),
                "targets": tok[:, 1:].astype(np.int32),
                "mask": np.ones((self.host_batch, cfg.seq_len), np.float32)}

    def __iter__(self):
        while True:
            out = self.batch_at(self._step)
            self._step += 1
            yield out


class Prefetcher:
    """Double-buffered background prefetch (overlap host data with device)."""

    def __init__(self, source, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._src = iter(source)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._src:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
