"""data subsystem."""
