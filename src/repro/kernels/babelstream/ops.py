"""jit'd public wrappers for BabelStream; registers backends in the registry.

All wrappers take flat 1-D arrays (like the benchmark) and handle the
(n/128, 128) reshape + padding internally.  Three backends:
``xla`` (ref oracle), ``pallas`` (TPU target), ``pallas_interpret`` (CPU CI).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.core.metrics import babelstream_bytes
from repro.kernels.babelstream import kernel as K
from repro.kernels.babelstream import ref

LANES = K.LANES


def _as2d(x):
    n = x.shape[0]
    if n % LANES:
        raise ValueError(f"BabelStream size must be a multiple of {LANES}")
    return x.reshape(n // LANES, LANES)


def _flat(x2):
    return x2.reshape(-1)


def _make_elementwise(pallas_fn, n_in):
    @functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
    def run(*arrays, interpret=False, block_rows=K.BLOCK_ROWS):
        arrs2 = [_as2d(a) for a in arrays]
        return _flat(pallas_fn(*arrs2, interpret=interpret,
                               block_rows=block_rows))
    return run


copy_pallas = _make_elementwise(K.copy_2d, 1)
add_pallas = _make_elementwise(K.add_2d, 2)


@functools.partial(jax.jit,
                   static_argnames=("scalar", "interpret", "block_rows"))
def mul_pallas(c, scalar=ref.START_SCALAR, *, interpret=False,
               block_rows=K.BLOCK_ROWS):
    return _flat(K.mul_2d(_as2d(c), scalar, interpret=interpret,
                          block_rows=block_rows))


@functools.partial(jax.jit,
                   static_argnames=("scalar", "interpret", "block_rows"))
def triad_pallas(b, c, scalar=ref.START_SCALAR, *, interpret=False,
                 block_rows=K.BLOCK_ROWS):
    return _flat(K.triad_2d(_as2d(b), _as2d(c), scalar, interpret=interpret,
                            block_rows=block_rows))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def dot_pallas(a, b, *, interpret=False, block_rows=K.BLOCK_ROWS):
    return K.dot_2d(_as2d(a), _as2d(b), interpret=interpret,
                    block_rows=block_rows)


# ---- registry ------------------------------------------------------------
def _bytes_model_factory(op):
    def model(*arrays, **kw):
        return babelstream_bytes(op, arrays[0].size, arrays[0].dtype.itemsize)
    return model


_JIT_REF = {name: jax.jit(getattr(ref, name))
            for name in ("copy", "mul", "add", "triad", "dot")}

_PALLAS = {"copy": copy_pallas, "mul": mul_pallas, "add": add_pallas,
           "triad": triad_pallas, "dot": dot_pallas}

def _block_rows_ok(p, *arrays, **kw):
    # the 1-D grid requires n to tile into (block_rows, LANES) blocks exactly
    return arrays[0].size % (p["block_rows"] * LANES) == 0


for _op in ("copy", "mul", "add", "triad", "dot"):
    _k = register_kernel(
        f"babelstream.{_op}",
        bytes_model=_bytes_model_factory(_op),
        doc=f"BabelStream {_op} (paper Eq. 2 FoM)")
    _k.add_backend("xla", _JIT_REF[_op])
    _k.add_backend("pallas", _PALLAS[_op], available=on_tpu)
    _k.add_backend(
        "pallas_interpret",
        functools.partial(_PALLAS[_op], interpret=True))
    _k.declare_tunables(("pallas", "pallas_interpret"),
                        block_rows=K.BLOCK_ROWS_GRID,
                        constraint=_block_rows_ok)
    if _op == "dot":
        # dot reduces every grid step into the same (1, 1) output block —
        # a declared sequential accumulator, not a write race
        _k.declare_grid_contract(("pallas", "pallas_interpret"),
                                 accumulator_outputs=(0,))
    # streaming kernels by construction: O(1) flops per byte, memory-bound
    # on every chip ridge the auditor models
    _k.declare_roofline_contract(("xla", "pallas", "pallas_interpret"),
                                 bound="memory")
