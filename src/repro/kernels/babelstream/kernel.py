"""BabelStream Pallas-TPU kernels.

TPU adaptation (DESIGN.md §3): the four streaming ops are 1-D grids over
(BLOCK, 128)-shaped VMEM tiles (VPU-aligned).  Dot replaces the paper's
block-shared-memory tree reduction + host reduction with the TPU-idiomatic
sequential-grid accumulation: the output BlockSpec maps every grid step onto
the same (1,1) block, which lives in VMEM for the whole grid and is
zero-initialised on the first step.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. 512x128 f32 = 256 KiB/operand — comfortably inside
# VMEM next to double-buffering, and a multiple of the (8,128) vreg.
BLOCK_ROWS = 512
LANES = 128
#: declared row-tile grid (ops.py registers it; sharded composites reuse it)
BLOCK_ROWS_GRID = (128, 256, 512, 1024)


def local_block_rows(n_local: int, block_rows: Optional[int] = None) -> int:
    """Row tile for a (possibly sharded) local 1-D block of ``n_local``
    elements.  An explicit ``block_rows`` is validated against the local
    extent (the grid must tile ``(n_local/128, 128)`` exactly); ``None``
    picks the largest declared tile that fits."""
    if block_rows is not None:
        if n_local % (block_rows * LANES):
            raise ValueError(
                f"block_rows={block_rows} does not tile the local extent "
                f"{n_local} into ({block_rows}, {LANES}) blocks")
        return block_rows
    for cand in sorted(BLOCK_ROWS_GRID, reverse=True):
        if n_local % (cand * LANES) == 0:
            return cand
    raise ValueError(
        f"no declared row tile {BLOCK_ROWS_GRID} tiles the local extent "
        f"{n_local}")


def _grid_1d(n: int, block_rows: int) -> int:
    per_block = block_rows * LANES
    if n % per_block:
        raise ValueError(f"size {n} not a multiple of {per_block}; "
                         "pad at the ops.py layer")
    return n // per_block


def _tile(i):
    return (i, 0)


def _elementwise_call(body, n, dtype, n_in, block_rows, interpret):
    spec = pl.BlockSpec((block_rows, LANES), _tile)
    return pl.pallas_call(
        body,
        grid=(_grid_1d(n, block_rows),),
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n // LANES, LANES), dtype),
        interpret=interpret,
    )


# ---- kernel bodies -------------------------------------------------------
def _copy_body(a_ref, o_ref):
    o_ref[...] = a_ref[...]


def _mul_body(scalar, c_ref, o_ref):
    o_ref[...] = scalar * c_ref[...]


def _add_body(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _triad_body(scalar, b_ref, c_ref, o_ref):
    o_ref[...] = b_ref[...] + scalar * c_ref[...]


def _dot_body(a_ref, b_ref, o_ref, *, acc_dtype):
    # Sequential-grid accumulation: o_ref is the same (1,1) block each step.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    partial = jnp.sum(a_ref[...].astype(acc_dtype) * b_ref[...].astype(acc_dtype))
    o_ref[...] += partial.reshape(1, 1).astype(o_ref.dtype)


# ---- pallas_call wrappers (operate on (n//128, 128) views) ---------------
def copy_2d(a2, *, block_rows: int = BLOCK_ROWS, interpret: bool = False):
    n = a2.size
    return _elementwise_call(_copy_body, n, a2.dtype, 1, block_rows,
                             interpret)(a2)


def mul_2d(c2, scalar, *, block_rows: int = BLOCK_ROWS,
           interpret: bool = False):
    # `scalar` is a compile-time constant — the Mojo `alias` analogue.
    n = c2.size
    body = functools.partial(_mul_body, float(scalar))
    return _elementwise_call(body, n, c2.dtype, 1, block_rows, interpret)(c2)


def add_2d(a2, b2, *, block_rows: int = BLOCK_ROWS, interpret: bool = False):
    n = a2.size
    return _elementwise_call(_add_body, n, a2.dtype, 2, block_rows,
                             interpret)(a2, b2)


def triad_2d(b2, c2, scalar, *, block_rows: int = BLOCK_ROWS,
             interpret: bool = False):
    n = b2.size
    body = functools.partial(_triad_body, float(scalar))
    return _elementwise_call(body, n, b2.dtype, 2, block_rows, interpret)(b2, c2)


def dot_2d(a2, b2, *, block_rows: int = BLOCK_ROWS, interpret: bool = False):
    n = a2.size
    acc_dtype = jnp.float32 if a2.dtype in (jnp.bfloat16, jnp.float16) \
        else a2.dtype
    in_spec = pl.BlockSpec((block_rows, LANES), _tile)
    out = pl.pallas_call(
        functools.partial(_dot_body, acc_dtype=acc_dtype),
        grid=(_grid_1d(n, block_rows),),
        in_specs=[in_spec, in_spec],
        # every grid step maps to the SAME (1,1) output block -> accumulator
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), a2.dtype),
        interpret=interpret,
    )(a2, b2)
    return out[0, 0]


def stream_2d_fns():
    """op name -> (2-D kernel fn, n array args, takes_scalar).

    The local-block entry points of this family: every fn consumes
    ``(rows, 128)`` views of any extent, so the sharded composite backends
    feed it per-device blocks exactly like ops.py feeds it whole arrays.
    """
    return {
        "copy": (copy_2d, 1, False),
        "mul": (mul_2d, 1, True),
        "add": (add_2d, 2, False),
        "triad": (triad_2d, 2, True),
        "dot": (dot_2d, 2, False),
    }
