"""Pure-jnp oracle for the BabelStream ops (paper Listing 3 semantics).

These are the "vendor baseline" analogues: what XLA produces from idiomatic
jnp.  scalar = 0.4 matches the upstream BabelStream startScalar.
"""

from __future__ import annotations

import jax.numpy as jnp

START_SCALAR = 0.4


def copy(a: jnp.ndarray) -> jnp.ndarray:
    """c[i] = a[i]"""
    return a + 0  # force a materialized copy rather than aliasing


def mul(c: jnp.ndarray, scalar: float = START_SCALAR) -> jnp.ndarray:
    """b[i] = scalar * c[i]"""
    return jnp.asarray(scalar, c.dtype) * c


def add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """c[i] = a[i] + b[i]"""
    return a + b


def triad(b: jnp.ndarray, c: jnp.ndarray,
          scalar: float = START_SCALAR) -> jnp.ndarray:
    """a[i] = b[i] + scalar * c[i]"""
    return b + jnp.asarray(scalar, b.dtype) * c


def dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """sum_i a[i]*b[i] (fp32/fp64 accumulate as input dtype dictates)."""
    acc = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) else a.dtype
    return jnp.sum(a.astype(acc) * b.astype(acc)).astype(a.dtype)
