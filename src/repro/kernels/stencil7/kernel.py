"""Seven-point stencil Pallas-TPU kernel.

TPU adaptation (DESIGN.md §3): instead of the GPU one-thread-per-cell model
with cache-served halos, we tile (z, y-block) slabs of the (z, y, x) volume
into VMEM using FIVE BlockSpecs over the same input:

    zc : (z,   y)   the resident plane-slab
    zm : (z-1, y)   plane above      (index map clamped at z=0)
    zp : (z+1, y)   plane below      (clamped at z=nz-1)
    ym : (z, y-1)   previous y-slab  (only its LAST row is consumed)
    yp : (z, y+1)   next y-slab      (only its FIRST row is consumed)

x-neighbours are in-slab lane shifts (pad+slice on the 128-lane axis).
Boundary cells are masked with a vector predicate rather than the CUDA-style
`if (i>0 && ...) return` guard — TPU is vector-predicated, not
thread-divergent.  All coefficients are compile-time constants (the Mojo
`alias` analogue).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BY = 64
#: declared y-tile grid (ops.py registers it; sharded composites reuse it)
BY_GRID = (8, 16, 32, 64)


def local_block_by(ny_local: int, by: Optional[int] = None) -> int:
    """y-tile height for a (possibly sharded) local block.

    The sharded composite backends tile the *post-shard* local block, so the
    admissible heights depend on the decomposition: an explicit ``by`` is
    validated against the local extent (a tile larger than the block can
    never divide it), ``None`` picks the largest declared tile that does —
    ``DEFAULT_BY`` whenever the block is the whole domain of the benchmark
    shapes (ny % 64 == 0).
    """
    if by is not None:
        if ny_local % by:
            raise ValueError(
                f"by={by} does not divide the local y extent {ny_local}")
        return by
    for cand in sorted(BY_GRID, reverse=True):
        if ny_local % cand == 0:
            return cand
    raise ValueError(
        f"no declared y-tile {BY_GRID} divides the local y extent "
        f"{ny_local}")


def _stencil_body(zc_ref, zm_ref, zp_ref, ym_ref, yp_ref, o_ref, *,
                  nz: int, ny: int, nx: int, by: int,
                  invhx2: float, invhy2: float, invhz2: float,
                  invhxyz2: float):
    z = pl.program_id(0)
    yb = pl.program_id(1)
    dt = o_ref.dtype

    c = zc_ref[0]          # (by, nx) resident slab
    up = zm_ref[0]
    dn = zp_ref[0]

    # y halo rows from the neighbouring slabs
    ym_row = ym_ref[0, by - 1, :][None, :]
    yp_row = yp_ref[0, 0, :][None, :]
    y_prev = jnp.concatenate([ym_row, c[:-1]], axis=0)
    y_next = jnp.concatenate([c[1:], yp_row], axis=0)

    # x halo via lane shifts (edge columns masked out below)
    x_prev = jnp.pad(c, ((0, 0), (1, 0)))[:, :-1]
    x_next = jnp.pad(c, ((0, 0), (0, 1)))[:, 1:]

    out = (c * dt.type(invhxyz2)
           + (x_prev + x_next) * dt.type(invhx2)
           + (y_prev + y_next) * dt.type(invhy2)
           + (up + dn) * dt.type(invhz2))

    # interior-cell predicate
    gy = yb * by + jax.lax.broadcasted_iota(jnp.int32, (by, nx), 0)
    gx = jax.lax.broadcasted_iota(jnp.int32, (by, nx), 1)
    interior = ((gy > 0) & (gy < ny - 1) & (gx > 0) & (gx < nx - 1)
                & (z > 0) & (z < nz - 1))
    o_ref[0] = jnp.where(interior, out, jnp.zeros_like(out))


def laplacian_3d(u: jnp.ndarray, invhx2: float, invhy2: float, invhz2: float,
                 invhxyz2: float, *, by: int = DEFAULT_BY,
                 interpret: bool = False) -> jnp.ndarray:
    """Pallas seven-point stencil over a (nz, ny, nx) volume."""
    nz, ny, nx = u.shape
    if nx % LANES:
        raise ValueError(f"nx={nx} must be a multiple of {LANES}")
    if ny % by:
        raise ValueError(f"ny={ny} must be a multiple of by={by}")

    block = (1, by, nx)
    zc = pl.BlockSpec(block, lambda z, y: (z, y, 0))
    zm = pl.BlockSpec(block, lambda z, y: (jnp.maximum(z - 1, 0), y, 0))
    zp = pl.BlockSpec(block, lambda z, y: (jnp.minimum(z + 1, nz - 1), y, 0))
    ym = pl.BlockSpec(block, lambda z, y: (z, jnp.maximum(y - 1, 0), 0))
    yp = pl.BlockSpec(block,
                      lambda z, y: (z, jnp.minimum(y + 1, ny // by - 1), 0))

    body = functools.partial(
        _stencil_body, nz=nz, ny=ny, nx=nx, by=by,
        invhx2=float(invhx2), invhy2=float(invhy2), invhz2=float(invhz2),
        invhxyz2=float(invhxyz2))

    return pl.pallas_call(
        body,
        grid=(nz, ny // by),
        in_specs=[zc, zm, zp, ym, yp],
        out_specs=pl.BlockSpec(block, lambda z, y: (z, y, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret,
    )(u, u, u, u, u)


def vmem_working_set_bytes(u_shape: Tuple[int, int, int], itemsize: int,
                           by: int = DEFAULT_BY) -> int:
    """Claimed VMEM footprint: 5 input slabs + 1 output slab (per buffer)."""
    _, _, nx = u_shape
    return 6 * by * nx * itemsize
