"""Pure-jnp oracle for the seven-point stencil (paper Listing 2 semantics).

f[i,j,k] = u[i,j,k]*invhxyz2 + (u[i,j,k-1]+u[i,j,k+1])*invhx2
                             + (u[i,j-1,k]+u[i,j+1,k])*invhy2
                             + (u[i-1,j,k]+u[i+1,j,k])*invhz2
on interior cells; boundary cells are zero (the HIP baseline never writes
them; we fix them to 0 so both implementations are pure functions).
Axis order is (z, y, x), x contiguous.
"""

from __future__ import annotations

import jax.numpy as jnp


def default_coefficients(hx: float = 1.0, hy: float = 1.0, hz: float = 1.0):
    invhx2, invhy2, invhz2 = 1.0 / hx ** 2, 1.0 / hy ** 2, 1.0 / hz ** 2
    invhxyz2 = -2.0 * (invhx2 + invhy2 + invhz2)
    return invhx2, invhy2, invhz2, invhxyz2


def laplacian(u: jnp.ndarray, invhx2: float, invhy2: float, invhz2: float,
              invhxyz2: float) -> jnp.ndarray:
    c = u.dtype.type
    core = (u[1:-1, 1:-1, 1:-1] * c(invhxyz2)
            + (u[1:-1, 1:-1, :-2] + u[1:-1, 1:-1, 2:]) * c(invhx2)
            + (u[1:-1, :-2, 1:-1] + u[1:-1, 2:, 1:-1]) * c(invhy2)
            + (u[:-2, 1:-1, 1:-1] + u[2:, 1:-1, 1:-1]) * c(invhz2))
    return jnp.pad(core, 1)
