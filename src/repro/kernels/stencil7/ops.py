"""jit'd wrappers + registry entries for the seven-point stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.core.metrics import stencil7_effective_bytes
from repro.kernels.stencil7 import kernel as K
from repro.kernels.stencil7 import ref


@functools.partial(jax.jit, static_argnames=(
    "invhx2", "invhy2", "invhz2", "invhxyz2", "by", "interpret"))
def laplacian_pallas(u, invhx2=1.0, invhy2=1.0, invhz2=1.0, invhxyz2=-6.0,
                     *, by=K.DEFAULT_BY, interpret=False):
    return K.laplacian_3d(u, invhx2, invhy2, invhz2, invhxyz2, by=by,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "invhx2", "invhy2", "invhz2", "invhxyz2"))
def laplacian_xla(u, invhx2=1.0, invhy2=1.0, invhz2=1.0, invhxyz2=-6.0):
    return ref.laplacian(u, invhx2, invhy2, invhz2, invhxyz2)


def _bytes_model(u, *args, **kw):
    # paper Eq. 1, assuming the cubic L^3 grid of the study
    L = u.shape[0]
    return stencil7_effective_bytes(L, u.dtype.itemsize)


_k = register_kernel("stencil7", bytes_model=_bytes_model,
                     doc="seven-point Laplacian stencil (paper Eq. 1 FoM)")
_k.add_backend("xla", laplacian_xla)
_k.add_backend("pallas", laplacian_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(laplacian_pallas, interpret=True))
# y-slab height: the VMEM working set is 6*by*nx*itemsize, so the grid must
# tile ny exactly — the autotuner sweeps the heights that do.
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    by=K.BY_GRID,
    constraint=lambda p, u, *a, **kw: u.shape[1] % p["by"] == 0)
# AI ~= 13/24 flop/byte at fp32: memory-bound on every chip ridge the
# auditor models (cpu-host 16.7 through H100 ~295)
_k.declare_roofline_contract(("xla", "pallas", "pallas_interpret"),
                             bound="memory")
