"""miniBUDE `fasten` Pallas-TPU kernel.

TPU adaptation (DESIGN.md §3): the GPU kernel holds PPWI poses per work-item
in registers and loops atoms from L1.  On TPU we lay **poses on the lane
axis** (128 poses per grid step = the PPWI analogue), **protein atoms on the
sublane axis**, and run the ligand-atom loop sequentially:

    grid step  = one 128-pose tile
    VMEM       = full protein (natpro, 4) pos + (natpro, 4) params,
                 full ligand, the (6, 128) pose slice
    inner loop = fori over ligand atoms; each iteration evaluates the
                 (natpro, 128) interaction tile with pure VPU ops

All branches of the BUDE energy model become vector predicates (jnp.where) —
TPU has no divergence.  Atom type is carried as a float and compared
numerically, mirroring the paper's Mojo plain-old-data workaround.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.minibude.ref import (
    CNSTNT, FLOAT_MAX, FOUR, HALF, HARDNESS, HBTYPE_E, HBTYPE_F, NPNPDIST,
    NPPDIST, ONE, QUARTER, TWO, ZERO,
)

POSE_TILE = 128  # poses per grid step (lane width)
#: declared pose-tile grid (ops.py registers it; sharded composites reuse it)
POSE_TILE_GRID = (64, 128, 256)


def local_pose_tile(nposes_local: int, pose_tile: Optional[int] = None) -> int:
    """Pose tile for a (possibly sharded) local pose block.  An explicit
    ``pose_tile`` is validated against the local extent; ``None`` picks the
    largest declared tile that divides it."""
    if pose_tile is not None:
        if nposes_local % pose_tile:
            raise ValueError(
                f"pose_tile={pose_tile} does not divide the local pose "
                f"count {nposes_local}")
        return pose_tile
    for cand in sorted(POSE_TILE_GRID, reverse=True):
        if nposes_local % cand == 0:
            return cand
    raise ValueError(
        f"no declared pose tile {POSE_TILE_GRID} divides the local pose "
        f"count {nposes_local}")


def _fasten_body(ppos_ref, ppar_ref, lpos_ref, lpar_ref, poses_ref, o_ref,
                 *, natlig: int):
    dt = o_ref.dtype
    # jnp.where over two weak Python scalars promotes to float64 under x64
    # mode; anchor the branch constants to the output dtype (same fix as
    # the jnp oracle — the bitwise twin contract needs both or neither)
    c = dt.type
    FOUR_, TWO_, QUARTER_, HALF_ = c(FOUR), c(TWO), c(QUARTER), c(HALF)
    ONE_, ZERO_, HARD2_ = c(ONE), c(ZERO), c(TWO * HARDNESS)
    NPNPDIST_, NPPDIST_, NFMAX_ = c(NPNPDIST), c(NPPDIST), c(-FLOAT_MAX)
    # pose transform for this 128-pose tile: twelve (1, T) rows
    ang = poses_ref[...]                       # (6, T)
    sx, cx = jnp.sin(ang[0:1]), jnp.cos(ang[0:1])
    sy, cy = jnp.sin(ang[1:2]), jnp.cos(ang[1:2])
    sz, cz = jnp.sin(ang[2:3]), jnp.cos(ang[2:3])
    tx, ty, tz = ang[3:4], ang[4:5], ang[5:6]
    m00, m01, m02 = cy * cz, sx * sy * cz - cx * sz, cx * sy * cz + sx * sz
    m10, m11, m12 = cy * sz, sx * sy * sz + cx * cz, cx * sy * sz - sx * cz
    m20, m21, m22 = -sy, sx * cy, cx * cy

    p_x = ppos_ref[:, 0:1]                     # (natpro, 1)
    p_y = ppos_ref[:, 1:2]
    p_z = ppos_ref[:, 2:3]
    p_hbtype = ppar_ref[:, 0:1]
    p_radius = ppar_ref[:, 1:2]
    p_hphb = ppar_ref[:, 2:3]
    p_elsc = ppar_ref[:, 3:4]

    phphb_ltz = p_hphb < ZERO
    phphb_gtz = p_hphb > ZERO
    phphb_nz = p_hphb != ZERO

    def per_ligand(il, etot):
        lrow_pos = lpos_ref[pl.ds(il, 1), :]   # (1, 4)
        lrow_par = lpar_ref[pl.ds(il, 1), :]
        lx, ly, lz = lrow_pos[0, 0], lrow_pos[0, 1], lrow_pos[0, 2]
        l_hbtype, l_radius = lrow_par[0, 0], lrow_par[0, 1]
        l_hphb, l_elsc = lrow_par[0, 2], lrow_par[0, 3]

        # transformed ligand position for every pose: (1, T)
        lpx = m00 * lx + m01 * ly + m02 * lz + tx
        lpy = m10 * lx + m11 * ly + m12 * lz + ty
        lpz = m20 * lx + m21 * ly + m22 * lz + tz

        lhphb_ltz = l_hphb < ZERO
        lhphb_gtz = l_hphb > ZERO

        radij = p_radius + l_radius            # (natpro, 1)
        r_radij = ONE / radij
        both_f = (p_hbtype == HBTYPE_F) & (l_hbtype == HBTYPE_F)
        elcdst = jnp.where(both_f, FOUR_, TWO_)
        elcdst1 = jnp.where(both_f, QUARTER_, HALF_)
        type_e = (p_hbtype == HBTYPE_E) | (l_hbtype == HBTYPE_E)

        p_hphb_s = p_hphb * jnp.where(phphb_ltz & lhphb_gtz, -ONE_, ONE_)
        l_hphb_s = l_hphb * jnp.where(phphb_gtz & lhphb_ltz, -ONE_, ONE_)
        distdslv = jnp.where(phphb_ltz,
                             jnp.where(lhphb_ltz, NPNPDIST_, NPPDIST_),
                             jnp.where(lhphb_ltz, NPPDIST_, NFMAX_))
        r_distdslv = ONE / distdslv
        chrg_init = l_elsc * p_elsc
        dslv_init = p_hphb_s + l_hphb_s

        # (natpro, T) interaction tile — pure VPU
        dx = lpx - p_x
        dy = lpy - p_y
        dz = lpz - p_z
        distij = jnp.sqrt(dx * dx + dy * dy + dz * dz)
        distbb = distij - radij
        zone1 = distbb < ZERO

        e_steric = (ONE - distij * r_radij) * jnp.where(zone1, HARD2_, ZERO_)
        chrg_e = chrg_init * (jnp.where(zone1, ONE, ONE - distbb * elcdst1)
                              * jnp.where(distbb < elcdst, ONE_, ZERO_))
        chrg_e = jnp.where(type_e, -jnp.abs(chrg_e), chrg_e)
        e_chrg = chrg_e * CNSTNT

        coeff = ONE - distbb * r_distdslv
        dslv_e = dslv_init * jnp.where((distbb < distdslv) & phphb_nz,
                                       ONE_, ZERO_)
        dslv_e = dslv_e * jnp.where(zone1, ONE, coeff)

        return etot + jnp.sum(e_steric + e_chrg + dslv_e, axis=0,
                              keepdims=True)

    etot = jnp.zeros((1, ang.shape[1]), dt)
    etot = jax.lax.fori_loop(0, natlig, per_ligand, etot)
    o_ref[...] = etot * HALF


def fasten_tiled(protein_pos, protein_par, ligand_pos, ligand_par, poses,
                 *, pose_tile: int = POSE_TILE, interpret: bool = False):
    """poses (6, P) -> energies (1, P); P must be a multiple of pose_tile."""
    natpro = protein_pos.shape[0]
    natlig = ligand_pos.shape[0]
    P = poses.shape[1]
    if P % pose_tile:
        raise ValueError(f"nposes={P} not a multiple of {pose_tile}")

    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_fasten_body, natlig=natlig),
        grid=(P // pose_tile,),
        in_specs=[
            whole((natpro, 4)),
            whole((natpro, 4)),
            whole((natlig, 4)),
            whole((natlig, 4)),
            pl.BlockSpec((6, pose_tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, pose_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, P), poses.dtype),
        interpret=interpret,
    )(protein_pos, protein_par, ligand_pos, ligand_par, poses)
