"""jit'd wrappers + registry entries + deck generator for miniBUDE."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.portable import on_tpu, register_kernel
from repro.core.metrics import minibude_ops
from repro.kernels.minibude import kernel as K
from repro.kernels.minibude import ref


@functools.partial(jax.jit, static_argnames=("pose_tile", "interpret"))
def fasten_pallas(protein_pos, protein_par, ligand_pos, ligand_par, poses,
                  *, pose_tile=K.POSE_TILE, interpret=False):
    out = K.fasten_tiled(protein_pos, protein_par, ligand_pos, ligand_par,
                         poses, pose_tile=pose_tile, interpret=interpret)
    return out[0]


fasten_xla = jax.jit(ref.fasten)


def make_deck(natpro=938, natlig=26, nposes=65536, ntypes=4, seed=0,
              dtype=jnp.float32):
    """Synthetic bm1-shaped deck (positions in Å-scale box, BUDE-like params).

    Forcefield rows are (hbtype, radius, hphb, elsc); hbtype drawn from
    {F, E, 0}, hphb from {-1, 0, 1}-ish magnitudes, matching the branch
    structure the real deck exercises.
    """
    rng = np.random.default_rng(seed)
    hb_choices = np.array([ref.HBTYPE_F, ref.HBTYPE_E, 0.0], np.float32)

    def params(n):
        return np.stack([
            rng.choice(hb_choices, n),
            rng.uniform(1.0, 2.5, n),
            rng.choice(np.array([-0.8, 0.0, 0.9], np.float32), n),
            rng.uniform(-1.0, 1.0, n),
        ], axis=1)

    def positions(n, box):
        xyz = rng.uniform(-box, box, (n, 3))
        types = rng.integers(0, ntypes, (n, 1)).astype(np.float64)
        return np.concatenate([xyz, types], axis=1)

    poses = np.concatenate([
        rng.uniform(0, 2 * np.pi, (3, nposes)),
        rng.uniform(-2.0, 2.0, (3, nposes)),
    ], axis=0)
    to = lambda a: jnp.asarray(a, dtype)
    return (to(positions(natpro, 24.0)), to(params(natpro)),
            to(positions(natlig, 8.0)), to(params(natlig)), to(poses))


def _flops_model(protein_pos, protein_par, ligand_pos, ligand_par, poses,
                 ppwi: int = K.POSE_TILE, **kw):
    # paper Eq. 3 with PPWI = poses-per-grid-step (lane tile)
    return minibude_ops(ppwi, ligand_pos.shape[0], protein_pos.shape[0],
                        poses.shape[1])


_k = register_kernel("minibude.fasten", flops_model=_flops_model,
                     doc="miniBUDE fasten energy kernel (paper Eq. 3 FoM)")
_k.add_backend("xla", fasten_xla)
_k.add_backend("pallas", fasten_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(fasten_pallas, interpret=True))
# PPWI analogue: poses per grid step (lane tile) — must divide nposes
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    pose_tile=K.POSE_TILE_GRID,
    constraint=lambda p, *deck, **kw: deck[4].shape[1] % p["pose_tile"] == 0)
