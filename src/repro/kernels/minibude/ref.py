"""Pure-jnp oracle for the miniBUDE `fasten` kernel.

Faithful port of the BUDE energy model (steric + formal/dipole charge +
desolvation terms) from the open-source miniBUDE kernel the paper benchmarks.
Data model mirrors the paper's Mojo workaround: atoms are flat float rows
(x, y, z, type-as-float); per-atom forcefield params are pre-gathered rows
(hbtype, radius, hphb, elsc).

fasten(protein_pos, protein_par, ligand_pos, ligand_par, poses) -> (nposes,)
poses is (6, nposes): three rotation angles + three translations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ZERO, QUARTER, HALF, ONE, TWO, FOUR = 0.0, 0.25, 0.5, 1.0, 2.0, 4.0
CNSTNT = 45.0
HARDNESS = 38.0
NPNPDIST = 5.5
NPPDIST = 1.0
HBTYPE_F = 70.0
HBTYPE_E = 69.0
FLOAT_MAX = 1e30


def pose_transforms(poses: jnp.ndarray) -> jnp.ndarray:
    """(6, P) pose parameters -> (P, 3, 4) rigid transforms (BUDE order)."""
    sx, cx = jnp.sin(poses[0]), jnp.cos(poses[0])
    sy, cy = jnp.sin(poses[1]), jnp.cos(poses[1])
    sz, cz = jnp.sin(poses[2]), jnp.cos(poses[2])
    tx, ty, tz = poses[3], poses[4], poses[5]
    m = jnp.stack([
        jnp.stack([cy * cz, sx * sy * cz - cx * sz, cx * sy * cz + sx * sz, tx], -1),
        jnp.stack([cy * sz, sx * sy * sz + cx * cz, cx * sy * sz - sx * cz, ty], -1),
        jnp.stack([-sy, sx * cy, cx * cy, tz], -1),
    ], axis=-2)  # (P, 3, 4)
    return m


def fasten(protein_pos: jnp.ndarray, protein_par: jnp.ndarray,
           ligand_pos: jnp.ndarray, ligand_par: jnp.ndarray,
           poses: jnp.ndarray) -> jnp.ndarray:
    P = poses.shape[1]
    m = pose_transforms(poses)                       # (P, 3, 4)

    # jnp.where over two weak Python scalars promotes to float64 under x64
    # mode; anchoring the branch constants to the input dtype keeps the
    # oracle precision-faithful on every host configuration
    c = poses.dtype.type
    FOUR_, TWO_, QUARTER_, HALF_ = c(FOUR), c(TWO), c(QUARTER), c(HALF)
    ONE_, ZERO_, HARD2_ = c(ONE), c(ZERO), c(TWO * HARDNESS)
    NPNPDIST_, NPPDIST_, NFMAX_ = c(NPNPDIST), c(NPPDIST), c(-FLOAT_MAX)

    p_hbtype = protein_par[:, 0][:, None]            # (natpro, 1)
    p_radius = protein_par[:, 1][:, None]
    p_hphb = protein_par[:, 2][:, None]
    p_elsc = protein_par[:, 3][:, None]
    p_xyz = protein_pos[:, :3]                       # (natpro, 3)

    def per_ligand(etot, il):
        lpos0 = ligand_pos[il, :3]
        l_hbtype, l_radius, l_hphb, l_elsc = (ligand_par[il, 0],
                                              ligand_par[il, 1],
                                              ligand_par[il, 2],
                                              ligand_par[il, 3])
        # transform ligand atom for every pose: (P, 3)
        lpos = jnp.einsum("pij,j->pi", m[:, :, :3], lpos0) + m[:, :, 3]

        lhphb_ltz = l_hphb < ZERO
        lhphb_gtz = l_hphb > ZERO

        radij = p_radius + l_radius                  # (natpro, 1)
        r_radij = ONE / radij
        both_f = (p_hbtype == HBTYPE_F) & (l_hbtype == HBTYPE_F)
        elcdst = jnp.where(both_f, FOUR_, TWO_)
        elcdst1 = jnp.where(both_f, QUARTER_, HALF_)
        type_e = (p_hbtype == HBTYPE_E) | (l_hbtype == HBTYPE_E)

        phphb_ltz = p_hphb < ZERO
        phphb_gtz = p_hphb > ZERO
        phphb_nz = p_hphb != ZERO
        p_hphb_s = p_hphb * jnp.where(phphb_ltz & lhphb_gtz, -ONE_, ONE_)
        l_hphb_s = l_hphb * jnp.where(phphb_gtz & lhphb_ltz, -ONE_, ONE_)
        distdslv = jnp.where(phphb_ltz,
                             jnp.where(lhphb_ltz, NPNPDIST_, NPPDIST_),
                             jnp.where(lhphb_ltz, NPPDIST_, NFMAX_))
        r_distdslv = ONE / distdslv
        chrg_init = l_elsc * p_elsc
        dslv_init = p_hphb_s + l_hphb_s

        # distances: (natpro, P)
        d = lpos.T[None, :, :] - p_xyz[:, :, None]   # (natpro, 3, P)
        distij = jnp.sqrt(jnp.sum(d * d, axis=1))
        distbb = distij - radij
        zone1 = distbb < ZERO

        e_steric = (ONE - distij * r_radij) * jnp.where(zone1, HARD2_, ZERO_)
        chrg_e = chrg_init * (jnp.where(zone1, ONE, ONE - distbb * elcdst1)
                              * jnp.where(distbb < elcdst, ONE_, ZERO_))
        chrg_e = jnp.where(type_e, -jnp.abs(chrg_e), chrg_e)
        e_chrg = chrg_e * CNSTNT

        coeff = ONE - distbb * r_distdslv
        dslv_e = dslv_init * jnp.where((distbb < distdslv) & phphb_nz,
                                       ONE_, ZERO_)
        dslv_e = dslv_e * jnp.where(zone1, ONE, coeff)

        contrib = jnp.sum(e_steric + e_chrg + dslv_e, axis=0)   # (P,)
        return etot + contrib, None

    etot0 = jnp.zeros((P,), poses.dtype)
    etot, _ = jax.lax.scan(per_ligand, etot0,
                           jnp.arange(ligand_pos.shape[0]))
    return etot * HALF
