"""jit'd wrappers + registry entries for flash attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import flash_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_pallas(q, k, v, *, causal=True, window=0, bq=K.DEFAULT_BQ,
                 bk=K.DEFAULT_BK, interpret=False):
    return K.flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                             bk=bk, interpret=interpret)


flash_xla = jax.jit(flash_ref, static_argnames=("causal", "window"))


def _flops_model(q, k, v, causal=True, **kw):
    b, h, s, dh = q.shape
    t = k.shape[2]
    pairs = s * t * (0.5 if causal and s == t else 1.0)
    return 4.0 * b * h * pairs * dh      # QK^T + PV


_k = register_kernel("attention.flash", flops_model=_flops_model,
                     doc="flash attention (causal/windowed GQA), "
                         "online-softmax Pallas kernel")
_k.add_backend("xla", flash_xla)
_k.add_backend("pallas", flash_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(flash_pallas, interpret=True))
# q/k block sizes of the online-softmax loop — must divide S and T
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    bq=(64, 128, 256, 512),
    bk=(64, 128, 256, 512),
    constraint=lambda p, q, k, v, **kw:
        q.shape[2] % p["bq"] == 0 and k.shape[2] % p["bk"] == 0)
