"""jit'd wrappers + registry entries for flash/decode attention.

Registers two kernel families the serving hot path dispatches through
(``models/attention.attend``):

  * ``attention.flash``  — prefill/train tiling, kernel layout
    q (B,H,S,Dh) / k,v (B,Kv,T,Dh), optional (B,S)/(B,T) position arrays
    for left-padded serving prefill;
  * ``attention.decode`` — single-query ring-buffer decode, *model-native*
    layout q (B,1,H,Dh) / k,v (B,T,Kv,Dh) / q_pos (B,1) / k_pos (B,T), so
    the ``xla`` oracle is literally the plain-XLA ``attend`` path serving
    has always run (bitwise, no layout moves).

Availability follows the ``shard_pallas`` convention: the compiled
``pallas`` backend declares ``available=on_tpu`` but its wrapper defaults
``interpret=None`` -> interpret everywhere but TPU, so a direct call (or a
dispatch that slipped past the availability check) degrades to the
interpret path off-TPU instead of crashing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import decode_ref, flash_ref


def _interpret_capable() -> bool:
    """Pallas interpret mode needs any jax backend at all."""
    try:
        jax.devices()
        return True
    except Exception:  # pragma: no cover - no jax backend at all
        return False


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_pallas(q, k, v, q_pos=None, k_pos=None, *, causal=True, window=0,
                 bq=K.DEFAULT_BQ, bk=K.DEFAULT_BK, interpret=None):
    if interpret is None:          # off-TPU fallback, never a crash
        interpret = not on_tpu()
    if q_pos is not None:
        b, h, s, _ = q.shape
        t = k.shape[2]
        q_pos = q_pos.astype(jnp.int32).reshape(b, s, 1)
        k_pos = k_pos.astype(jnp.int32).reshape(b, 1, t)
    return K.flash_attention(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, bq=bq, bk=bk,
                             interpret=interpret)


flash_xla = jax.jit(flash_ref, static_argnames=("causal", "window"))


@functools.partial(jax.jit, static_argnames=("window", "bkv", "interpret"))
def decode_pallas(q, k, v, q_pos, k_pos, *, window=0, bkv=K.DEFAULT_BKV,
                  interpret=None):
    if interpret is None:          # off-TPU fallback, never a crash
        interpret = not on_tpu()
    b, s, h, dh = q.shape          # s == 1 (single decode query)
    kv = k.shape[2]
    g = h // kv
    qk = q.reshape(b, h, dh).reshape(b, kv, g, dh)     # kv-major head order
    kk = jnp.moveaxis(k, 1, 2)                         # (B,Kv,T,Dh)
    vk = jnp.moveaxis(v, 1, 2)
    out = K.decode_attention(
        qk, kk, vk, q_pos.astype(jnp.int32),
        k_pos.astype(jnp.int32)[:, None, :], window=window, bkv=bkv,
        interpret=interpret)
    return out.reshape(b, h, dh).reshape(b, s, h, dh)


decode_xla = jax.jit(decode_ref, static_argnames=("window",))


def _flops_model(q, k, v, *pos, causal=True, **kw):
    b, h, s, dh = q.shape
    t = k.shape[2]
    pairs = s * t * (0.5 if causal and s == t else 1.0)
    return 4.0 * b * h * pairs * dh      # QK^T + PV


def _decode_flops_model(q, k, v, *pos, **kw):
    b, s, h, dh = q.shape                # model layout, s == 1
    return 4.0 * b * h * s * k.shape[1] * dh


_k = register_kernel("attention.flash", flops_model=_flops_model,
                     doc="flash attention (causal/windowed GQA), "
                         "online-softmax Pallas kernel")
_k.add_backend("xla", flash_xla)
_k.add_backend("pallas", flash_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(flash_pallas, interpret=True),
               available=_interpret_capable)
# q/k block sizes of the online-softmax loop — must divide S and T
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    bq=(64, 128, 256, 512),
    bk=(64, 128, 256, 512),
    constraint=lambda p, q, k, v, *a, **kw:
        q.shape[2] % p["bq"] == 0 and k.shape[2] % p["bk"] == 0)
# the online-softmax output block is revisited across the k-axis grid —
# a declared rescale-and-accumulate output, not a write race
_k.declare_grid_contract(("pallas", "pallas_interpret"),
                         accumulator_outputs=(0,))


_kd = register_kernel("attention.decode", flops_model=_decode_flops_model,
                      doc="single-query GQA decode against a ring-buffer "
                          "KV cache (position-masked, leftpad -1 aware)")
_kd.add_backend("xla", decode_xla)
_kd.add_backend("pallas", decode_pallas, available=on_tpu)
_kd.add_backend("pallas_interpret",
                functools.partial(decode_pallas, interpret=True),
                available=_interpret_capable)
# cache-axis block size of the online-softmax loop — must divide cache_len
_kd.declare_tunables(
    ("pallas", "pallas_interpret"),
    bkv=(64, 128, 256, 512),
    constraint=lambda p, q, k, v, *a, **kw:
        k.shape[1] % p["bkv"] == 0 or k.shape[1] <= p["bkv"])
# same online-softmax accumulator shape along the cache-axis grid
_kd.declare_grid_contract(("pallas", "pallas_interpret"),
                          accumulator_outputs=(0,))
# single-query decode re-reads the whole KV cache per token (AI ~1):
# memory-bound on every modeled chip ridge
_kd.declare_roofline_contract(("xla", "pallas", "pallas_interpret"),
                              bound="memory")
