"""Oracle for the flash-attention kernel: exact masked GQA attention.

Layout convention for the kernel path: q (B, H, S, Dh), k/v (B, Kv, T, Dh)
with index-aligned positions (token i at position i) — the train/prefill
case the kernel serves.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attend


def flash_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,H,S,Dh), k/v (B,Kv,T,Dh) -> (B,H,S,Dh)."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    t = k.shape[2]
    q_bshd = jnp.moveaxis(q, 1, 2)            # (B,S,H,Dh)
    k_bshd = jnp.moveaxis(k, 1, 2)
    v_bshd = jnp.moveaxis(v, 1, 2)
    pos_q = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pos_k = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    out = attend(q_bshd, k_bshd, v_bshd, pos_q, pos_k, n_kv_heads=kv,
                 causal=causal, window=window)
    return jnp.moveaxis(out, 2, 1)
