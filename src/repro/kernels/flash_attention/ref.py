"""Oracles for the flash/decode attention kernels: exact masked GQA.

Both wrap the plain-XLA ``models/attention.attend_xla`` path — the serving
engine's historical attention implementation — so registry conformance pins
the Pallas kernels to exactly what serving used to run.

Layout conventions:
  * ``flash_ref`` (prefill/train): q (B, H, S, Dh), k/v (B, Kv, T, Dh).
    Positions default to index-aligned (token i at position i); passing
    ``q_pos``/``k_pos`` (B, S)/(B, T) switches to explicit positions with
    -1 = empty/pad (left-padded serving prefill).
  * ``decode_ref`` (serving decode): model-native layout — q (B, 1, H, Dh),
    k/v (B, T, Kv, Dh) ring-buffer cache, q_pos (B, 1) / k_pos (B, T).
    This is *bitwise* the ``attend`` decode path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attend_xla


def flash_ref(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
              window: int = 0):
    """q (B,H,S,Dh), k/v (B,Kv,T,Dh) -> (B,H,S,Dh)."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    t = k.shape[2]
    q_bshd = jnp.moveaxis(q, 1, 2)            # (B,S,H,Dh)
    k_bshd = jnp.moveaxis(k, 1, 2)
    v_bshd = jnp.moveaxis(v, 1, 2)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                 (b, t))
    out = attend_xla(q_bshd, k_bshd, v_bshd, q_pos, k_pos, n_kv_heads=kv,
                     causal=causal, window=window)
    return jnp.moveaxis(out, 2, 1)


def decode_ref(q, k, v, q_pos, k_pos, *, window: int = 0):
    """q (B,1,H,Dh), k/v (B,T,Kv,Dh), q_pos (B,1), k_pos (B,T) -> like q."""
    return attend_xla(q, k, v, q_pos, k_pos, n_kv_heads=k.shape[2],
                      causal=True, window=window)
