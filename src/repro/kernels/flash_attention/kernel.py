"""Flash attention (causal/windowed GQA) as a Pallas-TPU kernel.

Tiling: grid (B, H, n_q, n_k) — the k axis is innermost and sequential on
TPU, so the online-softmax running state (m, l, acc) lives in VMEM scratch
persisting across k steps; the output BlockSpec maps every k step of one
(b, h, qi) cell to the same block and is written on the last step.  GQA is
expressed in the k/v index maps (h -> h // group).  BlockSpec dims are
(bq x dh) / (bk x dh) MXU-aligned tiles.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 256


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                bq: int, bk: int, n_k: int, causal: bool, window: int,
                scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = ki * bk
    # static-shape predicate: does this k block intersect the mask at all?
    run = True
    if causal:
        run = k_lo <= q_lo + bq - 1
    if window:
        run = run & (k_lo + bk - 1 >= q_lo - (window - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window:
            mask &= (qp - kp) < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q (B,H,S,Dh), k/v (B,Kv,T,Dh) -> (B,H,S,Dh)."""
    b, h, s, dh = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(bq, s)
    bk = min(bk, t)
    if s % bq or t % bk:
        raise ValueError(f"S={s}/T={t} must divide block sizes {bq}/{bk}")
    n_q, n_k = s // bq, t // bk
    scale = 1.0 / math.sqrt(dh)

    body = functools.partial(_flash_body, bq=bq, bk=bk, n_k=n_k,
                             causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        body,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),   # running accumulator
        ],
        interpret=interpret,
    )(q, k, v)
