"""Flash attention (causal/windowed GQA) as Pallas-TPU kernels.

Two kernel shapes share the online-softmax machinery:

* ``flash_attention`` — prefill/train tiling, grid (B, H, n_q, n_k) with the
  k axis innermost and sequential on TPU, so the running state (m, l, acc)
  lives in VMEM scratch persisting across k steps; the output BlockSpec maps
  every k step of one (b, h, qi) cell to the same block and is written on
  the last step.  GQA is expressed in the k/v index maps (h -> h // group).
  Masking is index-based by default; passing ``q_pos``/``k_pos`` switches to
  *position-based* masking (``k_pos == -1`` marks empty/pad slots — the
  serving engine's left-padded prefill), under the contract that positions
  are index-aligned up to a non-negative per-row left-pad offset
  (``pos[i] <= i``, real tokens contiguous).  The causal block-skip
  predicate stays sound under that contract; the window block-skip is only
  applied in index mode (a left-pad offset shifts which low blocks a window
  reaches, so position mode visits them all and lets the mask decide).

* ``decode_attention`` — single-query serving decode against a ring-buffer
  KV cache, grid (B, Kv, n_t) with the cache axis innermost/sequential.
  The cache carries the absolute position of every slot (-1 = empty), so
  wraparound needs no special handling: masking is purely position-based
  (``kp >= 0 & kp <= qp`` + optional sliding window) and slot order never
  matters.  Every slot block is visited (ring order is arbitrary).  At
  least one cache slot must be valid per row (the decode path always writes
  the current token's K/V before attending) — an all-masked row returns 0
  where the XLA oracle returns a uniform average of v, both garbage by
  contract.

BlockSpec dims are (bq x dh) / (bk x dh) MXU-aligned tiles; softmax state
accumulates in fp32.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 256
DEFAULT_BKV = 256


def _flash_body(*refs, bq: int, bk: int, n_k: int, causal: bool, window: int,
                scale: float, has_pos: bool):
    if has_pos:
        q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, m_scr, l_scr, acc_scr \
            = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
        qp_ref = kp_ref = None
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = ki * bk
    # static-shape predicate: does this k block intersect the mask at all?
    # In position mode the causal skip stays sound (pos[i] <= i with a
    # shared per-row offset for q, and cache slots holding pos in {-1, s}),
    # but the window skip is index-distance based and a left-pad offset
    # shrinks the position distance — so it only applies in index mode.
    run = True
    if causal:
        run = k_lo <= q_lo + bq - 1
    if window and not has_pos:
        run = run & (k_lo + bk - 1 >= q_lo - (window - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        if has_pos:
            qp = qp_ref[0]                           # (bq, 1) int32
            kp = kp_ref[0]                           # (1, bk) int32
            mask = kp >= 0                           # empty/pad slots
        else:
            qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kp <= qp)
        if window:
            mask = mask & ((qp - kp) < window)
        logits = jnp.where(jnp.broadcast_to(mask, (bq, bk)), logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
                    window: int = 0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False):
    """q (B,H,S,Dh), k/v (B,Kv,T,Dh) -> (B,H,S,Dh).

    q_pos (B,S,1) / k_pos (B,1,T) int32 absolute positions (pass both or
    neither); -1 marks empty/pad slots.  Without them masking is
    index-based (token i at position i).
    """
    b, h, s, dh = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(bq, s)
    bk = min(bk, t)
    if s % bq or t % bk:
        raise ValueError(f"S={s}/T={t} must divide block sizes {bq}/{bk}")
    if (q_pos is None) != (k_pos is None):
        raise ValueError("pass both q_pos and k_pos, or neither")
    has_pos = q_pos is not None
    n_q, n_k = s // bq, t // bk
    scale = 1.0 / math.sqrt(dh)

    body = functools.partial(_flash_body, bq=bq, bk=bk, n_k=n_k,
                             causal=causal, window=window, scale=scale,
                             has_pos=has_pos)
    in_specs = [
        pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, bk, dh),
                     lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        pl.BlockSpec((1, 1, bk, dh),
                     lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
    ]
    args = [q, k, v]
    if has_pos:
        in_specs += [
            pl.BlockSpec((1, bq, 1), lambda b_, h_, qi, ki: (b_, qi, 0)),
            pl.BlockSpec((1, 1, bk), lambda b_, h_, qi, ki: (b_, 0, ki)),
        ]
        args += [q_pos, k_pos]
    return pl.pallas_call(
        body,
        grid=(b, h, n_q, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),   # running accumulator
        ],
        interpret=interpret,
    )(*args)


# --------------------------------------------------------------------------
# decode: single query against the ring-buffer KV cache
# --------------------------------------------------------------------------
def _decode_body(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, m_scr, l_scr,
                 acc_scr, *, n_t: int, window: int, scale: float):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)              # (bkv, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bkv)

    qp = qp_ref[0, 0]                                # scalar int32
    kp = kp_ref[0]                                   # (1, bkv) int32
    mask = (kp >= 0) & (kp <= qp)                    # empty slots + causal
    if window:
        mask = mask & ((qp - kp) < window)
    logits = jnp.where(jnp.broadcast_to(mask, logits.shape), logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ti == n_t - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                     bkv: int = DEFAULT_BKV, interpret: bool = False):
    """q (B,Kv,G,Dh), k/v (B,Kv,T,Dh), q_pos (B,1), k_pos (B,1,T) int32
    -> (B,Kv,G,Dh).

    One query token per row against a position-annotated KV cache: slot
    order is arbitrary (ring buffers arrive as stored), ``k_pos == -1``
    marks empty slots, and ``window > 0`` additionally restricts to
    ``q_pos - k_pos < window``.  ``bkv`` tiles the cache axis.
    """
    b, kv, g, dh = q.shape
    t = k.shape[2]
    bkv = min(bkv, t)
    if t % bkv:
        raise ValueError(f"cache length T={t} must divide block size {bkv}")
    n_t = t // bkv
    scale = 1.0 / math.sqrt(dh)

    body = functools.partial(_decode_body, n_t=n_t, window=window,
                             scale=scale)
    return pl.pallas_call(
        body,
        grid=(b, kv, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, k_, ti: (b_, k_, 0, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda b_, k_, ti: (b_, k_, ti, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda b_, k_, ti: (b_, k_, ti, 0)),
            pl.BlockSpec((1, 1), lambda b_, k_, ti: (b_, 0)),
            pl.BlockSpec((1, 1, bkv), lambda b_, k_, ti: (b_, 0, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b_, k_, ti: (b_, k_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running denom l
            pltpu.VMEM((g, dh), jnp.float32),    # running accumulator
        ],
        interpret=interpret,
    )(q, k, v, q_pos, k_pos)
