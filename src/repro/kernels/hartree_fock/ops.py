"""jit'd wrappers + registry entries for the Hartree-Fock twoel kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.core.metrics import hartree_fock_quartets
from repro.kernels.hartree_fock import kernel as K
from repro.kernels.hartree_fock import ref


def _pad4(positions):
    n = positions.shape[0]
    return jnp.concatenate(
        [positions, jnp.zeros((n, 1), positions.dtype)], axis=1)


@functools.partial(jax.jit, static_argnames=("ngauss", "i_tile", "interpret"))
def fock_pallas(positions, density, *, ngauss=3, i_tile=K.I_TILE,
                interpret=False):
    basis = ref.sto_basis(ngauss, positions.dtype)
    return K.twoel_tiled(_pad4(positions), density, basis, i_tile=i_tile,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ngauss",))
def fock_xla(positions, density, *, ngauss=3):
    basis = ref.sto_basis(ngauss, positions.dtype)
    return ref.fock_build(positions, density, basis)


def _flops_model(positions, density, ngauss=3, **kw):
    # ~60 flops per primitive quartet (J + K tiles), x2 tiles
    return 120.0 * hartree_fock_quartets(positions.shape[0], ngauss)


_k = register_kernel("hartree_fock.twoel", flops_model=_flops_model,
                     doc="HF two-electron Fock build (wall-clock FoM; "
                         "gather reformulation of the paper's atomics)")
_k.add_backend("xla", fock_xla)
_k.add_backend("pallas", fock_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(fock_pallas, interpret=True))
# Fock rows per grid step (sublane height) — must divide natoms
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    i_tile=K.I_TILE_GRID,
    constraint=lambda p, positions, *a, **kw:
        positions.shape[0] % p["i_tile"] == 0)
# O(N^4) integrals over O(N^2) operands: AI in the thousands, compute-bound
# everywhere the auditor models
_k.declare_roofline_contract(("xla", "pallas", "pallas_interpret"),
                             bound="compute")
