"""Hartree-Fock `twoel` Pallas-TPU kernel (gather formulation).

TPU adaptation (DESIGN.md §3): the GPU versions scatter six atomic updates
per unique quartet; Pallas-TPU has no global atomics and the paper shows the
atomics serialize both vendors.  We grid over (i-tile) rows of the Fock
matrix; each grid step GATHERS its full
    F[i,:] = sum_kl D[k,l] (2 (ij|kl) - (ik|jl))
contribution with zero write contention:

    sublanes  <- i-tile (8 rows of F)
    lanes     <- j (all atoms)
    sequential fori over (k*l) pairs x (g3,g4) x (g1,g2) primitives

Both the J tile (ij|kl) and the K tile (ik|jl) for fixed (k,l,g...) are
(bi, N) VPU expressions sharing the same loop nest.  erf/exp/rsqrt are the
transcendental hot ops (the paper's "fast-math" sensitivity analogue).

``twoel_slab_tiled`` is the local-block entry point of the family: the same
kernel body with the quartet loop's *l* index restricted to an
``[l0, l0+nl)`` slab, the slab offset a traced scalar operand — the sharded
composite backend runs one slab per device and ``psum``s the partial Fock
matrices (the distributed analogue of the paper's atomic scatter-adds).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.hartree_fock.ref import TWO_PI_POW_2_5, Basis, boys_f0

I_TILE = 8  # Fock rows per grid step (sublane height)
#: declared i-tile grid (ops.py registers it; sharded composites reuse it)
I_TILE_GRID = (4, 8, 16)


def local_i_tile(natoms: int, i_tile: Optional[int] = None) -> int:
    """Fock-row tile for an ``natoms``-row build (the *i* rows stay whole
    under the l-slab decomposition — only the quartet loop shards).  An
    explicit ``i_tile`` is validated; ``None`` picks the largest declared
    tile that divides the row count."""
    if i_tile is not None:
        if natoms % i_tile:
            raise ValueError(
                f"i_tile={i_tile} does not divide natoms={natoms}")
        return i_tile
    for cand in sorted(I_TILE_GRID, reverse=True):
        if natoms % cand == 0:
            return cand
    raise ValueError(
        f"no declared i-tile {I_TILE_GRID} divides natoms={natoms}")


def _ssss_tile(dt, ax, ay, az, za, bx, by, bz, zb,
               cx, cy, cz, zc, dx, dy, dz, zd):
    """(bi,N)-broadcast ssss integral for one primitive quartet."""
    p = za + zb
    q = zc + zd
    ab2 = (ax - bx) ** 2 + (ay - by) ** 2 + (az - bz) ** 2
    cd2 = (cx - dx) ** 2 + (cy - dy) ** 2 + (cz - dz) ** 2
    kab = jnp.exp(-(za * zb / p) * ab2)
    kcd = jnp.exp(-(zc * zd / q) * cd2)
    px_, py_, pz_ = ((za * ax + zb * bx) / p, (za * ay + zb * by) / p,
                     (za * az + zb * bz) / p)
    qx_, qy_, qz_ = ((zc * cx + zd * dx) / q, (zc * cy + zd * dy) / q,
                     (zc * cz + zd * dz) / q)
    pq2 = (px_ - qx_) ** 2 + (py_ - qy_) ** 2 + (pz_ - qz_) ** 2
    t = (p * q / (p + q)) * pq2
    pref = dt.type(TWO_PI_POW_2_5) / (p * q * jnp.sqrt(p + q))
    return pref * kab * kcd * boys_f0(t)


def _quartet_term(dt, pos_i_refs, pos_ref, dens_ref, zc_ref, *,
                  natoms: int, ngauss: int, nl, l0, idx):
    """One (k, l, g1..g4) step of the gather loop: the J and K (bi, N)
    tiles scaled by the density element, with ``l`` enumerated over an
    ``[l0, l0+nl)`` slab (the full build is the ``l0=0, nl=natoms`` slab)."""
    N, G = natoms, ngauss
    xi, yi, zi, xj, yj, zj = pos_i_refs
    kl, g_all = idx // (G * G * G * G), idx % (G * G * G * G)
    k, l = kl // nl, l0 + kl % nl
    g34, g12 = g_all // (G * G), g_all % (G * G)
    g3, g4 = g34 // G, g34 % G
    g1, g2 = g12 // G, g12 % G

    zrow = zc_ref[0]  # (G,) exponents
    crow = zc_ref[1]  # (G,) coefficients
    z1, z2, z3, z4 = zrow[g1], zrow[g2], zrow[g3], zrow[g4]
    cc = crow[g1] * crow[g2] * crow[g3] * crow[g4]

    pk = pos_ref[k]  # (4,) dynamic row loads
    plr = pos_ref[l]
    kx, ky, kz = pk[0], pk[1], pk[2]
    lx, ly, lz = plr[0], plr[1], plr[2]
    dkl = dens_ref[k, l]

    # J: (i j | k l) -> bra pair (i-tile, all-j), ket (k, l) fixed
    j_tile = _ssss_tile(dt, xi, yi, zi, z1, xj, yj, zj, z2,
                        kx, ky, kz, z3, lx, ly, lz, z4)
    # K: (i k | j l) -> bra pair (i-tile, k), ket (all-j, l)
    k_tile = _ssss_tile(dt, xi, yi, zi, z1, kx, ky, kz, z2,
                        xj, yj, zj, z3, lx, ly, lz, z4)
    return cc * dkl * (2.0 * j_tile - k_tile)


def _i_tile_coords(pos_i_ref, pos_ref, natoms):
    N = natoms
    xi = pos_i_ref[:, 0:1]  # (bi, 1) i-tile coordinates
    yi = pos_i_ref[:, 1:2]
    zi = pos_i_ref[:, 2:3]
    xj = pos_ref[:, 0].reshape(1, N)  # (1, N) all-atom coordinates
    yj = pos_ref[:, 1].reshape(1, N)
    zj = pos_ref[:, 2].reshape(1, N)
    return xi, yi, zi, xj, yj, zj


def _twoel_body(pos_i_ref, pos_ref, dens_ref, zc_ref, o_ref, *,
                natoms: int, ngauss: int):
    dt = o_ref.dtype
    coords = _i_tile_coords(pos_i_ref, pos_ref, natoms)

    def body(idx, f_tile):
        return f_tile + _quartet_term(dt, coords, pos_ref, dens_ref, zc_ref,
                                      natoms=natoms, ngauss=ngauss,
                                      nl=natoms, l0=0, idx=idx)

    f0 = jnp.zeros(o_ref.shape, dt)
    total = natoms * natoms * ngauss ** 4
    o_ref[...] = jax.lax.fori_loop(0, total, body, f0)


def _twoel_slab_body(l0_ref, pos_i_ref, pos_ref, dens_ref, zc_ref, o_ref, *,
                     natoms: int, ngauss: int, nl: int):
    dt = o_ref.dtype
    coords = _i_tile_coords(pos_i_ref, pos_ref, natoms)
    l0 = l0_ref[0, 0]  # traced slab offset (one value per device)

    def body(idx, f_tile):
        return f_tile + _quartet_term(dt, coords, pos_ref, dens_ref, zc_ref,
                                      natoms=natoms, ngauss=ngauss,
                                      nl=nl, l0=l0, idx=idx)

    f0 = jnp.zeros(o_ref.shape, dt)
    total = natoms * nl * ngauss ** 4
    o_ref[...] = jax.lax.fori_loop(0, total, body, f0)


def twoel_tiled(positions4: jnp.ndarray, density: jnp.ndarray,
                basis: Basis, *, i_tile: int = I_TILE,
                interpret: bool = False) -> jnp.ndarray:
    """positions4 (N, 4) [xyz + pad], density (N, N) -> Fock (N, N)."""
    N = positions4.shape[0]
    if N % i_tile:
        raise ValueError(f"natoms={N} must be a multiple of i_tile={i_tile}")
    G = basis.ngauss
    zc = jnp.stack([basis.exponents, basis.coefficients]).astype(
        positions4.dtype)  # (2, G)

    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_twoel_body, natoms=N, ngauss=G),
        grid=(N // i_tile,),
        in_specs=[
            pl.BlockSpec((i_tile, 4), lambda i: (i, 0)),  # i-tile positions
            whole((N, 4)),                                # all positions
            whole((N, N)),                                # density
            whole((2, G)),                                # basis
        ],
        out_specs=pl.BlockSpec((i_tile, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), positions4.dtype),
        interpret=interpret,
    )(positions4, positions4, density, zc)


def twoel_slab_tiled(positions4: jnp.ndarray, density: jnp.ndarray,
                     basis: Basis, l0, nl: int, *, i_tile: int = I_TILE,
                     interpret: bool = False) -> jnp.ndarray:
    """Partial Fock build over the quartet slab ``l in [l0, l0+nl)``.

    ``nl`` is static (it sizes the loop); ``l0`` may be traced (each device
    passes ``axis_index * nl``), carried into the kernel as a (1, 1) scalar
    operand.  Summing the slabs over a disjoint cover of ``[0, N)``
    reconstructs the full ``twoel_tiled`` result up to summation order.
    """
    N = positions4.shape[0]
    if N % i_tile:
        raise ValueError(f"natoms={N} must be a multiple of i_tile={i_tile}")
    if not 1 <= nl <= N:
        raise ValueError(f"slab width nl={nl} outside [1, {N}]")
    G = basis.ngauss
    zc = jnp.stack([basis.exponents, basis.coefficients]).astype(
        positions4.dtype)
    l0a = jnp.asarray(l0, jnp.int32).reshape(1, 1)

    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_twoel_slab_body, natoms=N, ngauss=G, nl=nl),
        grid=(N // i_tile,),
        in_specs=[
            whole((1, 1)),                                # slab offset
            pl.BlockSpec((i_tile, 4), lambda i: (i, 0)),  # i-tile positions
            whole((N, 4)),                                # all positions
            whole((N, N)),                                # density
            whole((2, G)),                                # basis
        ],
        out_specs=pl.BlockSpec((i_tile, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), positions4.dtype),
        interpret=interpret,
    )(l0a, positions4, positions4, density, zc)
