"""Hartree-Fock `twoel` Pallas-TPU kernel (gather formulation).

TPU adaptation (DESIGN.md §3): the GPU versions scatter six atomic updates
per unique quartet; Pallas-TPU has no global atomics and the paper shows the
atomics serialize both vendors.  We grid over (i-tile) rows of the Fock
matrix; each grid step GATHERS its full
    F[i,:] = sum_kl D[k,l] (2 (ij|kl) - (ik|jl))
contribution with zero write contention:

    sublanes  <- i-tile (8 rows of F)
    lanes     <- j (all atoms)
    sequential fori over (k*l) pairs x (g3,g4) x (g1,g2) primitives

Both the J tile (ij|kl) and the K tile (ik|jl) for fixed (k,l,g...) are
(bi, N) VPU expressions sharing the same loop nest.  erf/exp/rsqrt are the
transcendental hot ops (the paper's "fast-math" sensitivity analogue).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.hartree_fock.ref import TWO_PI_POW_2_5, Basis, boys_f0

I_TILE = 8  # Fock rows per grid step (sublane height)


def _twoel_body(pos_i_ref, pos_ref, dens_ref, zc_ref, o_ref, *,
                natoms: int, ngauss: int):
    dt = o_ref.dtype
    N, G = natoms, ngauss

    xi = pos_i_ref[:, 0:1]  # (bi, 1) i-tile coordinates
    yi = pos_i_ref[:, 1:2]
    zi = pos_i_ref[:, 2:3]
    xj = pos_ref[:, 0].reshape(1, N)  # (1, N) all-atom coordinates
    yj = pos_ref[:, 1].reshape(1, N)
    zj = pos_ref[:, 2].reshape(1, N)

    def ssss_tile(ax, ay, az, za, bx, by, bz, zb,
                  cx, cy, cz, zc, dx, dy, dz, zd):
        """(bi,N)-broadcast ssss integral for one primitive quartet."""
        p = za + zb
        q = zc + zd
        ab2 = (ax - bx) ** 2 + (ay - by) ** 2 + (az - bz) ** 2
        cd2 = (cx - dx) ** 2 + (cy - dy) ** 2 + (cz - dz) ** 2
        kab = jnp.exp(-(za * zb / p) * ab2)
        kcd = jnp.exp(-(zc * zd / q) * cd2)
        px_, py_, pz_ = ((za * ax + zb * bx) / p, (za * ay + zb * by) / p,
                         (za * az + zb * bz) / p)
        qx_, qy_, qz_ = ((zc * cx + zd * dx) / q, (zc * cy + zd * dy) / q,
                         (zc * cz + zd * dz) / q)
        pq2 = (px_ - qx_) ** 2 + (py_ - qy_) ** 2 + (pz_ - qz_) ** 2
        t = (p * q / (p + q)) * pq2
        pref = dt.type(TWO_PI_POW_2_5) / (p * q * jnp.sqrt(p + q))
        return pref * kab * kcd * boys_f0(t)

    def body(idx, f_tile):
        # idx enumerates (k, l, g3, g4, g1, g2)
        kl, g_all = idx // (G * G * G * G), idx % (G * G * G * G)
        k, l = kl // N, kl % N
        g34, g12 = g_all // (G * G), g_all % (G * G)
        g3, g4 = g34 // G, g34 % G
        g1, g2 = g12 // G, g12 % G

        zrow = zc_ref[0]  # (G,) exponents
        crow = zc_ref[1]  # (G,) coefficients
        z1, z2, z3, z4 = zrow[g1], zrow[g2], zrow[g3], zrow[g4]
        cc = crow[g1] * crow[g2] * crow[g3] * crow[g4]

        pk = pos_ref[k]  # (4,) dynamic row loads
        plr = pos_ref[l]
        kx, ky, kz = pk[0], pk[1], pk[2]
        lx, ly, lz = plr[0], plr[1], plr[2]
        dkl = dens_ref[k, l]

        # J: (i j | k l) -> bra pair (i-tile, all-j), ket (k, l) fixed
        j_tile = ssss_tile(xi, yi, zi, z1, xj, yj, zj, z2,
                           kx, ky, kz, z3, lx, ly, lz, z4)
        # K: (i k | j l) -> bra pair (i-tile, k), ket (all-j, l)
        k_tile = ssss_tile(xi, yi, zi, z1, kx, ky, kz, z2,
                           xj, yj, zj, z3, lx, ly, lz, z4)
        return f_tile + cc * dkl * (2.0 * j_tile - k_tile)

    f0 = jnp.zeros(o_ref.shape, dt)
    total = N * N * G * G * G * G
    o_ref[...] = jax.lax.fori_loop(0, total, body, f0)


def twoel_tiled(positions4: jnp.ndarray, density: jnp.ndarray,
                basis: Basis, *, i_tile: int = I_TILE,
                interpret: bool = False) -> jnp.ndarray:
    """positions4 (N, 4) [xyz + pad], density (N, N) -> Fock (N, N)."""
    N = positions4.shape[0]
    if N % i_tile:
        raise ValueError(f"natoms={N} must be a multiple of i_tile={i_tile}")
    G = basis.ngauss
    zc = jnp.stack([basis.exponents, basis.coefficients]).astype(
        positions4.dtype)  # (2, G)

    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_twoel_body, natoms=N, ngauss=G),
        grid=(N // i_tile,),
        in_specs=[
            pl.BlockSpec((i_tile, 4), lambda i: (i, 0)),  # i-tile positions
            whole((N, 4)),                                # all positions
            whole((N, N)),                                # density
            whole((2, G)),                                # basis
        ],
        out_specs=pl.BlockSpec((i_tile, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, N), positions4.dtype),
        interpret=interpret,
    )(positions4, positions4, density, zc)
