"""Pure-jnp oracle for the Hartree-Fock two-electron (`twoel`) kernel.

The proxy app (Fletcher et al., basic-hf-proxy) builds the electron-repulsion
contribution to the Fock matrix from s-type Gaussian (ssss) integrals over a
system of helium atoms, all sharing one contracted basis:

    (ij|kl) = sum_{g1..g4} c1 c2 c3 c4 * ssss(z1@Ri, z2@Rj, z3@Rk, z4@Rl)

    ssss = 2 pi^{5/2} / (p q sqrt(p+q))
           * exp(-z1 z2/p |Ri-Rj|^2 - z3 z4/q |Rk-Rl|^2)
           * F0( p q/(p+q) |P-Q|^2 )
    p = z1+z2, q = z3+z4, P = (z1 Ri + z2 Rj)/p, Q = (z3 Rk + z4 Rl)/q
    F0(t) = 0.5 sqrt(pi/t) erf(sqrt t),  F0(0) = 1

Fock build (restricted HF closed form):

    F[i,j] = sum_{k,l} D[k,l] * ( 2 (ij|kl) - (ik|jl) )

GPU->TPU adaptation note (DESIGN.md §3): the paper's CUDA/HIP/Mojo kernels
loop over *unique* quartets (8-fold symmetry) and scatter six atomic updates
into F — atomics are their measured bottleneck.  The closed form above is the
*gather* formulation of exactly the same contraction: for symmetric D the six
scatter-adds over unique quartets sum to the same F (the symmetry weights are
absorbed by letting k,l range freely).  We trade the 8x FLOP saving for
contention-free parallelism.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

TWO_PI_POW_2_5 = 2.0 * np.pi ** 2.5


@dataclasses.dataclass(frozen=True)
class Basis:
    """One shared contracted s-shell: exponents + (normalized) coefficients."""

    exponents: jnp.ndarray  # (G,)
    coefficients: jnp.ndarray  # (G,)

    @property
    def ngauss(self) -> int:
        return self.exponents.shape[0]


def sto_basis(ngauss: int = 3, dtype=jnp.float32) -> Basis:
    """STO-nG-like helium s-shell (proxy-app style values, normalized)."""
    if ngauss == 3:
        expo = np.array([6.36242139, 1.15892300, 0.31364979])
        coef = np.array([0.15432897, 0.53532814, 0.44463454])
    elif ngauss == 6:
        expo = np.array([65.98456824, 12.09819836, 3.38438995,
                         1.16259185, 0.45178004, 0.18599939])
        coef = np.array([0.00916360, 0.04936150, 0.16853830,
                         0.37056280, 0.41649150, 0.13033400])
    else:
        raise ValueError("ngauss must be 3 or 6 (paper's cases)")
    # primitive normalization for s gaussians: (2a/pi)^(3/4)
    norm = (2.0 * expo / np.pi) ** 0.75
    return Basis(exponents=jnp.asarray(expo, dtype),
                 coefficients=jnp.asarray(coef * norm, dtype))


def boys_f0(t: jnp.ndarray) -> jnp.ndarray:
    """F0 Boys function, series-guarded at t -> 0."""
    t_safe = jnp.maximum(t, 1e-12)
    big = 0.5 * jnp.sqrt(jnp.pi / t_safe) * jax.lax.erf(jnp.sqrt(t_safe))
    small = 1.0 - t / 3.0 + t * t / 10.0
    return jnp.where(t < 1e-6, small, big)


def _pair_tables(positions: jnp.ndarray, basis: Basis):
    """Stacked (G^2,) pair quantities over all primitive pairs."""
    R = positions
    z, c = basis.exponents, basis.coefficients
    G = basis.ngauss
    g1, g2 = jnp.meshgrid(jnp.arange(G), jnp.arange(G), indexing="ij")
    g1, g2 = g1.reshape(-1), g2.reshape(-1)
    p = z[g1] + z[g2]                                        # (G2,)
    d2 = jnp.sum((R[:, None, :] - R[None, :, :]) ** 2, -1)   # (N,N)
    # P centers (G2, N, N, 3); Kab (G2, N, N)
    P = (z[g1][:, None, None, None] * R[None, :, None, :]
         + z[g2][:, None, None, None] * R[None, None, :, :]) \
        / p[:, None, None, None]
    Kab = jnp.exp(-(z[g1] * z[g2] / p)[:, None, None] * d2[None]) \
        * (c[g1] * c[g2])[:, None, None]
    return p, P, Kab


def eri_tensor(positions: jnp.ndarray, basis: Basis) -> jnp.ndarray:
    """All (ij|kl) integrals: (N, N, N, N). Reference-sized N only."""
    N = positions.shape[0]
    G2 = basis.ngauss ** 2
    p, P, Kab = _pair_tables(positions, basis)

    def body(eri, ab):
        a, b = ab // G2, ab % G2
        pa, qb = p[a], p[b]
        pq_d2 = jnp.sum((P[a][:, :, None, None, :]
                         - P[b][None, None, :, :, :]) ** 2, -1)
        t = (pa * qb / (pa + qb)) * pq_d2
        pref = TWO_PI_POW_2_5 / (pa * qb * jnp.sqrt(pa + qb))
        eri = eri + (pref * boys_f0(t)
                     * Kab[a][:, :, None, None] * Kab[b][None, None, :, :])
        return eri, None

    eri0 = jnp.zeros((N, N, N, N), positions.dtype)
    eri, _ = jax.lax.scan(body, eri0, jnp.arange(G2 * G2))
    return eri


def fock_build(positions: jnp.ndarray, density: jnp.ndarray,
               basis: Basis) -> jnp.ndarray:
    """F[i,j] = sum_kl D[k,l] (2 (ij|kl) - (ik|jl)) — the gather form."""
    eri = eri_tensor(positions, basis)
    j_term = 2.0 * jnp.einsum("ijkl,kl->ij", eri, density)
    k_term = jnp.einsum("ikjl,kl->ij", eri, density)
    return j_term - k_term


def helium_lattice(natoms: int, spacing: float = 1.4,
                   dtype=jnp.float32) -> jnp.ndarray:
    """Deterministic cubic-ish lattice of He atoms (proxy test-deck style)."""
    side = int(np.ceil(natoms ** (1.0 / 3.0)))
    pts = []
    for ix in range(side):
        for iy in range(side):
            for iz in range(side):
                if len(pts) < natoms:
                    pts.append((ix * spacing, iy * spacing, iz * spacing))
    return jnp.asarray(np.array(pts), dtype)


def initial_density(natoms: int, dtype=jnp.float32) -> jnp.ndarray:
    """Symmetric positive test density (identity-dominated, like an SCF guess)."""
    rng = np.random.default_rng(42)
    a = rng.standard_normal((natoms, natoms)) * 0.05
    d = np.eye(natoms) + (a + a.T) / 2.0
    return jnp.asarray(d, dtype)
