"""jit'd wrappers + registry entries for the WKV6 chunked kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.portable import on_tpu, register_kernel
from repro.kernels.rwkv6 import kernel as K
from repro.kernels.rwkv6.ref import wkv_chunked, wkv_serial


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, w_logdecay, u, *, chunk=K.DEFAULT_CHUNK,
               interpret=False):
    return K.wkv_chunked_pallas(r, k, v, w_logdecay, u, chunk=chunk,
                                interpret=interpret)


@jax.jit
def wkv_xla(r, k, v, w_logdecay, u):
    y, _ = wkv_serial(r, k, v, w_logdecay, u)
    return y


def _flops_model(r, k, v, w_logdecay, u, chunk=K.DEFAULT_CHUNK, **kw):
    b, h, s, dh = r.shape
    dv = v.shape[-1]
    intra = s * chunk * (dh + dv)          # A build + A@v per token row
    inter = (s // chunk) * 2 * dh * dv * chunk
    return float(b * h * (intra + inter)) * 2.0


_k = register_kernel("rwkv6.wkv", flops_model=_flops_model,
                     doc="RWKV6 chunked WKV scan (data-dependent decay)")
_k.add_backend("xla", wkv_xla)
_k.add_backend("pallas", wkv_pallas, available=on_tpu)
_k.add_backend("pallas_interpret",
               functools.partial(wkv_pallas, interpret=True))
# intra-chunk parallel width of the chunked scan — must divide S
_k.declare_tunables(
    ("pallas", "pallas_interpret"),
    chunk=(16, 32, 64),
    constraint=lambda p, r, *a, **kw: r.shape[2] % p["chunk"] == 0)
# the xla scan streams state every step (AI ~8): memory-bound on every
# modeled chip; the chunked pallas AI (~36) straddles the cpu-host ridge,
# so only the xla cell pins a bound
_k.declare_roofline_contract("xla", bound="memory")
