"""RWKV6 chunked WKV as a Pallas-TPU kernel.

Grid (B*H, n_chunks): the chunk axis is innermost and sequential on TPU, so
the recurrent state S (Dh x Dv, fp32) lives in VMEM scratch and flows across
chunk steps without touching HBM (the jnp formulation in models/rwkv.py
must round-trip it through the scan carry).  Per chunk:

    intra  A[t,s] = sum_d r[t,d] k[s,d] exp(lw_cum[t-1,d] - lw_cum[s,d])
           (strict lower triangle; every exponent <= 0 — stable)
    bonus  diag(r_t . (u ⊙ k_t))
    inter  y += (r ⊙ exp(lw_before)) @ S
    state  S  = diag(exp(cw)) S + (k ⊙ exp(cw - lw_cum))^T v

u is indexed per head via the grid index map (bh -> bh % H).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv_body(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
              chunk: int, dh: int, dv: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (C, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, Dv)
    lw = lw_ref[0].astype(jnp.float32)        # (C, Dh), log-decay <= 0
    u = u_ref[0].astype(jnp.float32)          # (1? Dh,) -> (Dh,)

    lw_cum = jnp.cumsum(lw, axis=0)           # (C, Dh)
    lw_before = lw_cum - lw
    cw = lw_cum[-1:]                          # (1, Dh)

    # intra-chunk strict triangle (C, C) via (t, s, d) contraction.
    # Clamp: masked s >= t entries have positive exponents (-> inf -> NaN).
    expdiff = jnp.exp(jnp.minimum(
        lw_before[:, None, :] - lw_cum[None, :, :], 0.0))
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    a = jnp.einsum("td,sd,tsd->ts", r, k, expdiff) * tri
    diag = jnp.sum(r * (u[None] * k), axis=1)           # (C,)
    y = jax.lax.dot(a, v, preferred_element_type=jnp.float32) \
        + diag[:, None] * v

    # inter-chunk from the carried state
    r_dec = r * jnp.exp(lw_before)
    y = y + jax.lax.dot(r_dec, s_scr[...],
                        preferred_element_type=jnp.float32)

    # state update
    k_dec = k * jnp.exp(cw - lw_cum)
    s_scr[...] = jnp.exp(cw).T * s_scr[...] + jax.lax.dot(
        k_dec.T, v, preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)


def wkv_chunked_pallas(r, k, v, w_logdecay, u, *, chunk: int = DEFAULT_CHUNK,
                       interpret: bool = False):
    """r/k/v/w (B, H, S, Dh) fp32, u (H, Dh) -> y (B, H, S, Dv).

    Note: unlike the jnp path this kernel starts from S = 0 (training /
    prefill-from-scratch); decode uses the O(1) serial step instead.
    """
    b, h, s, dh = r.shape
    dv = v.shape[-1]
    if s % chunk:
        raise ValueError(f"S={s} must divide chunk={chunk}")
    n = s // chunk

    def flat(x):
        return x.reshape(b * h, s, x.shape[-1])

    rf, kf, vf, lwf = map(flat, (r, k, v, w_logdecay))

    seq_spec = pl.BlockSpec((1, chunk, dh), lambda bh, ci: (bh, ci, 0))
    out = pl.pallas_call(
        functools.partial(_wkv_body, chunk=chunk, dh=dh, dv=dv),
        grid=(b * h, n),
        in_specs=[
            seq_spec, seq_spec,
            pl.BlockSpec((1, chunk, dv), lambda bh, ci: (bh, ci, 0)),
            seq_spec,
            pl.BlockSpec((1, dh), lambda bh, ci: (bh % h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dv), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, u)
    return out.reshape(b, h, s, dv)
