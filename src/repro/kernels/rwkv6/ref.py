"""Oracle for the WKV6 kernel: the exact serial recurrence."""

from __future__ import annotations

from repro.models.rwkv import wkv_chunked, wkv_serial  # noqa: F401

# wkv_serial is the oracle; wkv_chunked is the jnp chunked formulation the
# Pallas kernel mirrors (both validated against wkv_serial in tests).
