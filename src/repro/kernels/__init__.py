"""Pallas TPU kernels (kernel.py + ops.py + ref.py each).

Importing this package registers every kernel's backends in
`repro.core.portable.registry` (the paper's portable-kernel catalogue).
"""

import repro.kernels.babelstream.ops  # noqa: F401
import repro.kernels.stencil7.ops  # noqa: F401
import repro.kernels.minibude.ops  # noqa: F401
import repro.kernels.hartree_fock.ops  # noqa: F401
import repro.kernels.flash_attention.ops  # noqa: F401
import repro.kernels.rwkv6.ops  # noqa: F401

# last (they import the ops modules above): attach the multi-device
# `xla_shard` backends + num_shards tunables, then the composite
# `shard_pallas` backends (shard_map around the Pallas kernels) with their
# tile x shard tunable spaces
import repro.distributed.domain  # noqa: F401
import repro.distributed.shard_pallas  # noqa: F401

# host-side driver-loop "kernel": the serving engine's token-stream
# conformance entry (jaxpr_traceable=False — static passes skip it)
import repro.serving.portable  # noqa: F401
