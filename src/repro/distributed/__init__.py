"""Distributed subsystem: sharding policy, fault tolerance, and the
domain-decomposition science-kernel backends.

``repro.distributed.domain`` registers multi-device ``xla_shard`` backends
(slab/block/pose/quartet decompositions over ``jax.shard_map``) for every
science-kernel family; ``repro.distributed.collectives`` holds the halo-
exchange/psum vocabulary they share.  Neither is imported here — importing
this package must stay side-effect free (no jax device queries); the kernel
catalogue (``import repro.kernels``) pulls ``domain`` in explicitly."""
