"""distributed subsystem."""
