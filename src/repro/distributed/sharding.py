"""Divisibility-aware sharding policy: DP / FSDP(ZeRO) / TP / EP / SP.

Ten architectures with heterogeneous head counts (6, 24, 25, 32, 40, 64 …)
and vocab sizes (49155, 32001, …) make hand-written PartitionSpecs fragile.
This policy assigns mesh axes per tensor by rule, and provably never requests
an indivisible sharding (tests/test_sharding.py property-tests the
invariant):

  * parameters: largest dim divisible by `model` -> TP; largest remaining
    dim divisible by `data` -> FSDP/ZeRO.  Stacked-layer leading dims and
    expert dims get dedicated handling (scan unit / EP).
  * the `pod` axis is pure DP: batch + gradient all-reduce; parameters are
    replicated across pods (cross-pod links are slowest; see
    optim/compression.py for the gradient-bytes mitigation).
  * activations: batch over (pod, data); if batch is unshardable (long-
    context batch=1 cells) the *sequence* dim shards over (pod, data) — SP.
  * KV caches: batch -> DP when divisible, else sequence -> SP; kv-heads ->
    TP when divisible, else head_dim -> TP (head_dim is always a power of 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.transformer import ShardingHints


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig

    # ------------------------------------------------------------------
    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if "pod" in self.mesh.axis_names \
            else ("data",)

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= _axis_size(self.mesh, a)
        return out

    @property
    def tp_size(self) -> int:
        return _axis_size(self.mesh, "model")

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def param_spec(self, path: str, shape: Sequence[int]) -> P:
        """Generic rule engine; `path` is the '/'-joined tree path."""
        rank = len(shape)
        spec: list = [None] * rank
        if rank == 0:
            return P()
        start = 0
        stacked = ("segments/" in path or path.startswith("segments")
                   or "encoder/layers" in path)
        if stacked:
            start = 1  # leading n_layers dim is the scan unit — never shard

        dims = list(range(start, rank))
        # embedding table: shard ONLY the (padded) vocab dim.  Sharding the
        # d_model dim of a gather table trips XLA SPMD's gather-grad
        # partitioning ("slice dim size > dynamic slice dimension"); vocab
        # padding (configs/base.py) guarantees divisibility here.
        if path == "embed" or path.endswith("/embed"):
            spec = [None] * rank
            if shape[0] % self.tp_size == 0:
                spec[0] = "model"
            return P(*spec)

        # EP override: expert banks (L?, E, d_in, d_out) — expert dim -> model
        if "experts/" in path or "shared/" in path:
            e_dim = start
            if e_dim < rank and shape[e_dim] % self.tp_size == 0 \
                    and shape[e_dim] >= self.tp_size:
                spec[e_dim] = "model"
                dims.remove(e_dim)
            # FSDP on the largest remaining divisible dim
            self._assign(spec, shape, dims, "data",
                         _axis_size(self.mesh, "data"))
            return P(*spec)

        if rank - start == 1:
            return P(*spec)  # 1-D (norm scales, biases): replicate

        self._assign(spec, shape, dims, "model", self.tp_size)
        self._assign(spec, shape, dims, "data",
                     _axis_size(self.mesh, "data"))
        return P(*spec)

    @staticmethod
    def _assign(spec, shape, dims, axis_name, axis_size):
        if axis_size <= 1:
            return
        for d in sorted(dims, key=lambda i: -shape[i]):
            if shape[d] % axis_size == 0 and shape[d] >= axis_size:
                spec[d] = axis_name
                dims.remove(d)
                return

    def tree_shardings(self, tree) -> Any:
        """Pytree of NamedSharding matching `tree` (of arrays/SDS)."""
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in paths_leaves:
            pstr = "/".join(_key_str(k) for k in path)
            out.append(NamedSharding(self.mesh,
                                     self.param_spec(pstr, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    # batches / activations
    # ------------------------------------------------------------------
    def batch_spec(self, shape: Sequence[int]) -> P:
        """Input batches (tokens/targets/mask (B,S), frames/patches (B,T,D))."""
        rank = len(shape)
        b = shape[0]
        spec: list = [None] * rank
        if b % self.dp_size == 0:
            spec[0] = self.dp_axes
        elif rank >= 2 and shape[1] % self.dp_size == 0:
            spec[1] = self.dp_axes          # SP fallback (batch=1 cells)
        return P(*spec)

    def batch_shardings(self, batch) -> Any:
        return jax.tree.map(
            lambda a: NamedSharding(self.mesh, self.batch_spec(a.shape)),
            batch)

    # ------------------------------------------------------------------
    # KV caches / decode state
    # ------------------------------------------------------------------
    def cache_spec(self, path: str, shape: Sequence[int]) -> P:
        rank = len(shape)
        spec: list = [None] * rank
        start = 0
        if "segments/" in path or path.startswith("segments"):
            start = 1                        # stacked layer dim
        dims = list(range(start, rank))
        if not dims:
            return P(*spec)
        # batch is the first dim after stacking
        b_dim = start
        if shape[b_dim] % self.dp_size == 0 and shape[b_dim] >= self.dp_size:
            spec[b_dim] = self.dp_axes
            dims.remove(b_dim)
        elif rank > b_dim + 1 and shape[b_dim + 1] % self.dp_size == 0 \
                and shape[b_dim + 1] >= self.dp_size:
            spec[b_dim + 1] = self.dp_axes   # SP over cache length
            dims.remove(b_dim + 1)
        # TP: try kv-heads (dim -2) then head_dim (dim -1)
        for d in (rank - 2, rank - 1):
            if d in dims and shape[d] % self.tp_size == 0 \
                    and shape[d] >= self.tp_size:
                spec[d] = "model"
                dims.remove(d)
                break
        return P(*spec)

    def cache_shardings(self, caches) -> Any:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
        out = []
        for path, leaf in paths_leaves:
            pstr = "/".join(_key_str(k) for k in path)
            out.append(NamedSharding(self.mesh,
                                     self.cache_spec(pstr, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    # activation hints
    # ------------------------------------------------------------------
    def hints(self) -> ShardingHints:
        mesh, dp_axes, dp, tp = self.mesh, self.dp_axes, self.dp_size, \
            self.tp_size
        policy = self

        def moe_constraint(x, kind):
            spec: list = [None] * x.ndim
            if x.shape[0] % dp == 0 and x.shape[0] >= dp:
                spec[0] = dp_axes                 # token groups -> DP
            if kind == "gecd" and x.shape[1] % tp == 0 \
                    and x.shape[1] >= tp:
                spec[1] = "model"                 # expert dim -> EP
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        def params_compute(tree):
            paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
                tree)
            out = []
            for path, leaf in paths_leaves:
                pstr = "/".join(_key_str(k) for k in path)
                spec = policy.param_spec(pstr, leaf.shape)
                stripped = P(*[ax if ax == "model" else None
                               for ax in spec])
                out.append(jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, stripped)))
            return jax.tree_util.tree_unflatten(treedef, out)

        def act(x):
            if x.ndim < 2:
                return x
            spec: list = [None] * x.ndim
            if x.shape[0] % dp == 0 and x.shape[0] >= dp:
                spec[0] = dp_axes
            elif x.shape[1] % dp == 0:
                spec[1] = dp_axes            # SP
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        def logits(x):
            spec: list = [None] * x.ndim
            if x.shape[0] % dp == 0 and x.shape[0] >= dp:
                spec[0] = dp_axes
            elif x.ndim >= 2 and x.shape[1] % dp == 0:
                spec[1] = dp_axes
            if x.shape[-1] % tp == 0:
                spec[-1] = "model"           # vocab-sharded logits
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        return ShardingHints(activation=act, logits=logits,
                             params_compute=params_compute,
                             moe_constraint=moe_constraint)

    # ------------------------------------------------------------------
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)
