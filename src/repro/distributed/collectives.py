"""Thin collective helpers for the domain-decomposition subsystem.

Everything here runs *inside* ``shard_map``-mapped functions, against a named
mesh axis.  The helpers are deliberately minimal — they wrap ``lax.ppermute``
/ ``lax.psum`` with the ring-permutation bookkeeping that every block
decomposition needs, and nothing else:

  * ``ring_perm(n, offset, wrap)`` builds the (src, dst) pairs for a shift
    along a ring of ``n`` shards.  Non-wrapping shifts leave the edge shards
    without a source, and ``lax.ppermute`` fills un-addressed outputs with
    zeros — exactly the zero Dirichlet halo the stencil oracle assumes.
    ``wrap=True`` closes the ring (periodic boundaries).
  * ``shift(x, axis_name, n, offset)`` moves each shard's block ``offset``
    positions along the mesh axis.
  * ``halo_exchange(u, axis_name, n)`` swaps ``halo``-thick boundary slabs
    with both neighbours and returns ``(from_prev, from_next)`` halos.
  * ``halo_exchange_nd`` runs one ``halo_exchange`` per *mesh* axis of a
    named N-D mesh (e.g. ``("shards_z", "shards_y")`` for the 2-D pencil
    decomposition): every helper here is mesh-axis-parametric, so a 2-D
    decomposition is just two independent 1-D exchanges — the seven-point
    stencil has no corner coupling.
  * ``psum`` is re-exported so kernel code imports one module for its
    communication vocabulary.

``n`` (the mesh-axis size) is always passed statically: permutation tables
are Python-level metadata, not traced values.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
from jax import lax
from jax.lax import psum  # noqa: F401  (re-export)

__all__ = ["ring_perm", "shift", "halo_exchange", "halo_exchange_nd", "psum"]


def ring_perm(n: int, offset: int = 1,
              wrap: bool = False) -> List[Tuple[int, int]]:
    """(source, destination) pairs shifting data ``offset`` shards forward.

    ``wrap=False`` drops pairs that would cross the ends: the shards there
    receive zeros from ``ppermute`` (the non-periodic boundary).  Offsets
    beyond the ring are valid and simply address fewer pairs.
    """
    if n < 1:
        raise ValueError(f"ring needs at least one shard, got n={n}")
    pairs = []
    for src in range(n):
        dst = src + offset
        if wrap:
            pairs.append((src, dst % n))
        elif 0 <= dst < n:
            pairs.append((src, dst))
    return pairs


def shift(x: jnp.ndarray, axis_name: str, n: int, offset: int = 1,
          wrap: bool = False) -> jnp.ndarray:
    """Each shard receives the block of the shard ``offset`` positions
    *before* it (zeros at the open ends when ``wrap=False``)."""
    return lax.ppermute(x, axis_name, ring_perm(n, offset, wrap))


def halo_exchange(u: jnp.ndarray, axis_name: str, n: int, *, axis: int = 0,
                  halo: int = 1,
                  wrap: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exchange ``halo``-thick boundary slabs with both ring neighbours.

    Returns ``(from_prev, from_next)``: the previous shard's trailing slab
    and the next shard's leading slab along ``axis``.  At the open ends the
    missing neighbour's halo is zeros (``ppermute`` zero-fills), matching
    the zero-boundary convention of the stencil oracle.
    """
    extent = u.shape[axis]
    if halo > extent:
        raise ValueError(
            f"halo={halo} exceeds local extent {extent} along axis {axis}")
    leading = lax.slice_in_dim(u, 0, halo, axis=axis)
    trailing = lax.slice_in_dim(u, extent - halo, extent, axis=axis)
    from_prev = shift(trailing, axis_name, n, offset=1, wrap=wrap)
    from_next = shift(leading, axis_name, n, offset=-1, wrap=wrap)
    return from_prev, from_next


def halo_exchange_nd(
        u: jnp.ndarray, axis_names: Sequence[str], ns: Sequence[int], *,
        axes: Sequence[int] = (0, 1), halo: int = 1,
        wrap: bool = False) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...]:
    """One independent ``halo_exchange`` per named mesh axis.

    ``axis_names[i]`` is the mesh axis along which array axis ``axes[i]`` is
    decomposed (``ns[i]`` shards).  Returns one ``(from_prev, from_next)``
    pair per mesh axis, in order.  All exchanges are issued on the *same*
    input block, so a downstream consumer can overlap every ``ppermute``
    with halo-free interior compute; halos do **not** include each other's
    corners — fine for face-coupled stencils like the seven-point Laplacian,
    which never reads diagonal neighbours.
    """
    if not (len(axis_names) == len(ns) == len(axes)):
        raise ValueError(
            f"axis_names/ns/axes must align, got {len(axis_names)}/"
            f"{len(ns)}/{len(axes)}")
    return tuple(
        halo_exchange(u, name, n, axis=ax, halo=halo, wrap=wrap)
        for name, n, ax in zip(axis_names, ns, axes))
