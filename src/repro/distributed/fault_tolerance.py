"""Fault-tolerance machinery: heartbeat, straggler EMA, preemption-safe loop.

Designed for thousands of hosts: every component is local-state-only (no
coordination service needed) and composes with the checkpoint manager +
deterministic seekable data pipeline for replay-free restart.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class Heartbeat:
    """Touches a file every `interval` steps; external watchdogs alert on
    stale mtime (the standard k8s/SLURM liveness pattern)."""

    def __init__(self, path: str, interval_s: float = 30.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            with open(self.path, "w") as f:
                f.write(f"{step} {now}\n")
            self._last = now


@dataclasses.dataclass
class StragglerMonitor:
    """Per-step wall-time EMA; flags steps slower than `factor` x EMA.

    At fleet scale the flagged host ids feed the scheduler's replacement
    logic; here we record and expose them.
    """

    alpha: float = 0.1
    factor: float = 2.0
    warmup: int = 5
    _ema: float = 0.0
    _n: int = 0
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float, host_id: int = 0) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else \
                (1 - self.alpha) * self._ema + self.alpha * dt
            return False
        slow = dt > self.factor * self._ema
        if slow:
            self.events.append({"step": step, "dt": dt, "ema": self._ema,
                                "host": host_id})
        else:
            # stragglers don't poison the EMA
            self._ema = (1 - self.alpha) * self._ema + self.alpha * dt
        return slow

    @property
    def ema(self) -> float:
        return self._ema


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful `should_stop` flag (checked per step)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionGuard":
        if not self._installed:
            for s in self._signals:
                try:
                    signal.signal(s, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass
            self._installed = True
        return self

    def _handler(self, signum, frame):
        self._stop = True

    def request_stop(self) -> None:
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop


def elastic_mesh_shape(n_devices: int, prefer_model: int = 16
                       ) -> Dict[str, int]:
    """Factor an arbitrary surviving-device count into (data, model).

    Elastic restarts may come back with fewer hosts; we keep the model axis
    as large as divisibility allows (weights reshard via checkpoint restore).
    """
    model = prefer_model
    while model > 1 and n_devices % model:
        model //= 2
    return {"data": n_devices // model, "model": model}
