"""Composite sharded-Pallas backends: ``shard_map`` around the Pallas kernels.

The paper's portability claim (Eq. 4) rests on the *same* kernel source
serving every hardware tier; PR 3/4 added the device-count axis for the
oracle arithmetic only (``xla_shard``).  This module closes the split: each
science family's existing ``pl.pallas_call`` kernel runs *unchanged inside*
``jax.shard_map`` over the PR-3/4 meshes, so the shard grid
(``num_shards`` / ``shard_grid`` / ``decomp``) composes with that family's
tile tunables (``by`` / ``block_rows`` / ``pose_tile`` / ``i_tile``) in one
``TunableSpace``:

  * **stencil7** — slab or pencil halo exchange (``collectives``) pads the
    local block and the unchanged Pallas ``laplacian_3d`` consumes the
    padded block.  z is the Pallas grid axis, so z-halos pad freely; pencil
    y-halos round the padded width up to a multiple of ``by`` with dead
    columns that the kernel's own interior predicate zeroes and the output
    slice drops.  Because every kept cell is computed by the Pallas kernel
    on exact neighbour values, the sharded field is **bitwise identical to
    the single-device Pallas backend** (sharding must not change the
    kernel's output) — including the one-plane-per-shard edge, where the
    whole local block is halo;
  * **babelstream** — the block partition feeds the ``block_rows``-tiled
    stream kernels on local ``(rows, 128)`` views (bitwise); ``dot``
    reduces each local block with the Pallas sequential-grid accumulator
    and combines partials with ``psum`` (fp-reduction tolerance);
  * **minibude** — pose slabs feed ``fasten_tiled``; per-pose energies are
    independent, so any ``pose_tile`` dividing the local slab is bitwise;
  * **hartree_fock** — each device runs the *l-slab* variant of the Pallas
    twoel kernel (``twoel_slab_tiled``: the quartet loop restricted to the
    device's l range, the slab offset a traced scalar operand) and the
    partial Fock matrices accumulate with ``psum``.

``shard_map`` has no replication rule for ``pallas_call``, so every wrapper
here passes ``check_rep=False``.  Off-TPU the kernels run in
``interpret=True`` mode — the same validation path the single-device
``pallas_interpret`` backends use — so the whole composition is exercisable
on forced host devices (``repro.launch.hostsim``); on TPU the compiled
kernels run as-is.  Availability is therefore
``multi_device() and (on_tpu() or interpret-capable)``.

Unlike ``xla_shard`` (which traces the stream scalar), the scalar here is a
compile-time constant of the Pallas kernel (the Mojo ``alias`` analogue),
exactly as in the single-device pallas backends — one compiled program per
distinct scalar value.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.portable import get_kernel, on_tpu
from repro.distributed import collectives
from repro.distributed.domain import (AXIS, AXIS_Y, AXIS_Z, NO_COLLECTIVES,
                                      ONE_PSUM, SHARD_GRID, STENCIL_DECOMPS,
                                      STENCIL_SHARD_GRIDS, _boundary_keep,
                                      _shard_ok, _stencil_point_ok,
                                      multi_device, resolve_num_shards,
                                      resolve_shard_grid, shard_mesh,
                                      shard_mesh2d)
from repro.kernels.babelstream import kernel as stream_K
from repro.kernels.babelstream import ref as stream_ref
from repro.kernels.hartree_fock import kernel as hf_K
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import kernel as mb_K
from repro.kernels.stencil7 import kernel as s7_K

__all__ = [
    "PALLAS_SHARD_BACKEND",
    "shard_pallas_available",
    "default_interpret",
    "laplacian_shard_pallas",
    "stream_shard_pallas_fns",
    "fasten_shard_pallas",
    "fock_shard_pallas",
    "stencil_pallas_comm_contract",
    "stencil_pallas_point_ok",
    "stream_pallas_point_ok",
    "bude_pallas_point_ok",
    "hf_pallas_point_ok",
    "register_shard_pallas_backends",
]

#: registry backend name: sharded composition of the Pallas kernels
PALLAS_SHARD_BACKEND = "shard_pallas"

LANES = stream_K.LANES


def _interpret_capable() -> bool:
    """Pallas interpret mode lowers to plain jax ops — it runs on any live
    jax backend (the predicate exists so availability reads like the
    contract: multi-device AND a tier that can execute the kernel)."""
    try:
        jax.devices()
        return True
    except Exception:  # pragma: no cover - no jax backend at all
        return False


def default_interpret() -> bool:
    """Interpret everywhere but TPU (where the compiled kernels run)."""
    return not on_tpu()


def shard_pallas_available() -> bool:
    """Availability predicate for every ``shard_pallas`` backend."""
    return multi_device() and (on_tpu() or _interpret_capable())


# --------------------------------------------------------------------------
# stencil7: halo-padded local blocks through the unchanged Pallas kernel
# --------------------------------------------------------------------------
def _slab_local_pallas(u, num_shards, coeffs, by, interpret):
    """One shard of the 1-D slab decomposition: the Pallas kernel consumes
    the z-padded ``(nz_local+2, ny, nx)`` block (z is the grid axis — any
    plane count works) and the halo planes slice away.  With one plane per
    shard the whole block is halo and the same path still holds."""
    lo, hi = collectives.halo_exchange(u, AXIS, num_shards, axis=0)
    padded = jnp.concatenate([lo, u, hi], axis=0)
    out = s7_K.laplacian_3d(padded, *coeffs, by=by, interpret=interpret)
    out = out[1:-1]
    keep = _boundary_keep(out.shape[0], lax.axis_index(AXIS), num_shards)
    return jnp.where(keep[:, None, None], out, jnp.zeros_like(out))


def _pencil_local_pallas(u, sz, sy, coeffs, by, interpret):
    """One shard of the 2-D pencil decomposition.  The y-padded width
    ``ny_local + 2`` rarely divides ``by``, so dead zero columns round it
    up: the kernel's interior predicate (``gy == 0`` / ``gy == ny-1``)
    zeroes the edge columns it would otherwise mis-read, the dead columns
    never feed a kept cell, and the output slice keeps exactly the local
    block — every kept cell is a Pallas-computed cell on exact neighbour
    values."""
    (lo_z, hi_z), (lo_y, hi_y) = collectives.halo_exchange_nd(
        u, (AXIS_Z, AXIS_Y), (sz, sy), axes=(0, 1))
    uz = jnp.concatenate([lo_z, u, hi_z], axis=0)
    nyl = u.shape[1]
    # z-pad the y-halos with dead rows: cells in the z-halo planes are
    # sliced away, so their y-halo values are never consumed
    cols = [jnp.pad(lo_y, ((1, 1), (0, 0), (0, 0))), uz,
            jnp.pad(hi_y, ((1, 1), (0, 0), (0, 0)))]
    extra = (-(nyl + 2)) % by
    if extra:
        cols.append(jnp.zeros((uz.shape[0], extra, u.shape[2]), u.dtype))
    padded = jnp.concatenate(cols, axis=1)
    out = s7_K.laplacian_3d(padded, *coeffs, by=by, interpret=interpret)
    out = out[1:-1, 1:nyl + 1]
    keep = (_boundary_keep(out.shape[0], lax.axis_index(AXIS_Z), sz)
            [:, None, None]
            & _boundary_keep(out.shape[1], lax.axis_index(AXIS_Y), sy)
            [None, :, None])
    return jnp.where(keep, out, jnp.zeros_like(out))


@functools.lru_cache(maxsize=None)
def _stencil_shard_pallas(sz, sy, by, interpret, invhx2, invhy2, invhz2,
                          invhxyz2):
    # audit: compile-time-constant(invhx2, invhy2, invhz2, invhxyz2) —
    # grid-spacing coefficients are fixed per problem; baking them mirrors
    # the single-device pallas backends' static_argnames contract
    coeffs = (invhx2, invhy2, invhz2, invhxyz2)
    if sy == 1:
        mesh, spec = shard_mesh(sz), P(AXIS)
        local = functools.partial(_slab_local_pallas, num_shards=sz,
                                  coeffs=coeffs, by=by, interpret=interpret)
    else:
        mesh, spec = shard_mesh2d(sz, sy), P(AXIS_Z, AXIS_Y)
        local = functools.partial(_pencil_local_pallas, sz=sz, sy=sy,
                                  coeffs=coeffs, by=by, interpret=interpret)
    return jax.jit(shard_map(local, mesh, in_specs=spec, out_specs=spec,
                             check_rep=False))


def laplacian_shard_pallas(u, invhx2=1.0, invhy2=1.0, invhz2=1.0,
                           invhxyz2=-6.0, *, num_shards: Optional[int] = None,
                           decomp: str = "slab", shard_grid=None,
                           by: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Domain-decomposed Pallas seven-point stencil.

    The shard grid resolves exactly like ``laplacian_shard`` (slab splits
    z, pencil splits z and y); ``by`` tiles the *local* block and defaults
    to the largest declared height dividing it.  Bitwise identical to the
    single-device Pallas backend for every decomposition.
    """
    sz, sy = resolve_shard_grid(u.shape[0], u.shape[1], decomp=decomp,
                                shard_grid=shard_grid, num_shards=num_shards)
    by = s7_K.local_block_by(u.shape[1] // sy, by)
    if interpret is None:
        interpret = default_interpret()
    return _stencil_shard_pallas(sz, sy, by, bool(interpret), float(invhx2),
                                 float(invhy2), float(invhz2),
                                 float(invhxyz2))(u)


# --------------------------------------------------------------------------
# BabelStream: block partition through the block_rows-tiled stream kernels
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _stream_shard_pallas(op, num_shards, block_rows, interpret, scalar):
    # audit: compile-time-constant(scalar) — the scalar IS part of this
    # cache key: the Pallas stream kernels bake it as a compile-time
    # constant (the Mojo `alias` analogue), exactly like the single-device
    # pallas backends — one program per value
    mesh = shard_mesh(num_shards)
    fn2d, nargs, takes_scalar = stream_K.stream_2d_fns()[op]

    if op == "dot":
        def local(a, b):
            part = fn2d(a.reshape(-1, LANES), b.reshape(-1, LANES),
                        block_rows=block_rows, interpret=interpret)
            return lax.psum(part, AXIS)
        out_spec = P()
    else:
        def local(*arrays):
            views = [x.reshape(-1, LANES) for x in arrays]
            if takes_scalar:
                out = fn2d(*views, scalar, block_rows=block_rows,
                           interpret=interpret)
            else:
                out = fn2d(*views, block_rows=block_rows,
                           interpret=interpret)
            return out.reshape(-1)
        out_spec = P(AXIS)
    return jax.jit(shard_map(local, mesh, in_specs=(P(AXIS),) * nargs,
                             out_specs=out_spec, check_rep=False))


def _make_stream_shard_pallas(op, nargs, takes_scalar):
    if takes_scalar:
        def run(*args, scalar: Optional[float] = None,
                num_shards: Optional[int] = None,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
            arrays, rest = args[:nargs], args[nargs:]
            if rest:
                scalar = rest[0]
            elif scalar is None:
                scalar = stream_ref.START_SCALAR
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            br = stream_K.local_block_rows(arrays[0].shape[0] // s,
                                           block_rows)
            if interpret is None:
                interpret = default_interpret()
            return _stream_shard_pallas(op, s, br, bool(interpret),
                                        float(scalar))(*arrays)
    else:
        def run(*arrays, num_shards: Optional[int] = None,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            br = stream_K.local_block_rows(arrays[0].shape[0] // s,
                                           block_rows)
            if interpret is None:
                interpret = default_interpret()
            return _stream_shard_pallas(op, s, br, bool(interpret),
                                        None)(*arrays)
    run.__name__ = f"{op}_shard_pallas"
    return run


def stream_shard_pallas_fns():
    """op name -> sharded-Pallas backend fn (ops-layer signatures)."""
    return {op: _make_stream_shard_pallas(op, nargs, takes_scalar)
            for op, (_, nargs, takes_scalar)
            in stream_K.stream_2d_fns().items()}


# --------------------------------------------------------------------------
# miniBUDE: pose slabs through the pose_tile-tiled fasten kernel
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _fasten_shard_pallas(num_shards, pose_tile, interpret):
    mesh = shard_mesh(num_shards)

    def local(pp, ppar, lp, lpar, poses):
        return mb_K.fasten_tiled(pp, ppar, lp, lpar, poses,
                                 pose_tile=pose_tile, interpret=interpret)

    # decks replicate, poses (6, P) shard along P; fasten_tiled returns a
    # (1, P_local) row whose concatenation along lanes is the exact result
    return jax.jit(shard_map(
        local, mesh, in_specs=(P(), P(), P(), P(), P(None, AXIS)),
        out_specs=P(None, AXIS), check_rep=False))


def fasten_shard_pallas(protein_pos, protein_par, ligand_pos, ligand_par,
                        poses, *, num_shards: Optional[int] = None,
                        pose_tile: Optional[int] = None,
                        interpret: Optional[bool] = None):
    """Pose-parallel Pallas miniBUDE energy evaluation."""
    s = resolve_num_shards(poses.shape[1], num_shards)
    pt = mb_K.local_pose_tile(poses.shape[1] // s, pose_tile)
    if interpret is None:
        interpret = default_interpret()
    return _fasten_shard_pallas(s, pt, bool(interpret))(
        protein_pos, protein_par, ligand_pos, ligand_par, poses)[0]


# --------------------------------------------------------------------------
# Hartree-Fock: l-slab Pallas quartet loops, psum Fock accumulation
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _fock_shard_pallas(num_shards, ngauss, i_tile, interpret):
    mesh = shard_mesh(num_shards)

    def local(positions4, density):
        basis = hf_ref.sto_basis(ngauss, positions4.dtype)
        nl = positions4.shape[0] // num_shards
        l0 = lax.axis_index(AXIS) * nl
        part = hf_K.twoel_slab_tiled(positions4, density, basis, l0, nl,
                                     i_tile=i_tile, interpret=interpret)
        return lax.psum(part, AXIS)

    return jax.jit(shard_map(local, mesh, in_specs=(P(), P()),
                             out_specs=P(), check_rep=False))


def fock_shard_pallas(positions, density, *, ngauss: int = 3,
                      num_shards: Optional[int] = None,
                      i_tile: Optional[int] = None,
                      interpret: Optional[bool] = None):
    """Distributed Pallas two-electron Fock build (quartets sharded over
    l; the Fock *rows* stay whole, so ``i_tile`` constrains against the
    full atom count)."""
    N = positions.shape[0]
    s = resolve_num_shards(N, num_shards)
    it = hf_K.local_i_tile(N, i_tile)
    if interpret is None:
        interpret = default_interpret()
    positions4 = jnp.concatenate(
        [positions, jnp.zeros((N, 1), positions.dtype)], axis=1)
    return _fock_shard_pallas(s, ngauss, it, bool(interpret))(
        positions4, density)


# --------------------------------------------------------------------------
# tunable-space cross-constraints (public: the property tests audit them
# with an injected device_count)
# --------------------------------------------------------------------------
def stencil_pallas_point_ok(p, nz: int, ny: int,
                            device_count: Optional[int] = None) -> bool:
    """Shard grid valid AND the y tile divides the *local* (post-shard) y
    extent — a tile larger than the local block can never divide it, so
    oversized tiles are rejected by construction."""
    if not _stencil_point_ok(p, nz, ny, device_count):
        return False
    try:
        _, sy = (int(x) for x in p["shard_grid"])
        by = int(p["by"])
    except (KeyError, TypeError, ValueError):
        return False
    return by >= 1 and (ny // sy) % by == 0


def stream_pallas_point_ok(p, n: int,
                           device_count: Optional[int] = None) -> bool:
    """Shard count valid AND the local block tiles into
    ``(block_rows, 128)`` blocks exactly."""
    try:
        s, br = int(p["num_shards"]), int(p["block_rows"])
    except (KeyError, TypeError, ValueError):
        return False
    return (_shard_ok(s, n, device_count)
            and br >= 1 and (n // s) % (br * LANES) == 0)


def bude_pallas_point_ok(p, nposes: int,
                         device_count: Optional[int] = None) -> bool:
    """Shard count valid AND the pose tile divides the local pose slab."""
    try:
        s, pt = int(p["num_shards"]), int(p["pose_tile"])
    except (KeyError, TypeError, ValueError):
        return False
    return (_shard_ok(s, nposes, device_count)
            and pt >= 1 and (nposes // s) % pt == 0)


def hf_pallas_point_ok(p, natoms: int,
                       device_count: Optional[int] = None) -> bool:
    """Shard count valid for the l axis AND the i tile divides the (whole)
    Fock row count."""
    try:
        s, it = int(p["num_shards"]), int(p["i_tile"])
    except (KeyError, TypeError, ValueError):
        return False
    return (_shard_ok(s, natoms, device_count)
            and 1 <= it <= natoms and natoms % it == 0)


# --------------------------------------------------------------------------
# registration: plug into the existing PortableKernel registry
# --------------------------------------------------------------------------
def stencil_pallas_comm_contract(u, *args):
    """Declared collective census for the shard_pallas stencil: the halo
    exchange is identical to the xla_shard composition (slab: 2 ppermutes,
    pencil: 4) — what changes is only the interior compute, which lowers to
    a pallas_call instead of fused XLA ops.  No overlap variants: the
    Pallas composition has no overlap knob."""
    return [
        ({"decomp": "slab"}, {**NO_COLLECTIVES, "ppermute": 2}),
        ({"decomp": "pencil"}, {**NO_COLLECTIVES, "ppermute": 4}),
    ]


def register_shard_pallas_backends() -> None:
    """Attach ``shard_pallas`` backends + composite tile x shard tunables
    to every science family whose Pallas kernel shards.  Idempotent."""
    k = get_kernel("stencil7")
    if PALLAS_SHARD_BACKEND not in k.backends:
        k.add_backend(PALLAS_SHARD_BACKEND, laplacian_shard_pallas,
                      available=shard_pallas_available)
        k.declare_tunables(
            PALLAS_SHARD_BACKEND, decomp=STENCIL_DECOMPS,
            shard_grid=STENCIL_SHARD_GRIDS, by=s7_K.BY_GRID,
            constraint=lambda p, u, *a, device_count=None, **kw:
                stencil_pallas_point_ok(p, u.shape[0], u.shape[1],
                                        device_count))
        k.declare_comm_contract(PALLAS_SHARD_BACKEND,
                                stencil_pallas_comm_contract)
        # every shard re-reads its halo-padded slab once per local grid
        # step and the tiny grids replicate operand planes, so the modeled
        # traffic legitimately runs ~9-12x over the compulsory floor
        k.declare_roofline_contract(PALLAS_SHARD_BACKEND,
                                    traffic_inflation_limit=16.0)

    for op, fn in stream_shard_pallas_fns().items():
        k = get_kernel(f"babelstream.{op}")
        if PALLAS_SHARD_BACKEND in k.backends:
            continue
        k.add_backend(PALLAS_SHARD_BACKEND, fn,
                      available=shard_pallas_available)
        k.declare_tunables(
            PALLAS_SHARD_BACKEND, num_shards=SHARD_GRID,
            block_rows=stream_K.BLOCK_ROWS_GRID,
            constraint=lambda p, *arrays, device_count=None, **kw:
                stream_pallas_point_ok(p, arrays[0].shape[0], device_count))
        k.declare_comm_contract(
            PALLAS_SHARD_BACKEND,
            ONE_PSUM if op == "dot" else NO_COLLECTIVES)
        if op == "dot":
            # the local Pallas dot reduces sequentially into one output
            # block revisited every grid step — a declared accumulator, not
            # a write race
            k.declare_grid_contract(PALLAS_SHARD_BACKEND,
                                    accumulator_outputs=(0,))
        # streaming AI is shard-invariant: memory-bound on every chip
        k.declare_roofline_contract(PALLAS_SHARD_BACKEND, bound="memory")

    k = get_kernel("minibude.fasten")
    if PALLAS_SHARD_BACKEND not in k.backends:
        k.add_backend(PALLAS_SHARD_BACKEND, fasten_shard_pallas,
                      available=shard_pallas_available)
        k.declare_tunables(
            PALLAS_SHARD_BACKEND, num_shards=SHARD_GRID,
            pose_tile=mb_K.POSE_TILE_GRID,
            constraint=lambda p, *deck, device_count=None, **kw:
                bude_pallas_point_ok(p, deck[4].shape[1], device_count))
        k.declare_comm_contract(PALLAS_SHARD_BACKEND, NO_COLLECTIVES)

    k = get_kernel("hartree_fock.twoel")
    if PALLAS_SHARD_BACKEND not in k.backends:
        k.add_backend(PALLAS_SHARD_BACKEND, fock_shard_pallas,
                      available=shard_pallas_available)
        k.declare_tunables(
            PALLAS_SHARD_BACKEND, num_shards=SHARD_GRID,
            i_tile=hf_K.I_TILE_GRID,
            constraint=lambda p, positions, *a, device_count=None, **kw:
                hf_pallas_point_ok(p, positions.shape[0], device_count))
        k.declare_comm_contract(PALLAS_SHARD_BACKEND, ONE_PSUM)
        # compute-bound everywhere; the conformance deck is tiny (608-byte
        # compulsory floor) and every shard re-reads the replicated
        # operands, so modeled traffic runs ~10-16x over the floor
        k.declare_roofline_contract(PALLAS_SHARD_BACKEND, bound="compute",
                                    traffic_inflation_limit=24.0)


# importing the ops modules registers the base kernels (mirrors domain.py);
# the composite backends then attach on top
import repro.kernels.babelstream.ops  # noqa: E402,F401
import repro.kernels.hartree_fock.ops  # noqa: E402,F401
import repro.kernels.minibude.ops  # noqa: E402,F401
import repro.kernels.stencil7.ops  # noqa: E402,F401

register_shard_pallas_backends()
