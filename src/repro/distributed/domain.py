"""Domain decomposition: the science kernels as multi-device registry backends.

The paper measures portability across *compiler backends* on one GPU; the
Eq.-4 methodology generalizes to the device-count axis (Godoy et al., 2023
run these same workloads across full exascale nodes).  This module supplies
that axis: each science-kernel family gains an ``xla_shard`` backend that
runs the oracle arithmetic under ``jax.shard_map`` over a 1-D device mesh —

  * **stencil7** — tunable decomposition shape: 1-D z slabs or 2-D
    ``(sz, sy)`` pencils over a named ``(shards_z, shards_y)`` mesh, with
    per-axis ``ppermute`` halo exchange (``collectives.halo_exchange`` /
    ``halo_exchange_nd``) and an ``overlap=True`` variant that issues the
    halo traffic first, computes the halo-free interior while it is in
    flight, and patches only the O(surface) boundary planes afterwards;
    every variant applies the unchanged oracle arithmetic, so the sharded
    field is *bitwise identical* to the single-device result;
  * **babelstream** — block-partitioned 1-D arrays; copy/mul/add/triad are
    embarrassingly parallel (bitwise identical), ``dot`` reduces each block
    locally in the accumulation dtype and combines partials with ``psum``;
  * **minibude.fasten** — pose-parallel: poses shard across devices, the
    protein/ligand decks replicate, per-pose energies are independent
    (bitwise identical);
  * **hartree_fock.twoel** — each device computes the ERI slab for its range
    of the *l* quartet index, contracts it with the matching density
    columns, and the partial Fock matrices accumulate with ``psum`` — the
    distributed analogue of the paper's atomic scatter-adds, without the
    contention.

Backends register in the existing ``PortableKernel`` registry with
``available = device_count >= 2`` and a tunable ``num_shards`` grid, so
``repro.core.tuning`` and the Eq.-4 sweep extend to the device axis with no
registry changes.  On a CPU host, simulate devices with
``repro.launch.hostsim.ensure_host_device_count(8)`` *before* importing jax
(``benchmarks/scaling.py`` and ``repro.distributed.selftest`` do).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.portable import get_kernel
from repro.distributed import collectives
from repro.kernels.babelstream import ref as stream_ref
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import ref as mb_ref
from repro.kernels.stencil7 import ref as s7_ref

__all__ = [
    "AXIS",
    "AXIS_Z",
    "AXIS_Y",
    "SHARD_BACKEND",
    "shard_mesh",
    "shard_mesh2d",
    "multi_device",
    "resolve_num_shards",
    "balanced_pencil_grid",
    "resolve_shard_grid",
    "laplacian_shard",
    "stream_shard_fns",
    "fasten_shard",
    "fock_shard",
    "stencil_comm_contract",
    "register_sharded_backends",
]

#: mesh axis name every 1-D sharded kernel maps over
AXIS = "shards"
#: named axes of the 2-D pencil mesh (z outermost, matching array layout)
AXIS_Z = "shards_z"
AXIS_Y = "shards_y"
#: registry backend name (xla arithmetic + sharding, hence the prefix)
SHARD_BACKEND = "xla_shard"
#: num_shards grid declared to the autotuner (1-D decompositions)
SHARD_GRID = (2, 4, 8)
#: stencil7 decomposition tunables: shape of the shard grid is a tunable,
#: not a hard-coded choice (slab = (s, 1); pencil splits z AND y)
STENCIL_DECOMPS = ("slab", "pencil")
STENCIL_SHARD_GRIDS = ((2, 1), (4, 1), (8, 1), (2, 2), (4, 2), (2, 4))
OVERLAP_GRID = (False, True)


def multi_device() -> bool:
    """Availability predicate for every ``xla_shard`` backend."""
    try:
        return jax.device_count() >= 2
    except Exception:  # pragma: no cover - no jax backend at all
        return False


@functools.lru_cache(maxsize=None)
def shard_mesh(num_shards: int) -> Mesh:
    """1-D mesh over the first ``num_shards`` local devices."""
    devices = jax.devices()
    if num_shards > len(devices):
        raise ValueError(
            f"num_shards={num_shards} exceeds the {len(devices)} available "
            f"device(s)")
    return Mesh(np.array(devices[:num_shards]), (AXIS,))


@functools.lru_cache(maxsize=None)
def shard_mesh2d(sz: int, sy: int) -> Mesh:
    """2-D ``(shards_z, shards_y)`` mesh over the first ``sz*sy`` devices."""
    devices = jax.devices()
    if sz * sy > len(devices):
        raise ValueError(
            f"shard grid ({sz}, {sy}) needs {sz * sy} devices, have "
            f"{len(devices)}")
    return Mesh(np.array(devices[:sz * sy]).reshape(sz, sy),
                (AXIS_Z, AXIS_Y))


def resolve_num_shards(extent: int, num_shards: Optional[int] = None,
                       device_count: Optional[int] = None) -> int:
    """Validate an explicit shard count, or pick the largest usable one.

    ``extent`` is the decomposed axis length; a valid count divides it, is
    at least 2, and does not exceed the device count.  ``num_shards=None``
    chooses the largest valid count (deterministic), raising when even 2
    shards cannot be used.
    """
    if device_count is None:
        device_count = jax.device_count()
    if num_shards is not None:
        if num_shards < 2:
            raise ValueError(f"num_shards must be >= 2, got {num_shards}")
        if num_shards > device_count:
            raise ValueError(
                f"num_shards={num_shards} exceeds device_count="
                f"{device_count}")
        if extent % num_shards:
            raise ValueError(
                f"num_shards={num_shards} does not divide the decomposed "
                f"extent {extent}")
        return num_shards
    for s in range(min(device_count, extent), 1, -1):
        if extent % s == 0:
            return s
    raise ValueError(
        f"no valid shard count for extent {extent} on {device_count} "
        f"device(s)")


def _shard_ok(num_shards: int, extent: int,
              device_count: Optional[int] = None) -> bool:
    """Tunable-space constraint twin of ``resolve_num_shards``.

    ``device_count=None`` reads the live topology; tests (and any caller
    reasoning about a hypothetical host) inject an explicit count.
    """
    if device_count is None:
        device_count = jax.device_count()
    return (num_shards >= 2 and num_shards <= device_count
            and extent % num_shards == 0)


def balanced_pencil_grid(total: int, nz: Optional[int] = None,
                         ny: Optional[int] = None):
    """Deterministic most-balanced ``(sz, sy)`` with ``sz * sy == total``
    and both factors >= 2, optionally constrained to divide the ``nz``/
    ``ny`` extents.  ``None`` when no such grid exists (e.g. total=2 has
    no true 2-D grid).  Every factorization is considered (a short z axis
    may only admit ``sy > sz``); ties prefer the z-major grid.  Single
    source of the pencil-picking policy — the scaling benchmark's
    recorded grids must match what the registry resolves."""
    pairs = [(total // sy, sy) for sy in range(2, total // 2 + 1)
             if total % sy == 0 and total // sy >= 2]
    pairs.sort(key=lambda p: (abs(p[0] - p[1]), p[0] < p[1]))
    for sz, sy in pairs:
        if nz is not None and nz % sz:
            continue
        if ny is not None and ny % sy:
            continue
        return sz, sy
    return None


def resolve_shard_grid(nz: int, ny: int, *, decomp: str = "slab",
                       shard_grid=None, num_shards: Optional[int] = None,
                       device_count: Optional[int] = None):
    """Validate or pick the ``(sz, sy)`` shard grid for the stencil.

    ``decomp="slab"`` decomposes z only (``sy == 1``; ``num_shards`` is the
    legacy alias for ``sz``); ``decomp="pencil"`` splits z *and* y
    (``sz, sy >= 2``).  A valid grid divides both decomposed extents and
    fits in the device count.  With no explicit grid, slab reuses
    ``resolve_num_shards`` and pencil deterministically picks the largest
    total shard count, most-balanced grid first.
    """
    if decomp not in STENCIL_DECOMPS:
        raise ValueError(
            f"unknown decomp {decomp!r}; expected one of {STENCIL_DECOMPS}")
    if device_count is None:
        device_count = jax.device_count()
    if shard_grid is None:
        if decomp == "slab":
            return resolve_num_shards(nz, num_shards, device_count), 1
        totals = ([num_shards] if num_shards is not None
                  else range(device_count, 3, -1))
        for total in totals:
            if total > device_count:
                break
            grid = balanced_pencil_grid(total, nz, ny)
            if grid is not None:
                return grid
        raise ValueError(
            f"no valid pencil grid for extents ({nz}, {ny}) on "
            f"{device_count} device(s)"
            + (f" with num_shards={num_shards}" if num_shards else ""))
    sz, sy = (int(shard_grid[0]), int(shard_grid[1]))
    if num_shards is not None and num_shards != sz * sy:
        raise ValueError(
            f"num_shards={num_shards} contradicts shard_grid=({sz}, {sy})")
    if decomp == "slab" and sy != 1:
        raise ValueError(f"slab decomposition needs sy=1, got sy={sy}")
    if decomp == "pencil" and (sz < 2 or sy < 2):
        raise ValueError(
            f"pencil decomposition needs sz, sy >= 2, got ({sz}, {sy})")
    if sz * sy < 2:
        raise ValueError(f"shard grid ({sz}, {sy}) has fewer than 2 shards")
    if sz * sy > device_count:
        raise ValueError(
            f"shard grid ({sz}, {sy}) needs {sz * sy} devices, have "
            f"{device_count}")
    if nz % sz or ny % sy:
        raise ValueError(
            f"shard grid ({sz}, {sy}) does not divide extents ({nz}, {ny})")
    return sz, sy


def _stencil_point_ok(p, nz: int, ny: int,
                      device_count: Optional[int] = None) -> bool:
    """Tunable-space constraint twin of ``resolve_shard_grid``."""
    if device_count is None:
        device_count = jax.device_count()
    try:
        sz, sy = (int(x) for x in p["shard_grid"])
    except (KeyError, TypeError, ValueError):
        return False
    if sz * sy < 2 or sz * sy > device_count:
        return False
    if nz % sz or ny % sy:
        return False
    if p.get("decomp") == "pencil":
        return sz >= 2 and sy >= 2
    return sy == 1 and sz >= 2


# --------------------------------------------------------------------------
# stencil7: slab / pencil decomposition + (optionally overlapped) halo
# exchange
# --------------------------------------------------------------------------
def _boundary_keep(extent, idx, n_shards):
    """Per-plane keep mask along one decomposed axis: the first/last local
    plane is zeroed on the shards owning the *global* boundary (the oracle
    fixes boundary cells to 0; with one plane per shard the two edges are
    the same plane and both conditions AND together)."""
    return (jnp.ones((extent,), bool).at[0].set(idx != 0)
            & jnp.ones((extent,), bool).at[-1].set(idx != n_shards - 1))


def _slab_local(u, num_shards, coeffs, overlap):
    """One shard of the 1-D slab decomposition (z split)."""
    lo, hi = collectives.halo_exchange(u, AXIS, num_shards, axis=0)
    if overlap and u.shape[0] >= 2:
        # double-buffered: the ppermutes above have no data dependency on
        # the interior stencil, so XLA overlaps the halo traffic with the
        # O(volume) compute on the local buffer; only the two O(surface)
        # boundary planes wait for the halos and get patched afterwards.
        # Same per-element expression as the oracle -> bitwise equal.
        out = s7_ref.laplacian(u, *coeffs)
        lo_plane = s7_ref.laplacian(
            jnp.concatenate([lo, u[:2]], axis=0), *coeffs)[1:2]
        hi_plane = s7_ref.laplacian(
            jnp.concatenate([u[-2:], hi], axis=0), *coeffs)[1:2]
        out = out.at[:1].set(lo_plane).at[-1:].set(hi_plane)
    else:
        # one plane per shard has no halo-free interior: plain exchange
        padded = jnp.concatenate([lo, u, hi], axis=0)
        out = s7_ref.laplacian(padded, *coeffs)[1:-1]
    keep = _boundary_keep(out.shape[0], lax.axis_index(AXIS), num_shards)
    return jnp.where(keep[:, None, None], out, jnp.zeros_like(out))


def _pencil_local(u, sz, sy, coeffs, overlap):
    """One shard of the 2-D pencil decomposition (z and y split)."""
    if overlap and u.shape[0] >= 2 and u.shape[1] >= 2:
        # all four ppermutes are issued on the raw block up front (the
        # seven-point stencil has no corner coupling, so per-axis halos of
        # the *unpadded* block suffice), the halo-free interior overlaps
        # with them, and four thin O(surface) slabs patch the boundary.
        (lo_z, hi_z), (lo_y, hi_y) = collectives.halo_exchange_nd(
            u, (AXIS_Z, AXIS_Y), (sz, sy), axes=(0, 1))
        out = s7_ref.laplacian(u, *coeffs)
        uz = jnp.concatenate([lo_z, u, hi_z], axis=0)
        uy = jnp.concatenate([lo_y, u, hi_y], axis=1)
        # z-boundary planes: 3-plane slabs, middle plane's y-halos attached
        # (its y-edge cells read the y-neighbour); the outer planes' y-pads
        # are stencil-dead corners and stay zero
        slab = jnp.pad(uz[0:3], ((0, 0), (1, 1), (0, 0)))
        slab = slab.at[1:2, :1].set(lo_y[:1]).at[1:2, -1:].set(hi_y[:1])
        z_lo = s7_ref.laplacian(slab, *coeffs)[1:2, 1:-1]
        slab = jnp.pad(uz[-3:], ((0, 0), (1, 1), (0, 0)))
        slab = slab.at[1:2, :1].set(lo_y[-1:]).at[1:2, -1:].set(hi_y[-1:])
        z_hi = s7_ref.laplacian(slab, *coeffs)[1:2, 1:-1]
        # y-boundary rows: 3-column slabs, middle column's z-halos attached
        slab = jnp.pad(uy[:, 0:3], ((1, 1), (0, 0), (0, 0)))
        slab = slab.at[:1, 1:2].set(lo_z[:, :1]).at[-1:, 1:2].set(
            hi_z[:, :1])
        y_lo = s7_ref.laplacian(slab, *coeffs)[1:-1, 1:2]
        slab = jnp.pad(uy[:, -3:], ((1, 1), (0, 0), (0, 0)))
        slab = slab.at[:1, 1:2].set(lo_z[:, -1:]).at[-1:, 1:2].set(
            hi_z[:, -1:])
        y_hi = s7_ref.laplacian(slab, *coeffs)[1:-1, 1:2]
        # corner cells appear in both a z- and a y-patch; both compute the
        # identical expression on identical values, so order is irrelevant
        out = out.at[:1].set(z_lo).at[-1:].set(z_hi)
        out = out.at[:, :1].set(y_lo).at[:, -1:].set(y_hi)
    else:
        # staged exchange: z first, then y on the z-padded block (the
        # second exchange carries the corner rows for free)
        lo_z, hi_z = collectives.halo_exchange(u, AXIS_Z, sz, axis=0)
        uz = jnp.concatenate([lo_z, u, hi_z], axis=0)
        lo_y, hi_y = collectives.halo_exchange(uz, AXIS_Y, sy, axis=1)
        padded = jnp.concatenate([lo_y, uz, hi_y], axis=1)
        out = s7_ref.laplacian(padded, *coeffs)[1:-1, 1:-1]
    keep_z = _boundary_keep(out.shape[0], lax.axis_index(AXIS_Z), sz)
    keep_y = _boundary_keep(out.shape[1], lax.axis_index(AXIS_Y), sy)
    keep = keep_z[:, None, None] & keep_y[None, :, None]
    return jnp.where(keep, out, jnp.zeros_like(out))


@functools.lru_cache(maxsize=None)
def _stencil_sharded(sz, sy, overlap, invhx2, invhy2, invhz2, invhxyz2):
    # audit: compile-time-constant(invhx2, invhy2, invhz2, invhxyz2) —
    # grid-spacing coefficients are fixed for a given problem and baking
    # them matches the single-device backends' static_argnames contract
    coeffs = (invhx2, invhy2, invhz2, invhxyz2)
    if sy == 1:
        mesh, spec = shard_mesh(sz), P(AXIS)
        local = functools.partial(_slab_local, num_shards=sz, coeffs=coeffs,
                                  overlap=overlap)
    else:
        mesh, spec = shard_mesh2d(sz, sy), P(AXIS_Z, AXIS_Y)
        local = functools.partial(_pencil_local, sz=sz, sy=sy, coeffs=coeffs,
                                  overlap=overlap)
    return jax.jit(shard_map(local, mesh, in_specs=spec, out_specs=spec))


def laplacian_shard(u, invhx2=1.0, invhy2=1.0, invhz2=1.0, invhxyz2=-6.0,
                    *, num_shards: Optional[int] = None,
                    decomp: str = "slab", shard_grid=None,
                    overlap: bool = False):
    """Domain-decomposed seven-point stencil.

    ``decomp="slab"`` splits z across ``num_shards`` devices (PR-3
    behaviour); ``decomp="pencil"`` splits z and y across a
    ``shard_grid=(sz, sy)`` device mesh.  ``overlap=True`` issues the halo
    ``ppermute``s first, computes the halo-free interior while they are in
    flight, then patches the boundary planes — all variants are bitwise
    equal to the single-device oracle.
    """
    sz, sy = resolve_shard_grid(u.shape[0], u.shape[1], decomp=decomp,
                                shard_grid=shard_grid, num_shards=num_shards)
    return _stencil_sharded(sz, sy, bool(overlap), invhx2, invhy2, invhz2,
                            invhxyz2)(u)


# --------------------------------------------------------------------------
# BabelStream: block-partitioned arrays, psum dot
# --------------------------------------------------------------------------
def _dot_local(a, b):
    # partials stay in the accumulation dtype across the psum (the oracle
    # only downcasts once, at the very end)
    acc = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) else a.dtype
    part = jnp.sum(a.astype(acc) * b.astype(acc))
    return lax.psum(part, AXIS).astype(a.dtype)


_STREAM_LOCAL = {
    "copy": (stream_ref.copy, 1, False),
    "mul": (stream_ref.mul, 1, True),
    "add": (stream_ref.add, 2, False),
    "triad": (stream_ref.triad, 2, True),
    "dot": (_dot_local, 2, False),
}


@functools.lru_cache(maxsize=None)
def _stream_sharded(op, num_shards):
    mesh = shard_mesh(num_shards)
    body, nargs, takes_scalar = _STREAM_LOCAL[op]
    out_spec = P() if op == "dot" else P(AXIS)
    if takes_scalar:
        # the scalar is a *traced*, replicated argument — baking it into
        # this cache key would compile (and pin) one jitted program per
        # distinct Python float
        def local(*args):
            return body(*args[:-1], scalar=args[-1])
        in_specs = (P(AXIS),) * nargs + (P(),)
    else:
        local, in_specs = body, (P(AXIS),) * nargs
    return jax.jit(shard_map(local, mesh, in_specs=in_specs,
                             out_specs=out_spec))


def _make_stream_shard(op, nargs, takes_scalar):
    if takes_scalar:
        def run(*args, scalar: Optional[float] = None,
                num_shards: Optional[int] = None):
            arrays, rest = args[:nargs], args[nargs:]
            if rest:
                scalar = rest[0]
            elif scalar is None:
                scalar = stream_ref.START_SCALAR
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            return _stream_sharded(op, s)(
                *arrays, jnp.asarray(scalar, arrays[0].dtype))
    else:
        def run(*arrays, num_shards: Optional[int] = None):
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            return _stream_sharded(op, s)(*arrays)
    run.__name__ = f"{op}_shard"
    return run


def stream_shard_fns():
    """op name -> sharded backend fn, signatures matching the xla oracle."""
    return {op: _make_stream_shard(op, nargs, takes_scalar)
            for op, (_, nargs, takes_scalar) in _STREAM_LOCAL.items()}


# --------------------------------------------------------------------------
# miniBUDE: pose-parallel
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _fasten_sharded(num_shards):
    mesh = shard_mesh(num_shards)
    # decks replicate, poses (6, P) shard along P; per-pose energies are
    # independent, so out_specs concatenation reassembles the exact result
    return jax.jit(shard_map(
        mb_ref.fasten, mesh,
        in_specs=(P(), P(), P(), P(), P(None, AXIS)),
        out_specs=P(AXIS)))


def fasten_shard(protein_pos, protein_par, ligand_pos, ligand_par, poses,
                 *, num_shards: Optional[int] = None):
    """Pose-parallel miniBUDE energy evaluation."""
    s = resolve_num_shards(poses.shape[1], num_shards)
    return _fasten_sharded(s)(protein_pos, protein_par, ligand_pos,
                              ligand_par, poses)


# --------------------------------------------------------------------------
# Hartree-Fock: l-slab quartet decomposition, psum Fock accumulation
# --------------------------------------------------------------------------
def _eri_slab(positions, basis, l0, ls):
    """(N, N, N, ls) slab of the ERI tensor: all (ij|kl) with l in
    [l0, l0+ls).  Mirrors ``hf_ref.eri_tensor`` with the second pair's l
    index restricted (``l0`` may be traced; ``ls`` is static)."""
    N = positions.shape[0]
    G2 = basis.ngauss ** 2
    p, Pc, Kab = hf_ref._pair_tables(positions, basis)

    def body(eri, ab):
        a, b = ab // G2, ab % G2
        pa, qb = p[a], p[b]
        Pb = lax.dynamic_slice_in_dim(Pc[b], l0, ls, axis=1)   # (N, ls, 3)
        Kb = lax.dynamic_slice_in_dim(Kab[b], l0, ls, axis=1)  # (N, ls)
        pq_d2 = jnp.sum((Pc[a][:, :, None, None, :]
                         - Pb[None, None, :, :, :]) ** 2, -1)
        t = (pa * qb / (pa + qb)) * pq_d2
        pref = hf_ref.TWO_PI_POW_2_5 / (pa * qb * jnp.sqrt(pa + qb))
        eri = eri + (pref * hf_ref.boys_f0(t)
                     * Kab[a][:, :, None, None] * Kb[None, None, :, :])
        return eri, None

    eri0 = jnp.zeros((N, N, N, ls), positions.dtype)
    eri, _ = lax.scan(body, eri0, jnp.arange(G2 * G2))
    return eri


@functools.lru_cache(maxsize=None)
def _fock_sharded(num_shards, ngauss):
    mesh = shard_mesh(num_shards)

    def local(positions, density):
        basis = hf_ref.sto_basis(ngauss, positions.dtype)
        N = positions.shape[0]
        ls = N // num_shards
        l0 = lax.axis_index(AXIS) * ls
        eri = _eri_slab(positions, basis, l0, ls)
        d_slab = lax.dynamic_slice_in_dim(density, l0, ls, axis=1)
        # F[i,j] = sum_kl D[k,l] (2 (ij|kl) - (ik|jl)); both terms read
        # the same l-slab, so each device owns a disjoint set of quartet
        # contributions and psum replaces the paper's atomic scatter-adds
        j_term = 2.0 * jnp.einsum("ijkl,kl->ij", eri, d_slab)
        k_term = jnp.einsum("ikjl,kl->ij", eri, d_slab)
        return lax.psum(j_term - k_term, AXIS)

    return jax.jit(shard_map(local, mesh, in_specs=(P(), P()),
                             out_specs=P()))


def fock_shard(positions, density, *, ngauss: int = 3,
               num_shards: Optional[int] = None):
    """Distributed two-electron Fock build (quartets sharded over l)."""
    s = resolve_num_shards(positions.shape[0], num_shards)
    return _fock_sharded(s, ngauss)(positions, density)


# --------------------------------------------------------------------------
# registration: plug into the existing PortableKernel registry
# --------------------------------------------------------------------------
#: collective traffic of the 1-D sharded families (static-auditor contract)
NO_COLLECTIVES = {"ppermute": 0, "psum": 0, "all_gather": 0}
ONE_PSUM = {"ppermute": 0, "psum": 1, "all_gather": 0}


def stencil_comm_contract(u, *args):
    """Audited variants of the sharded stencil: a slab step exchanges two
    halos (one ppermute each way), a pencil step four (two axes); the
    overlap variants pin a shard grid leaving >= 2 local planes (the
    one-plane-per-shard edge legitimately falls back to plain exchange)
    and additionally require an interior compute of the full local-block
    shape with no data dependency on the halo ppermutes."""
    nz, ny, nx = u.shape
    variants = [
        ({"decomp": "slab"}, {**NO_COLLECTIVES, "ppermute": 2}),
        ({"decomp": "pencil"}, {**NO_COLLECTIVES, "ppermute": 4}),
    ]
    for sz in (4, 2):
        if nz % sz == 0 and nz // sz >= 2:
            variants.append((
                {"decomp": "slab", "shard_grid": (sz, 1), "overlap": True},
                {**NO_COLLECTIVES, "ppermute": 2,
                 "overlap_shape": (nz // sz, ny, nx)}))
            break
    if nz % 2 == 0 and ny % 2 == 0 and nz // 2 >= 2 and ny // 2 >= 2:
        variants.append((
            {"decomp": "pencil", "shard_grid": (2, 2), "overlap": True},
            {**NO_COLLECTIVES, "ppermute": 4,
             "overlap_shape": (nz // 2, ny // 2, nx)}))
    return variants


def register_sharded_backends() -> None:
    """Attach ``xla_shard`` backends + ``num_shards`` tunables to every
    science-kernel family already in the registry.  Idempotent."""
    k = get_kernel("stencil7")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, laplacian_shard, available=multi_device)
        # the decomposition *shape* is a tunable, not a hard-coded choice:
        # the sweep walks slab vs pencil grids and halo/compute overlap
        k.declare_tunables(
            SHARD_BACKEND, decomp=STENCIL_DECOMPS,
            shard_grid=STENCIL_SHARD_GRIDS, overlap=OVERLAP_GRID,
            constraint=lambda p, u, *a, device_count=None, **kw:
                _stencil_point_ok(p, u.shape[0], u.shape[1], device_count))
        k.declare_comm_contract(SHARD_BACKEND, stencil_comm_contract)

    for op, fn in stream_shard_fns().items():
        k = get_kernel(f"babelstream.{op}")
        if SHARD_BACKEND in k.backends:
            continue
        k.add_backend(SHARD_BACKEND, fn, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, *arrays, device_count=None, **kw:
                _shard_ok(p["num_shards"], arrays[0].shape[0], device_count))
        # dot combines per-block partials with one psum; the elementwise
        # ops are embarrassingly parallel
        k.declare_comm_contract(
            SHARD_BACKEND, ONE_PSUM if op == "dot" else NO_COLLECTIVES)
        # sharding does not change the streaming AI: still memory-bound
        # on every modeled chip
        k.declare_roofline_contract(SHARD_BACKEND, bound="memory")

    k = get_kernel("minibude.fasten")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, fasten_shard, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, *deck, device_count=None, **kw:
                _shard_ok(p["num_shards"], deck[4].shape[1], device_count))
        k.declare_comm_contract(SHARD_BACKEND, NO_COLLECTIVES)

    k = get_kernel("hartree_fock.twoel")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, fock_shard, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, positions, *a, device_count=None, **kw:
                _shard_ok(p["num_shards"], positions.shape[0], device_count))
        # per-device Fock partials accumulate with exactly one psum
        k.declare_comm_contract(SHARD_BACKEND, ONE_PSUM)
        # O(N^4) work dwarfs the one Fock psum: compute-bound everywhere
        k.declare_roofline_contract(SHARD_BACKEND, bound="compute")


# importing the ops modules (not the package, to stay cycle-safe when
# repro.kernels.__init__ imports this module last) registers the base
# kernels; we then attach the sharded backends on top
import repro.kernels.babelstream.ops  # noqa: E402,F401
import repro.kernels.hartree_fock.ops  # noqa: E402,F401
import repro.kernels.minibude.ops  # noqa: E402,F401
import repro.kernels.stencil7.ops  # noqa: E402,F401

register_sharded_backends()
