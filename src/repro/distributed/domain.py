"""Domain decomposition: the science kernels as multi-device registry backends.

The paper measures portability across *compiler backends* on one GPU; the
Eq.-4 methodology generalizes to the device-count axis (Godoy et al., 2023
run these same workloads across full exascale nodes).  This module supplies
that axis: each science-kernel family gains an ``xla_shard`` backend that
runs the oracle arithmetic under ``jax.shard_map`` over a 1-D device mesh —

  * **stencil7** — 1-D slab decomposition along z with a one-plane
    ``ppermute`` halo exchange (``collectives.halo_exchange``); each shard
    applies the unchanged oracle stencil to its halo-padded slab, so the
    sharded field is *bitwise identical* to the single-device result
    (elementwise arithmetic, no cross-shard reductions);
  * **babelstream** — block-partitioned 1-D arrays; copy/mul/add/triad are
    embarrassingly parallel (bitwise identical), ``dot`` reduces each block
    locally in the accumulation dtype and combines partials with ``psum``;
  * **minibude.fasten** — pose-parallel: poses shard across devices, the
    protein/ligand decks replicate, per-pose energies are independent
    (bitwise identical);
  * **hartree_fock.twoel** — each device computes the ERI slab for its range
    of the *l* quartet index, contracts it with the matching density
    columns, and the partial Fock matrices accumulate with ``psum`` — the
    distributed analogue of the paper's atomic scatter-adds, without the
    contention.

Backends register in the existing ``PortableKernel`` registry with
``available = device_count >= 2`` and a tunable ``num_shards`` grid, so
``repro.core.tuning`` and the Eq.-4 sweep extend to the device axis with no
registry changes.  On a CPU host, simulate devices with
``repro.launch.hostsim.ensure_host_device_count(8)`` *before* importing jax
(``benchmarks/scaling.py`` and ``repro.distributed.selftest`` do).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.portable import get_kernel
from repro.distributed import collectives
from repro.kernels.babelstream import ref as stream_ref
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import ref as mb_ref
from repro.kernels.stencil7 import ref as s7_ref

__all__ = [
    "AXIS",
    "SHARD_BACKEND",
    "shard_mesh",
    "multi_device",
    "resolve_num_shards",
    "laplacian_shard",
    "stream_shard_fns",
    "fasten_shard",
    "fock_shard",
    "register_sharded_backends",
]

#: mesh axis name every sharded kernel maps over
AXIS = "shards"
#: registry backend name (xla arithmetic + sharding, hence the prefix)
SHARD_BACKEND = "xla_shard"
#: num_shards grid declared to the autotuner
SHARD_GRID = (2, 4, 8)


def multi_device() -> bool:
    """Availability predicate for every ``xla_shard`` backend."""
    try:
        return jax.device_count() >= 2
    except Exception:  # pragma: no cover - no jax backend at all
        return False


@functools.lru_cache(maxsize=None)
def shard_mesh(num_shards: int) -> Mesh:
    """1-D mesh over the first ``num_shards`` local devices."""
    devices = jax.devices()
    if num_shards > len(devices):
        raise ValueError(
            f"num_shards={num_shards} exceeds the {len(devices)} available "
            f"device(s)")
    return Mesh(np.array(devices[:num_shards]), (AXIS,))


def resolve_num_shards(extent: int, num_shards: Optional[int] = None,
                       device_count: Optional[int] = None) -> int:
    """Validate an explicit shard count, or pick the largest usable one.

    ``extent`` is the decomposed axis length; a valid count divides it, is
    at least 2, and does not exceed the device count.  ``num_shards=None``
    chooses the largest valid count (deterministic), raising when even 2
    shards cannot be used.
    """
    if device_count is None:
        device_count = jax.device_count()
    if num_shards is not None:
        if num_shards < 2:
            raise ValueError(f"num_shards must be >= 2, got {num_shards}")
        if num_shards > device_count:
            raise ValueError(
                f"num_shards={num_shards} exceeds device_count="
                f"{device_count}")
        if extent % num_shards:
            raise ValueError(
                f"num_shards={num_shards} does not divide the decomposed "
                f"extent {extent}")
        return num_shards
    for s in range(min(device_count, extent), 1, -1):
        if extent % s == 0:
            return s
    raise ValueError(
        f"no valid shard count for extent {extent} on {device_count} "
        f"device(s)")


def _shard_ok(num_shards: int, extent: int) -> bool:
    """Tunable-space constraint twin of ``resolve_num_shards``."""
    return (num_shards >= 2 and num_shards <= jax.device_count()
            and extent % num_shards == 0)


# --------------------------------------------------------------------------
# stencil7: 1-D slab decomposition + halo exchange
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _stencil_sharded(num_shards, invhx2, invhy2, invhz2, invhxyz2):
    mesh = shard_mesh(num_shards)

    def local(u):
        # one-plane halos from both z-neighbours (zeros at the open ends)
        lo, hi = collectives.halo_exchange(u, AXIS, num_shards, axis=0)
        padded = jnp.concatenate([lo, u, hi], axis=0)
        # the oracle on the halo-padded slab: identical per-element
        # arithmetic to the single-device backend, so interior planes are
        # bitwise equal; its zero-padding already handles the y/x faces
        out = s7_ref.laplacian(padded, invhx2, invhy2, invhz2,
                               invhxyz2)[1:-1]
        # global z-boundary planes are *boundary*, not interior-with-a-
        # zero-halo: force them to the oracle's zero on the edge shards
        idx = lax.axis_index(AXIS)
        nz = out.shape[0]
        keep = (jnp.ones((nz,), bool).at[0].set(idx != 0)
                & jnp.ones((nz,), bool).at[-1].set(idx != num_shards - 1))
        return jnp.where(keep[:, None, None], out, jnp.zeros_like(out))

    return jax.jit(shard_map(local, mesh, in_specs=P(AXIS),
                             out_specs=P(AXIS)))


def laplacian_shard(u, invhx2=1.0, invhy2=1.0, invhz2=1.0, invhxyz2=-6.0,
                    *, num_shards: Optional[int] = None):
    """Slab-decomposed seven-point stencil (z axis split across devices)."""
    s = resolve_num_shards(u.shape[0], num_shards)
    return _stencil_sharded(s, invhx2, invhy2, invhz2, invhxyz2)(u)


# --------------------------------------------------------------------------
# BabelStream: block-partitioned arrays, psum dot
# --------------------------------------------------------------------------
def _dot_local(a, b):
    # partials stay in the accumulation dtype across the psum (the oracle
    # only downcasts once, at the very end)
    acc = jnp.float32 if a.dtype in (jnp.bfloat16, jnp.float16) else a.dtype
    part = jnp.sum(a.astype(acc) * b.astype(acc))
    return lax.psum(part, AXIS).astype(a.dtype)


_STREAM_LOCAL = {
    "copy": (stream_ref.copy, 1, False),
    "mul": (stream_ref.mul, 1, True),
    "add": (stream_ref.add, 2, False),
    "triad": (stream_ref.triad, 2, True),
    "dot": (_dot_local, 2, False),
}


@functools.lru_cache(maxsize=None)
def _stream_sharded(op, num_shards, scalar):
    mesh = shard_mesh(num_shards)
    body, nargs, takes_scalar = _STREAM_LOCAL[op]
    local = functools.partial(body, scalar=scalar) if takes_scalar else body
    out_spec = P() if op == "dot" else P(AXIS)
    return jax.jit(shard_map(local, mesh, in_specs=(P(AXIS),) * nargs,
                             out_specs=out_spec))


def _make_stream_shard(op, nargs, takes_scalar):
    if takes_scalar:
        def run(*args, scalar: Optional[float] = None,
                num_shards: Optional[int] = None):
            arrays, rest = args[:nargs], args[nargs:]
            if rest:
                scalar = rest[0]
            elif scalar is None:
                scalar = stream_ref.START_SCALAR
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            return _stream_sharded(op, s, float(scalar))(*arrays)
    else:
        def run(*arrays, num_shards: Optional[int] = None):
            s = resolve_num_shards(arrays[0].shape[0], num_shards)
            return _stream_sharded(op, s, None)(*arrays)
    run.__name__ = f"{op}_shard"
    return run


def stream_shard_fns():
    """op name -> sharded backend fn, signatures matching the xla oracle."""
    return {op: _make_stream_shard(op, nargs, takes_scalar)
            for op, (_, nargs, takes_scalar) in _STREAM_LOCAL.items()}


# --------------------------------------------------------------------------
# miniBUDE: pose-parallel
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _fasten_sharded(num_shards):
    mesh = shard_mesh(num_shards)
    # decks replicate, poses (6, P) shard along P; per-pose energies are
    # independent, so out_specs concatenation reassembles the exact result
    return jax.jit(shard_map(
        mb_ref.fasten, mesh,
        in_specs=(P(), P(), P(), P(), P(None, AXIS)),
        out_specs=P(AXIS)))


def fasten_shard(protein_pos, protein_par, ligand_pos, ligand_par, poses,
                 *, num_shards: Optional[int] = None):
    """Pose-parallel miniBUDE energy evaluation."""
    s = resolve_num_shards(poses.shape[1], num_shards)
    return _fasten_sharded(s)(protein_pos, protein_par, ligand_pos,
                              ligand_par, poses)


# --------------------------------------------------------------------------
# Hartree-Fock: l-slab quartet decomposition, psum Fock accumulation
# --------------------------------------------------------------------------
def _eri_slab(positions, basis, l0, ls):
    """(N, N, N, ls) slab of the ERI tensor: all (ij|kl) with l in
    [l0, l0+ls).  Mirrors ``hf_ref.eri_tensor`` with the second pair's l
    index restricted (``l0`` may be traced; ``ls`` is static)."""
    N = positions.shape[0]
    G2 = basis.ngauss ** 2
    p, Pc, Kab = hf_ref._pair_tables(positions, basis)

    def body(eri, ab):
        a, b = ab // G2, ab % G2
        pa, qb = p[a], p[b]
        Pb = lax.dynamic_slice_in_dim(Pc[b], l0, ls, axis=1)   # (N, ls, 3)
        Kb = lax.dynamic_slice_in_dim(Kab[b], l0, ls, axis=1)  # (N, ls)
        pq_d2 = jnp.sum((Pc[a][:, :, None, None, :]
                         - Pb[None, None, :, :, :]) ** 2, -1)
        t = (pa * qb / (pa + qb)) * pq_d2
        pref = hf_ref.TWO_PI_POW_2_5 / (pa * qb * jnp.sqrt(pa + qb))
        eri = eri + (pref * hf_ref.boys_f0(t)
                     * Kab[a][:, :, None, None] * Kb[None, None, :, :])
        return eri, None

    eri0 = jnp.zeros((N, N, N, ls), positions.dtype)
    eri, _ = lax.scan(body, eri0, jnp.arange(G2 * G2))
    return eri


@functools.lru_cache(maxsize=None)
def _fock_sharded(num_shards, ngauss):
    mesh = shard_mesh(num_shards)

    def local(positions, density):
        basis = hf_ref.sto_basis(ngauss, positions.dtype)
        N = positions.shape[0]
        ls = N // num_shards
        l0 = lax.axis_index(AXIS) * ls
        eri = _eri_slab(positions, basis, l0, ls)
        d_slab = lax.dynamic_slice_in_dim(density, l0, ls, axis=1)
        # F[i,j] = sum_kl D[k,l] (2 (ij|kl) - (ik|jl)); both terms read
        # the same l-slab, so each device owns a disjoint set of quartet
        # contributions and psum replaces the paper's atomic scatter-adds
        j_term = 2.0 * jnp.einsum("ijkl,kl->ij", eri, d_slab)
        k_term = jnp.einsum("ikjl,kl->ij", eri, d_slab)
        return lax.psum(j_term - k_term, AXIS)

    return jax.jit(shard_map(local, mesh, in_specs=(P(), P()),
                             out_specs=P()))


def fock_shard(positions, density, *, ngauss: int = 3,
               num_shards: Optional[int] = None):
    """Distributed two-electron Fock build (quartets sharded over l)."""
    s = resolve_num_shards(positions.shape[0], num_shards)
    return _fock_sharded(s, ngauss)(positions, density)


# --------------------------------------------------------------------------
# registration: plug into the existing PortableKernel registry
# --------------------------------------------------------------------------
def register_sharded_backends() -> None:
    """Attach ``xla_shard`` backends + ``num_shards`` tunables to every
    science-kernel family already in the registry.  Idempotent."""
    k = get_kernel("stencil7")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, laplacian_shard, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, u, *a, **kw:
                _shard_ok(p["num_shards"], u.shape[0]))

    for op, fn in stream_shard_fns().items():
        k = get_kernel(f"babelstream.{op}")
        if SHARD_BACKEND in k.backends:
            continue
        k.add_backend(SHARD_BACKEND, fn, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, *arrays, **kw:
                _shard_ok(p["num_shards"], arrays[0].shape[0]))

    k = get_kernel("minibude.fasten")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, fasten_shard, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, *deck, **kw:
                _shard_ok(p["num_shards"], deck[4].shape[1]))

    k = get_kernel("hartree_fock.twoel")
    if SHARD_BACKEND not in k.backends:
        k.add_backend(SHARD_BACKEND, fock_shard, available=multi_device)
        k.declare_tunables(
            SHARD_BACKEND, num_shards=SHARD_GRID,
            constraint=lambda p, positions, *a, **kw:
                _shard_ok(p["num_shards"], positions.shape[0]))


# importing the ops modules (not the package, to stay cycle-safe when
# repro.kernels.__init__ imports this module last) registers the base
# kernels; we then attach the sharded backends on top
import repro.kernels.babelstream.ops  # noqa: E402,F401
import repro.kernels.hartree_fock.ops  # noqa: E402,F401
import repro.kernels.minibude.ops  # noqa: E402,F401
import repro.kernels.stencil7.ops  # noqa: E402,F401

register_sharded_backends()
