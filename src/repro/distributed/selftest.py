"""Multi-device self-test for the domain-decomposition subsystem.

    PYTHONPATH=src python -m repro.distributed.selftest [--devices 8]

Runs on simulated host devices (``hostsim`` appends
``--xla_force_host_platform_device_count`` before jax initializes — an
XLA_FLAGS value you already exported is respected).  The test suite invokes
this module in a subprocess (``tests/test_distributed_domain.py``) because
pytest's process has already pinned jax to the 1-device topology.

Checks, each against the single-device ``xla`` oracle:
  * stencil7 slab decomposition is **bitwise identical** at 2/4/8 shards —
    and so are the 2-D pencil grids ((2,2)/(4,2)/(2,4)) and the
    halo/compute-overlap variants of both decompositions, including the
    one-plane-per-shard edge case of the boundary mask;
  * the halo exchange round-trips shard-boundary planes (zeros at the open
    ends), wraps periodically with ``wrap=True``, and moves ``halo``-thick
    multi-plane slabs;
  * BabelStream copy/mul/add/triad are bitwise identical; ``dot`` matches
    within fp32 reduction tolerance (psum changes the summation order);
    scalar ops trace the scalar (two scalars share one compiled program);
  * miniBUDE pose-parallel energies are bitwise identical;
  * Hartree-Fock psum-accumulated Fock matrices match within oracle
    tolerance;
  * divisibility / device-count constraints raise ``ValueError`` and the
    autotuner sweeps the decomp/shard-grid/overlap axes through the
    unchanged registry path (tuple-valued tunables round-trip the cache).
"""

from __future__ import annotations

import argparse
import sys


def _check_stencil(np, jnp, get_kernel, shard_counts):
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16, 32)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(u, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"stencil7 xla_shard num_shards={s} is not bitwise equal"
    # default shard-count resolution also matches
    got = np.asarray(k(u, backend="xla_shard"))
    assert np.array_equal(want, got), "stencil7 auto num_shards mismatch"
    print(f"  stencil7: bitwise equal at shards {shard_counts} + auto")


def _check_stencil_pencil(np, jnp, get_kernel, n_devices):
    if n_devices < 4:
        print("  stencil7: pencil checks skipped (< 4 devices)")
        return
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(3).standard_normal((16, 16, 32)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    grids = [g for g in ((2, 2), (4, 2), (2, 4))
             if g[0] * g[1] <= n_devices]
    for grid in grids:
        for overlap in (False, True):
            got = np.asarray(k(u, backend="xla_shard", decomp="pencil",
                               shard_grid=grid, overlap=overlap))
            assert np.array_equal(want, got), \
                f"stencil7 pencil grid={grid} overlap={overlap} mismatch"
    # slab overlap variant, and auto pencil-grid resolution
    for s in (2, 4):
        got = np.asarray(k(u, backend="xla_shard", decomp="slab",
                           shard_grid=(s, 1), overlap=True))
        assert np.array_equal(want, got), f"stencil7 slab+overlap s={s}"
    got = np.asarray(k(u, backend="xla_shard", decomp="pencil"))
    assert np.array_equal(want, got), "stencil7 auto pencil grid mismatch"
    print(f"  stencil7: pencil grids {grids} and overlap variants "
          f"bitwise equal")


def _check_stencil_one_plane_per_shard(np, jnp, get_kernel, n_devices):
    """nz == num_shards: each shard owns exactly one plane, so its first
    and last local plane coincide and the boundary mask must AND the two
    edge conditions rather than overwrite one with the other."""
    k = get_kernel("stencil7")
    s = min(8, n_devices)
    u = jnp.asarray(np.random.default_rng(4).standard_normal((s, 8, 16)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    for overlap in (False, True):
        got = np.asarray(k(u, backend="xla_shard", num_shards=s,
                           overlap=overlap))
        assert np.array_equal(want, got), \
            f"stencil7 one-plane-per-shard overlap={overlap} mismatch"
    print(f"  stencil7: one plane per shard ({s} shards) bitwise equal")


def _check_halo_exchange(np, jnp, n_shards):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives
    from repro.distributed.domain import AXIS, shard_mesh

    rows = 2 * n_shards
    x = jnp.arange(rows * 3, dtype=jnp.float32).reshape(rows, 3)

    def local(u):
        lo, hi = collectives.halo_exchange(u, AXIS, n_shards, axis=0)
        return jnp.concatenate([lo, hi], axis=0)

    halos = np.asarray(jax.jit(shard_map(
        local, shard_mesh(n_shards), in_specs=P(AXIS),
        out_specs=P(AXIS)))(x))
    xs = np.asarray(x).reshape(n_shards, 2, 3)
    halos = halos.reshape(n_shards, 2, 3)
    for i in range(n_shards):
        want_lo = xs[i - 1][-1] if i > 0 else np.zeros(3)
        want_hi = xs[i + 1][0] if i < n_shards - 1 else np.zeros(3)
        assert np.array_equal(halos[i][0], want_lo), f"halo from_prev {i}"
        assert np.array_equal(halos[i][1], want_hi), f"halo from_next {i}"
    print(f"  halo_exchange: round-trips at {n_shards} shards, "
          f"zero at the open ends")


def _check_halo_wrap_and_multiplane(np, jnp, n_shards):
    """The wrap=True periodic ring and halo>1 multi-plane slabs."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives
    from repro.distributed.domain import AXIS, shard_mesh

    planes = 3
    rows = planes * n_shards
    x = jnp.arange(rows * 2, dtype=jnp.float32).reshape(rows, 2)
    xs = np.asarray(x).reshape(n_shards, planes, 2)

    def run(local):
        return np.asarray(jax.jit(shard_map(
            local, shard_mesh(n_shards), in_specs=P(AXIS),
            out_specs=P(AXIS)))(x))

    # periodic shift: every shard receives its predecessor's block, the
    # first shard wrapping around to the last
    shifted = run(lambda u: collectives.shift(u, AXIS, n_shards, offset=1,
                                              wrap=True))
    shifted = shifted.reshape(n_shards, planes, 2)
    for i in range(n_shards):
        assert np.array_equal(shifted[i], xs[(i - 1) % n_shards]), \
            f"periodic shift shard {i}"

    # halo=2 multi-plane exchange, open ends: the previous shard's trailing
    # two planes / the next shard's leading two planes, zeros at the edges
    halos = run(lambda u: jnp.concatenate(
        collectives.halo_exchange(u, AXIS, n_shards, axis=0, halo=2),
        axis=0)).reshape(n_shards, 4, 2)
    for i in range(n_shards):
        want_lo = xs[i - 1][-2:] if i > 0 else np.zeros((2, 2))
        want_hi = xs[i + 1][:2] if i < n_shards - 1 else np.zeros((2, 2))
        assert np.array_equal(halos[i][:2], want_lo), f"halo=2 prev {i}"
        assert np.array_equal(halos[i][2:], want_hi), f"halo=2 next {i}"

    # halo=2, periodic: the edge shards exchange with each other
    halos = run(lambda u: jnp.concatenate(
        collectives.halo_exchange(u, AXIS, n_shards, axis=0, halo=2,
                                  wrap=True), axis=0))
    halos = halos.reshape(n_shards, 4, 2)
    for i in range(n_shards):
        assert np.array_equal(halos[i][:2], xs[(i - 1) % n_shards][-2:]), \
            f"wrap halo=2 prev {i}"
        assert np.array_equal(halos[i][2:], xs[(i + 1) % n_shards][:2]), \
            f"wrap halo=2 next {i}"
    print(f"  halo_exchange: wrap=True periodic ring and halo=2 "
          f"multi-plane slabs at {n_shards} shards")


def _check_babelstream(np, jnp, get_kernel, shard_counts):
    r = np.random.default_rng(1)
    n = 1 << 12
    a = jnp.asarray(r.standard_normal(n), jnp.float32)
    b = jnp.asarray(r.standard_normal(n), jnp.float32)
    cases = {"copy": (a,), "mul": (a,), "add": (a, b), "triad": (a, b),
             "dot": (a, b)}
    for op, args in cases.items():
        k = get_kernel(f"babelstream.{op}")
        want = np.asarray(k(*args, backend="xla"))
        for s in shard_counts:
            got = np.asarray(k(*args, backend="xla_shard", num_shards=s))
            if op == "dot":
                np.testing.assert_allclose(got, want, rtol=1e-6)
            else:
                assert np.array_equal(want, got), \
                    f"babelstream.{op} num_shards={s} not bitwise equal"
    print(f"  babelstream: copy/mul/add/triad bitwise equal, dot within "
          f"1e-6, shards {shard_counts}")

    # the scalar is traced, not baked into the compile cache: two distinct
    # scalars must share one jitted program per (op, num_shards)
    from repro.distributed import domain
    k = get_kernel("babelstream.triad")
    want = np.asarray(k(a, b, backend="xla", scalar=2.5))
    got = np.asarray(k(a, b, backend="xla_shard", num_shards=2, scalar=2.5))
    assert np.array_equal(want, got), "triad scalar=2.5 not bitwise equal"
    size = domain._stream_sharded.cache_info().currsize
    k(a, b, backend="xla_shard", num_shards=2, scalar=7.25)
    assert domain._stream_sharded.cache_info().currsize == size, \
        "a new scalar recompiled the sharded stream kernel"
    print("  babelstream: scalar is traced (one compile serves all values)")


def _check_minibude(np, jnp, get_kernel, shard_counts):
    from repro.kernels.minibude import ops as mb_ops
    deck = mb_ops.make_deck(natpro=16, natlig=4, nposes=128, seed=0)
    k = get_kernel("minibude.fasten")
    want = np.asarray(k(*deck, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(*deck, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"minibude.fasten num_shards={s} not bitwise equal"
    print(f"  minibude: pose-parallel bitwise equal at shards "
          f"{shard_counts}")


def _check_hartree_fock(np, jnp, get_kernel, shard_counts):
    from repro.kernels.hartree_fock import ref as hf_ref
    pos, dens = hf_ref.helium_lattice(8), hf_ref.initial_density(8)
    k = get_kernel("hartree_fock.twoel")
    want = np.asarray(k(pos, dens, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(pos, dens, backend="xla_shard", num_shards=s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print(f"  hartree_fock: psum Fock within oracle tolerance at shards "
          f"{shard_counts}")


def _check_constraints(np, jnp, get_kernel):
    import tempfile

    from repro.core import tuning
    from repro.distributed.domain import (resolve_num_shards,
                                          resolve_shard_grid)

    for bad in ({"extent": 15, "num_shards": 2},    # indivisible
                {"extent": 16, "num_shards": 1},    # < 2
                {"extent": 16, "num_shards": 1024}):  # > devices
        try:
            resolve_num_shards(bad["extent"], bad["num_shards"])
        except ValueError:
            pass
        else:
            raise AssertionError(f"resolve_num_shards accepted {bad}")

    for kw in ({"decomp": "pencil", "shard_grid": (2, 1)},   # not 2-D
                {"decomp": "slab", "shard_grid": (2, 2)},    # slab has sy=1
                {"decomp": "pencil", "shard_grid": (2, 3)},  # 8 % 3 != 0
                {"decomp": "pencil", "shard_grid": (64, 64)},  # > devices
                {"decomp": "block"}):                        # unknown
        try:
            resolve_shard_grid(16, 8, **kw)
        except ValueError:
            pass
        else:
            raise AssertionError(f"resolve_shard_grid accepted {kw}")

    # the declared tunable grid only admits valid (divisible, in-budget)
    # points, and tune() sweeps the decomp/shard-grid/overlap axes through
    # the unchanged registry path
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(2).standard_normal((4, 8, 16)),
                    jnp.float32)
    pts = k.tunable_space("xla_shard").valid_points(u)
    grids = sorted({(p["decomp"], p["shard_grid"]) for p in pts})
    assert grids == [("pencil", (2, 2)), ("pencil", (2, 4)),
                     ("pencil", (4, 2)), ("slab", (2, 1)),
                     ("slab", (4, 1))], grids
    assert all({True, False} == {q["overlap"] for q in pts
                                 if (q["decomp"], q["shard_grid"]) == g}
               for g in grids)
    with tempfile.TemporaryDirectory() as td:
        cache = tuning.TuningCache(path=td + "/tuning.json")
        r = tuning.tune(k, u, backend="xla_shard", cache=cache, iters=1,
                        warmup=0)
        assert r.skipped is None and not r.cached, r
        assert r.params["decomp"] in ("slab", "pencil"), r
        # tuple-valued shard_grid round-trips the JSON cache as a tuple
        r2 = tuning.tune(k, u, backend="xla_shard", cache=cache, iters=1,
                         warmup=0)
        assert r2.cached and r2.params == r.params, (r, r2)
        assert isinstance(r2.params["shard_grid"], tuple), r2
    print("  constraints: invalid shard counts/grids rejected, tunable "
          "grid filtered, tune() sweeps decomp/shard_grid/overlap")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args(argv)

    # must precede the first jax device query
    from repro.launch.hostsim import ensure_host_device_count
    ensure_host_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.kernels  # noqa: F401  (registers xla_shard backends)
    from repro.core.portable import get_kernel

    n = jax.device_count()
    if n < 2:
        print(f"selftest needs >= 2 devices, got {n} (is XLA_FLAGS already "
              f"forcing a 1-device topology?)", file=sys.stderr)
        return 2
    shard_counts = [s for s in (2, 4, 8) if s <= n]
    print(f"selftest on {n} simulated {jax.devices()[0].platform} devices, "
          f"shard counts {shard_counts}")

    _check_stencil(np, jnp, get_kernel, shard_counts)
    _check_stencil_pencil(np, jnp, get_kernel, n)
    _check_stencil_one_plane_per_shard(np, jnp, get_kernel, n)
    _check_halo_exchange(np, jnp, min(4, n))
    _check_halo_wrap_and_multiplane(np, jnp, min(4, n))
    _check_babelstream(np, jnp, get_kernel, shard_counts)
    _check_minibude(np, jnp, get_kernel, shard_counts)
    _check_hartree_fock(np, jnp, get_kernel, shard_counts)
    _check_constraints(np, jnp, get_kernel)
    print("selftest ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
