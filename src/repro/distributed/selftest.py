"""Multi-device self-test for the domain-decomposition subsystem.

    PYTHONPATH=src python -m repro.distributed.selftest [--devices 8]

Runs on simulated host devices (``hostsim`` appends
``--xla_force_host_platform_device_count`` before jax initializes — an
XLA_FLAGS value you already exported is respected).  The test suite invokes
this module in a subprocess (``tests/test_distributed_domain.py``) because
pytest's process has already pinned jax to the 1-device topology.

Checks, each against the single-device ``xla`` oracle:
  * stencil7 slab decomposition is **bitwise identical** at 2/4/8 shards;
  * the halo exchange round-trips shard-boundary planes (zeros at the open
    ends);
  * BabelStream copy/mul/add/triad are bitwise identical; ``dot`` matches
    within fp32 reduction tolerance (psum changes the summation order);
  * miniBUDE pose-parallel energies are bitwise identical;
  * Hartree-Fock psum-accumulated Fock matrices match within oracle
    tolerance;
  * divisibility / device-count constraints raise ``ValueError`` and the
    autotuner sweeps ``num_shards`` through the unchanged registry path.
"""

from __future__ import annotations

import argparse
import sys


def _check_stencil(np, jnp, get_kernel, shard_counts):
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16, 32)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(u, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"stencil7 xla_shard num_shards={s} is not bitwise equal"
    # default shard-count resolution also matches
    got = np.asarray(k(u, backend="xla_shard"))
    assert np.array_equal(want, got), "stencil7 auto num_shards mismatch"
    print(f"  stencil7: bitwise equal at shards {shard_counts} + auto")


def _check_halo_exchange(np, jnp, n_shards):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives
    from repro.distributed.domain import AXIS, shard_mesh

    rows = 2 * n_shards
    x = jnp.arange(rows * 3, dtype=jnp.float32).reshape(rows, 3)

    def local(u):
        lo, hi = collectives.halo_exchange(u, AXIS, n_shards, axis=0)
        return jnp.concatenate([lo, hi], axis=0)

    halos = np.asarray(jax.jit(shard_map(
        local, shard_mesh(n_shards), in_specs=P(AXIS),
        out_specs=P(AXIS)))(x))
    xs = np.asarray(x).reshape(n_shards, 2, 3)
    halos = halos.reshape(n_shards, 2, 3)
    for i in range(n_shards):
        want_lo = xs[i - 1][-1] if i > 0 else np.zeros(3)
        want_hi = xs[i + 1][0] if i < n_shards - 1 else np.zeros(3)
        assert np.array_equal(halos[i][0], want_lo), f"halo from_prev {i}"
        assert np.array_equal(halos[i][1], want_hi), f"halo from_next {i}"
    print(f"  halo_exchange: round-trips at {n_shards} shards, "
          f"zero at the open ends")


def _check_babelstream(np, jnp, get_kernel, shard_counts):
    r = np.random.default_rng(1)
    n = 1 << 12
    a = jnp.asarray(r.standard_normal(n), jnp.float32)
    b = jnp.asarray(r.standard_normal(n), jnp.float32)
    cases = {"copy": (a,), "mul": (a,), "add": (a, b), "triad": (a, b),
             "dot": (a, b)}
    for op, args in cases.items():
        k = get_kernel(f"babelstream.{op}")
        want = np.asarray(k(*args, backend="xla"))
        for s in shard_counts:
            got = np.asarray(k(*args, backend="xla_shard", num_shards=s))
            if op == "dot":
                np.testing.assert_allclose(got, want, rtol=1e-6)
            else:
                assert np.array_equal(want, got), \
                    f"babelstream.{op} num_shards={s} not bitwise equal"
    print(f"  babelstream: copy/mul/add/triad bitwise equal, dot within "
          f"1e-6, shards {shard_counts}")


def _check_minibude(np, jnp, get_kernel, shard_counts):
    from repro.kernels.minibude import ops as mb_ops
    deck = mb_ops.make_deck(natpro=16, natlig=4, nposes=128, seed=0)
    k = get_kernel("minibude.fasten")
    want = np.asarray(k(*deck, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(*deck, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"minibude.fasten num_shards={s} not bitwise equal"
    print(f"  minibude: pose-parallel bitwise equal at shards "
          f"{shard_counts}")


def _check_hartree_fock(np, jnp, get_kernel, shard_counts):
    from repro.kernels.hartree_fock import ref as hf_ref
    pos, dens = hf_ref.helium_lattice(8), hf_ref.initial_density(8)
    k = get_kernel("hartree_fock.twoel")
    want = np.asarray(k(pos, dens, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(pos, dens, backend="xla_shard", num_shards=s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print(f"  hartree_fock: psum Fock within oracle tolerance at shards "
          f"{shard_counts}")


def _check_constraints(np, jnp, get_kernel):
    from repro.core import tuning
    from repro.distributed.domain import resolve_num_shards

    for bad in ({"extent": 15, "num_shards": 2},    # indivisible
                {"extent": 16, "num_shards": 1},    # < 2
                {"extent": 16, "num_shards": 1024}):  # > devices
        try:
            resolve_num_shards(bad["extent"], bad["num_shards"])
        except ValueError:
            pass
        else:
            raise AssertionError(f"resolve_num_shards accepted {bad}")

    # the declared tunable grid only admits valid (divisible, in-budget)
    # shard counts, and tune() sweeps it through the unchanged registry path
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(2).standard_normal((4, 8, 16)),
                    jnp.float32)
    pts = k.tunable_space("xla_shard").valid_points(u)
    assert [p["num_shards"] for p in pts] == [2, 4], pts
    r = tuning.tune(k, u, backend="xla_shard", iters=1, warmup=0)
    assert r.skipped is None and r.params["num_shards"] in (2, 4), r
    print("  constraints: invalid shard counts rejected, tunable grid "
          "filtered, tune() sweeps num_shards")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args(argv)

    # must precede the first jax device query
    from repro.launch.hostsim import ensure_host_device_count
    ensure_host_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.kernels  # noqa: F401  (registers xla_shard backends)
    from repro.core.portable import get_kernel

    n = jax.device_count()
    if n < 2:
        print(f"selftest needs >= 2 devices, got {n} (is XLA_FLAGS already "
              f"forcing a 1-device topology?)", file=sys.stderr)
        return 2
    shard_counts = [s for s in (2, 4, 8) if s <= n]
    print(f"selftest on {n} simulated {jax.devices()[0].platform} devices, "
          f"shard counts {shard_counts}")

    _check_stencil(np, jnp, get_kernel, shard_counts)
    _check_halo_exchange(np, jnp, min(4, n))
    _check_babelstream(np, jnp, get_kernel, shard_counts)
    _check_minibude(np, jnp, get_kernel, shard_counts)
    _check_hartree_fock(np, jnp, get_kernel, shard_counts)
    _check_constraints(np, jnp, get_kernel)
    print("selftest ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
