"""Multi-device self-test for the domain-decomposition subsystem.

    PYTHONPATH=src python -m repro.distributed.selftest [--devices 8]
                                                        [--only BATTERY,...]

Runs on simulated host devices (``hostsim`` appends
``--xla_force_host_platform_device_count`` before jax initializes — an
XLA_FLAGS value you already exported is respected).  The test suite invokes
this module in a subprocess (``tests/test_distributed_domain.py``) because
pytest's process has already pinned jax to the 1-device topology: the full
battery set runs in the slow pytest lane, while ``--only smoke`` is a
seconds-scale single battery the tier-1 lane keeps.

Checks, each against the single-device ``xla`` oracle:
  * stencil7 slab decomposition is **bitwise identical** at 2/4/8 shards —
    and so are the 2-D pencil grids ((2,2)/(4,2)/(2,4)) and the
    halo/compute-overlap variants of both decompositions, including the
    one-plane-per-shard edge case of the boundary mask;
  * the halo exchange round-trips shard-boundary planes (zeros at the open
    ends), wraps periodically with ``wrap=True``, and moves ``halo``-thick
    multi-plane slabs;
  * BabelStream copy/mul/add/triad are bitwise identical; ``dot`` matches
    within fp32 reduction tolerance (psum changes the summation order);
    scalar ops trace the scalar (two scalars share one compiled program);
  * miniBUDE pose-parallel energies are bitwise identical;
  * Hartree-Fock psum-accumulated Fock matrices match within oracle
    tolerance;
  * divisibility / device-count constraints raise ``ValueError`` and the
    autotuner sweeps the decomp/shard-grid/overlap axes through the
    unchanged registry path (tuple-valued tunables round-trip the cache);
  * the ``shard_pallas`` composites (shard_map around the unchanged Pallas
    kernels, interpret mode off-TPU) are **bitwise identical to the
    single-device Pallas backend** for stencil7 (slab, pencil, every ``by``
    tile, one plane per shard) and the elementwise streams / miniBUDE;
    ``dot`` and Hartree-Fock match within psum-reduction tolerances; the
    composite tile x shard tunable spaces sweep through ``tune()``;
  * the registry-wide differential conformance matrix
    (``repro.core.conformance``) passes for every backend available here —
    on 8 forced host devices that is everything except compiled-TPU
    ``pallas``.
"""

from __future__ import annotations

import argparse
import sys


def _check_stencil(np, jnp, get_kernel, shard_counts):
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16, 32)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(u, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"stencil7 xla_shard num_shards={s} is not bitwise equal"
    # default shard-count resolution also matches
    got = np.asarray(k(u, backend="xla_shard"))
    assert np.array_equal(want, got), "stencil7 auto num_shards mismatch"
    print(f"  stencil7: bitwise equal at shards {shard_counts} + auto")


def _check_stencil_pencil(np, jnp, get_kernel, n_devices):
    if n_devices < 4:
        print("  stencil7: pencil checks skipped (< 4 devices)")
        return
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(3).standard_normal((16, 16, 32)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    grids = [g for g in ((2, 2), (4, 2), (2, 4))
             if g[0] * g[1] <= n_devices]
    for grid in grids:
        for overlap in (False, True):
            got = np.asarray(k(u, backend="xla_shard", decomp="pencil",
                               shard_grid=grid, overlap=overlap))
            assert np.array_equal(want, got), \
                f"stencil7 pencil grid={grid} overlap={overlap} mismatch"
    # slab overlap variant, and auto pencil-grid resolution
    for s in (2, 4):
        got = np.asarray(k(u, backend="xla_shard", decomp="slab",
                           shard_grid=(s, 1), overlap=True))
        assert np.array_equal(want, got), f"stencil7 slab+overlap s={s}"
    got = np.asarray(k(u, backend="xla_shard", decomp="pencil"))
    assert np.array_equal(want, got), "stencil7 auto pencil grid mismatch"
    print(f"  stencil7: pencil grids {grids} and overlap variants "
          f"bitwise equal")


def _check_stencil_one_plane_per_shard(np, jnp, get_kernel, n_devices):
    """nz == num_shards: each shard owns exactly one plane, so its first
    and last local plane coincide and the boundary mask must AND the two
    edge conditions rather than overwrite one with the other."""
    k = get_kernel("stencil7")
    s = min(8, n_devices)
    u = jnp.asarray(np.random.default_rng(4).standard_normal((s, 8, 16)),
                    jnp.float32)
    want = np.asarray(k(u, backend="xla"))
    for overlap in (False, True):
        got = np.asarray(k(u, backend="xla_shard", num_shards=s,
                           overlap=overlap))
        assert np.array_equal(want, got), \
            f"stencil7 one-plane-per-shard overlap={overlap} mismatch"
    print(f"  stencil7: one plane per shard ({s} shards) bitwise equal")


def _check_halo_exchange(np, jnp, n_shards):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives
    from repro.distributed.domain import AXIS, shard_mesh

    rows = 2 * n_shards
    x = jnp.arange(rows * 3, dtype=jnp.float32).reshape(rows, 3)

    def local(u):
        lo, hi = collectives.halo_exchange(u, AXIS, n_shards, axis=0)
        return jnp.concatenate([lo, hi], axis=0)

    halos = np.asarray(jax.jit(shard_map(
        local, shard_mesh(n_shards), in_specs=P(AXIS),
        out_specs=P(AXIS)))(x))
    xs = np.asarray(x).reshape(n_shards, 2, 3)
    halos = halos.reshape(n_shards, 2, 3)
    for i in range(n_shards):
        want_lo = xs[i - 1][-1] if i > 0 else np.zeros(3)
        want_hi = xs[i + 1][0] if i < n_shards - 1 else np.zeros(3)
        assert np.array_equal(halos[i][0], want_lo), f"halo from_prev {i}"
        assert np.array_equal(halos[i][1], want_hi), f"halo from_next {i}"
    print(f"  halo_exchange: round-trips at {n_shards} shards, "
          f"zero at the open ends")


def _check_halo_wrap_and_multiplane(np, jnp, n_shards):
    """The wrap=True periodic ring and halo>1 multi-plane slabs."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives
    from repro.distributed.domain import AXIS, shard_mesh

    planes = 3
    rows = planes * n_shards
    x = jnp.arange(rows * 2, dtype=jnp.float32).reshape(rows, 2)
    xs = np.asarray(x).reshape(n_shards, planes, 2)

    def run(local):
        return np.asarray(jax.jit(shard_map(
            local, shard_mesh(n_shards), in_specs=P(AXIS),
            out_specs=P(AXIS)))(x))

    # periodic shift: every shard receives its predecessor's block, the
    # first shard wrapping around to the last
    shifted = run(lambda u: collectives.shift(u, AXIS, n_shards, offset=1,
                                              wrap=True))
    shifted = shifted.reshape(n_shards, planes, 2)
    for i in range(n_shards):
        assert np.array_equal(shifted[i], xs[(i - 1) % n_shards]), \
            f"periodic shift shard {i}"

    # halo=2 multi-plane exchange, open ends: the previous shard's trailing
    # two planes / the next shard's leading two planes, zeros at the edges
    halos = run(lambda u: jnp.concatenate(
        collectives.halo_exchange(u, AXIS, n_shards, axis=0, halo=2),
        axis=0)).reshape(n_shards, 4, 2)
    for i in range(n_shards):
        want_lo = xs[i - 1][-2:] if i > 0 else np.zeros((2, 2))
        want_hi = xs[i + 1][:2] if i < n_shards - 1 else np.zeros((2, 2))
        assert np.array_equal(halos[i][:2], want_lo), f"halo=2 prev {i}"
        assert np.array_equal(halos[i][2:], want_hi), f"halo=2 next {i}"

    # halo=2, periodic: the edge shards exchange with each other
    halos = run(lambda u: jnp.concatenate(
        collectives.halo_exchange(u, AXIS, n_shards, axis=0, halo=2,
                                  wrap=True), axis=0))
    halos = halos.reshape(n_shards, 4, 2)
    for i in range(n_shards):
        assert np.array_equal(halos[i][:2], xs[(i - 1) % n_shards][-2:]), \
            f"wrap halo=2 prev {i}"
        assert np.array_equal(halos[i][2:], xs[(i + 1) % n_shards][:2]), \
            f"wrap halo=2 next {i}"
    print(f"  halo_exchange: wrap=True periodic ring and halo=2 "
          f"multi-plane slabs at {n_shards} shards")


def _check_babelstream(np, jnp, get_kernel, shard_counts):
    r = np.random.default_rng(1)
    n = 1 << 12
    a = jnp.asarray(r.standard_normal(n), jnp.float32)
    b = jnp.asarray(r.standard_normal(n), jnp.float32)
    cases = {"copy": (a,), "mul": (a,), "add": (a, b), "triad": (a, b),
             "dot": (a, b)}
    for op, args in cases.items():
        k = get_kernel(f"babelstream.{op}")
        want = np.asarray(k(*args, backend="xla"))
        for s in shard_counts:
            got = np.asarray(k(*args, backend="xla_shard", num_shards=s))
            if op == "dot":
                np.testing.assert_allclose(got, want, rtol=1e-6)
            else:
                assert np.array_equal(want, got), \
                    f"babelstream.{op} num_shards={s} not bitwise equal"
    print(f"  babelstream: copy/mul/add/triad bitwise equal, dot within "
          f"1e-6, shards {shard_counts}")

    # the scalar is traced, not baked into the compile cache: two distinct
    # scalars must share one jitted program per (op, num_shards)
    from repro.distributed import domain
    k = get_kernel("babelstream.triad")
    want = np.asarray(k(a, b, backend="xla", scalar=2.5))
    got = np.asarray(k(a, b, backend="xla_shard", num_shards=2, scalar=2.5))
    assert np.array_equal(want, got), "triad scalar=2.5 not bitwise equal"
    size = domain._stream_sharded.cache_info().currsize
    k(a, b, backend="xla_shard", num_shards=2, scalar=7.25)
    assert domain._stream_sharded.cache_info().currsize == size, \
        "a new scalar recompiled the sharded stream kernel"
    print("  babelstream: scalar is traced (one compile serves all values)")


def _check_minibude(np, jnp, get_kernel, shard_counts):
    from repro.kernels.minibude import ops as mb_ops
    deck = mb_ops.make_deck(natpro=16, natlig=4, nposes=128, seed=0)
    k = get_kernel("minibude.fasten")
    want = np.asarray(k(*deck, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(*deck, backend="xla_shard", num_shards=s))
        assert np.array_equal(want, got), \
            f"minibude.fasten num_shards={s} not bitwise equal"
    print(f"  minibude: pose-parallel bitwise equal at shards "
          f"{shard_counts}")


def _check_hartree_fock(np, jnp, get_kernel, shard_counts):
    from repro.kernels.hartree_fock import ref as hf_ref
    pos, dens = hf_ref.helium_lattice(8), hf_ref.initial_density(8)
    k = get_kernel("hartree_fock.twoel")
    want = np.asarray(k(pos, dens, backend="xla"))
    for s in shard_counts:
        got = np.asarray(k(pos, dens, backend="xla_shard", num_shards=s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print(f"  hartree_fock: psum Fock within oracle tolerance at shards "
          f"{shard_counts}")


def _check_constraints(np, jnp, get_kernel):
    import tempfile

    from repro.core import tuning
    from repro.distributed.domain import (resolve_num_shards,
                                          resolve_shard_grid)

    for bad in ({"extent": 15, "num_shards": 2},    # indivisible
                {"extent": 16, "num_shards": 1},    # < 2
                {"extent": 16, "num_shards": 1024}):  # > devices
        try:
            resolve_num_shards(bad["extent"], bad["num_shards"])
        except ValueError:
            pass
        else:
            raise AssertionError(f"resolve_num_shards accepted {bad}")

    for kw in ({"decomp": "pencil", "shard_grid": (2, 1)},   # not 2-D
                {"decomp": "slab", "shard_grid": (2, 2)},    # slab has sy=1
                {"decomp": "pencil", "shard_grid": (2, 3)},  # 8 % 3 != 0
                {"decomp": "pencil", "shard_grid": (64, 64)},  # > devices
                {"decomp": "block"}):                        # unknown
        try:
            resolve_shard_grid(16, 8, **kw)
        except ValueError:
            pass
        else:
            raise AssertionError(f"resolve_shard_grid accepted {kw}")

    # the declared tunable grid only admits valid (divisible, in-budget)
    # points, and tune() sweeps the decomp/shard-grid/overlap axes through
    # the unchanged registry path
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(2).standard_normal((4, 8, 16)),
                    jnp.float32)
    pts = k.tunable_space("xla_shard").valid_points(u)
    grids = sorted({(p["decomp"], p["shard_grid"]) for p in pts})
    assert grids == [("pencil", (2, 2)), ("pencil", (2, 4)),
                     ("pencil", (4, 2)), ("slab", (2, 1)),
                     ("slab", (4, 1))], grids
    assert all({True, False} == {q["overlap"] for q in pts
                                 if (q["decomp"], q["shard_grid"]) == g}
               for g in grids)
    with tempfile.TemporaryDirectory() as td:
        cache = tuning.TuningCache(path=td + "/tuning.json")
        r = tuning.tune(k, u, backend="xla_shard", cache=cache, iters=1,
                        warmup=0)
        assert r.skipped is None and not r.cached, r
        assert r.params["decomp"] in ("slab", "pencil"), r
        # tuple-valued shard_grid round-trips the JSON cache as a tuple
        r2 = tuning.tune(k, u, backend="xla_shard", cache=cache, iters=1,
                         warmup=0)
        assert r2.cached and r2.params == r.params, (r, r2)
        assert isinstance(r2.params["shard_grid"], tuple), r2
    print("  constraints: invalid shard counts/grids rejected, tunable "
          "grid filtered, tune() sweeps decomp/shard_grid/overlap")


def _check_shard_pallas_stencil(np, jnp, get_kernel, n_devices):
    """The composite backend must be bitwise identical to the single-device
    Pallas backend — sharding must not change the kernel's output — across
    slab/pencil grids, every admissible ``by`` tile, and the
    one-plane-per-shard edge (where the whole local block is halo)."""
    k = get_kernel("stencil7")
    # ny=32 keeps every pencil grid's local block >= the smallest declared
    # by tile ((2,4) leaves an 8-wide block)
    u = jnp.asarray(np.random.default_rng(5).standard_normal((16, 32, 128)),
                    jnp.float32)
    want_pi = np.asarray(k(u, backend="pallas_interpret", by=16))
    want_x = np.asarray(k(u, backend="xla"))
    np.testing.assert_allclose(want_pi, want_x, rtol=1e-5, atol=1e-5)
    cases = [{"num_shards": s} for s in (2, 4, 8) if s <= n_devices]
    cases += [{"num_shards": min(4, n_devices), "by": 8}, {}]
    if n_devices >= 4:
        cases += [{"decomp": "pencil", "shard_grid": g}
                  for g in ((2, 2), (4, 2), (2, 4))
                  if g[0] * g[1] <= n_devices]
    for kw in cases:
        got = np.asarray(k(u, backend="shard_pallas", **kw))
        assert np.array_equal(want_pi, got), \
            f"stencil7 shard_pallas {kw} != single-device pallas"
    s = min(8, n_devices)
    u1 = jnp.asarray(np.random.default_rng(6).standard_normal((s, 16, 128)),
                     jnp.float32)
    want1 = np.asarray(k(u1, backend="pallas_interpret", by=16))
    got1 = np.asarray(k(u1, backend="shard_pallas", num_shards=s))
    assert np.array_equal(want1, got1), \
        "stencil7 shard_pallas one-plane-per-shard mismatch"
    print(f"  shard_pallas stencil7: bitwise equal to single-device pallas "
          f"({len(cases)} grids incl. pencil + one plane per shard)")


def _check_shard_pallas_streams(np, jnp, get_kernel, n_devices):
    r = np.random.default_rng(7)
    n = 1 << 17
    a = jnp.asarray(r.standard_normal(n), jnp.float32)
    b = jnp.asarray(r.standard_normal(n), jnp.float32)
    shard_counts = [s for s in (2, 8) if s <= n_devices]
    cases = {"copy": ((a,), {}), "mul": ((a,), {"scalar": 2.5}),
             "add": ((a, b), {}), "triad": ((a, b), {"scalar": 2.5})}
    for op, (args, kw) in cases.items():
        k = get_kernel(f"babelstream.{op}")
        want = np.asarray(k(*args, backend="pallas_interpret", **kw))
        for s in shard_counts:
            got = np.asarray(k(*args, backend="shard_pallas", num_shards=s,
                               **kw))
            assert np.array_equal(want, got), \
                f"babelstream.{op} shard_pallas num_shards={s} mismatch"
    k = get_kernel("babelstream.dot")
    want = np.asarray(k(a, b, backend="pallas_interpret"))
    for s in shard_counts:
        got = np.asarray(k(a, b, backend="shard_pallas", num_shards=s))
        np.testing.assert_allclose(got, want, rtol=1e-5)
    print(f"  shard_pallas babelstream: elementwise bitwise equal to "
          f"single-device pallas, dot within 1e-5, shards {shard_counts}")


def _check_shard_pallas_minibude(np, jnp, get_kernel, n_devices):
    from repro.kernels.minibude import ops as mb_ops
    deck = mb_ops.make_deck(natpro=16, natlig=4, nposes=512, seed=0)
    k = get_kernel("minibude.fasten")
    want = np.asarray(k(*deck, backend="pallas_interpret"))
    shard_counts = [s for s in (2, 4) if s <= n_devices]
    for s in shard_counts:
        got = np.asarray(k(*deck, backend="shard_pallas", num_shards=s))
        assert np.array_equal(want, got), \
            f"minibude shard_pallas num_shards={s} mismatch"
    print(f"  shard_pallas minibude: bitwise equal to single-device pallas "
          f"at shards {shard_counts}")


def _check_shard_pallas_hartree_fock(np, jnp, get_kernel, n_devices):
    from repro.kernels.hartree_fock import ref as hf_ref
    pos, dens = hf_ref.helium_lattice(8), hf_ref.initial_density(8)
    k = get_kernel("hartree_fock.twoel")
    want = np.asarray(k(pos, dens, backend="xla"))
    shard_counts = [s for s in (2, 4, 8) if s <= n_devices]
    for s in shard_counts:
        got = np.asarray(k(pos, dens, backend="shard_pallas", num_shards=s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print(f"  shard_pallas hartree_fock: l-slab Pallas psum within oracle "
          f"tolerance at shards {shard_counts}")


def _check_shard_pallas_tuning(np, jnp, get_kernel):
    """The composite tile x shard space sweeps through the unchanged
    registry/tuning path (48-point grid -> budgeted coordinate descent)."""
    import tempfile

    from repro.core import tuning

    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(8).standard_normal((8, 16, 128)),
                    jnp.float32)
    pts = k.tunable_space("shard_pallas").valid_points(u)
    assert all((u.shape[1] // p["shard_grid"][1]) % p["by"] == 0
               for p in pts), pts
    assert {p["decomp"] for p in pts} == {"slab", "pencil"}, pts
    with tempfile.TemporaryDirectory() as td:
        cache = tuning.TuningCache(path=td + "/tuning.json")
        r = tuning.tune(k, u, backend="shard_pallas", cache=cache, iters=1,
                        warmup=0, budget=4)
        assert r.skipped is None and not r.cached, r
        assert {"decomp", "shard_grid", "by"} <= set(r.params), r
        r2 = tuning.tune(k, u, backend="shard_pallas", cache=cache, iters=1,
                         warmup=0, budget=4)
        assert r2.cached and r2.params == r.params, (r, r2)
        assert isinstance(r2.params["shard_grid"], tuple), r2
    print("  shard_pallas tuning: composite tile x shard space sweeps and "
          "round-trips the cache")


def _check_conformance(np, jnp, get_kernel):
    """The registry-wide differential matrix, on this multi-device host:
    every (kernel, backend) cell either validates against its oracle or
    skips with a ``BackendUnavailableError`` reason — here only the
    compiled-TPU ``pallas`` backends may skip."""
    from repro.core import conformance
    from repro.core.portable import BackendUnavailableError

    ran, skipped = [], []
    for name, backend in conformance.conformance_pairs():
        try:
            conformance.check_backend(name, backend)
            ran.append((name, backend))
        except BackendUnavailableError:
            skipped.append((name, backend))
    assert all(b == "pallas" for _, b in skipped), skipped
    for b in ("xla_shard", "shard_pallas"):
        assert any(x[1] == b for x in ran), f"{b} never ran: {ran}"
    print(f"  conformance: {len(ran)} registry cells validated "
          f"({len(skipped)} TPU-only skips)")


def _check_smoke(np, jnp, get_kernel, n_devices):
    """Seconds-scale single battery for the tier-1 lane: one sharded-oracle
    and one sharded-Pallas stencil, bitwise, at 2 shards."""
    k = get_kernel("stencil7")
    u = jnp.asarray(np.random.default_rng(9).standard_normal((4, 8, 128)),
                    jnp.float32)
    want_x = np.asarray(k(u, backend="xla"))
    got = np.asarray(k(u, backend="xla_shard", num_shards=2))
    assert np.array_equal(want_x, got), "smoke: xla_shard mismatch"
    want_pi = np.asarray(k(u, backend="pallas_interpret", by=8))
    got = np.asarray(k(u, backend="shard_pallas", num_shards=2))
    assert np.array_equal(want_pi, got), "smoke: shard_pallas mismatch"
    np.testing.assert_allclose(got, want_x, rtol=1e-5, atol=1e-5)
    print("  smoke: xla_shard + shard_pallas stencil bitwise at 2 shards")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--only", default=None, metavar="BATTERY[,BATTERY...]",
                    help="run only the named batteries (default: every "
                         "battery except the tier-1 'smoke' shortcut)")
    args = ap.parse_args(argv)

    # must precede the first jax device query
    from repro.launch.hostsim import ensure_host_device_count
    ensure_host_device_count(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.kernels  # noqa: F401  (registers the sharded backends)
    from repro.core.portable import get_kernel

    n = jax.device_count()
    if n < 2:
        print(f"selftest needs >= 2 devices, got {n} (is XLA_FLAGS already "
              f"forcing a 1-device topology?)", file=sys.stderr)
        return 2
    shard_counts = [s for s in (2, 4, 8) if s <= n]

    batteries = {
        "stencil": lambda: _check_stencil(np, jnp, get_kernel, shard_counts),
        "stencil_pencil": lambda: _check_stencil_pencil(np, jnp, get_kernel,
                                                        n),
        "stencil_one_plane": lambda: _check_stencil_one_plane_per_shard(
            np, jnp, get_kernel, n),
        "halo": lambda: _check_halo_exchange(np, jnp, min(4, n)),
        "halo_wrap": lambda: _check_halo_wrap_and_multiplane(np, jnp,
                                                             min(4, n)),
        "babelstream": lambda: _check_babelstream(np, jnp, get_kernel,
                                                  shard_counts),
        "minibude": lambda: _check_minibude(np, jnp, get_kernel,
                                            shard_counts),
        "hartree_fock": lambda: _check_hartree_fock(np, jnp, get_kernel,
                                                    shard_counts),
        "constraints": lambda: _check_constraints(np, jnp, get_kernel),
        "shard_pallas_stencil": lambda: _check_shard_pallas_stencil(
            np, jnp, get_kernel, n),
        "shard_pallas_streams": lambda: _check_shard_pallas_streams(
            np, jnp, get_kernel, n),
        "shard_pallas_minibude": lambda: _check_shard_pallas_minibude(
            np, jnp, get_kernel, n),
        "shard_pallas_hf": lambda: _check_shard_pallas_hartree_fock(
            np, jnp, get_kernel, n),
        "shard_pallas_tuning": lambda: _check_shard_pallas_tuning(
            np, jnp, get_kernel),
        "conformance": lambda: _check_conformance(np, jnp, get_kernel),
        "smoke": lambda: _check_smoke(np, jnp, get_kernel, n),
    }
    if args.only is None:
        selected = [b for b in batteries if b != "smoke"]
    else:
        selected = [b.strip() for b in args.only.split(",") if b.strip()]
        unknown = [b for b in selected if b not in batteries]
        if unknown:
            print(f"unknown batteries {unknown}; known: "
                  f"{sorted(batteries)}", file=sys.stderr)
            return 2

    print(f"selftest on {n} simulated {jax.devices()[0].platform} devices, "
          f"shard counts {shard_counts}, batteries {selected}")
    for name in selected:
        batteries[name]()
    print(f"selftest ok ({len(selected)} batteries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
