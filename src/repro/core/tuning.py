"""Registry-driven autotuning for PortableKernel backends.

Kokkos/Julia-style portability evaluations (Godoy et al., 2023) and the
Mojo paper's own methodology both time each kernel at its *best* tunable
configuration before computing Eq.-4 efficiencies — an untuned portable
kernel understates the metric.  This module supplies that measurement
spine:

  * each backend declares its tunable grid via
    ``PortableKernel.declare_tunables`` (block/tile sizes plus a
    divisibility constraint over the concrete inputs);
  * ``tune()`` walks the grid *deterministically* (declaration order),
    timing every valid point through ``PortableKernel.time_backend`` and
    picking the fastest (ties break toward the earlier point);
  * results persist in a JSON :class:`TuningCache` keyed by
    ``(kernel, backend, shape-signature, dtype, platform)`` so repeat runs
    — and ``PortableKernel.__call__(tuned=True)`` at serving time — skip
    the re-search entirely;
  * unavailable backends are *skipped with a reason*
    (``TuningResult.skipped``), never crashed into, so a CPU host can sweep
    a catalogue that also contains TPU-only backends.

Cache location: ``$REPRO_TUNING_CACHE`` if set, else
``~/.cache/repro/tuning.json``.  The file maps the key string to
``{"params": {...}, "seconds": float}`` and is rewritten atomically.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.portable import (BackendUnavailableError, PortableKernel,
                                 registry)

__all__ = [
    "TuningKey",
    "TuningCache",
    "TuningResult",
    "make_key",
    "shape_signature",
    "tune",
    "cached_best_params",
    "default_cache_path",
]

CACHE_ENV = "REPRO_TUNING_CACHE"


# --------------------------------------------------------------------------
# keys
# --------------------------------------------------------------------------
def _sig_one(x: Any) -> str:
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return f"{dtype}[{','.join(str(d) for d in shape)}]"
    return repr(x)


def shape_signature(*args: Any, **kwargs: Any) -> str:
    """Deterministic signature of the concrete call: dtypes+shapes of array
    arguments, ``repr`` of scalars, kwargs sorted by name."""
    parts = [_sig_one(a) for a in args]
    parts += [f"{k}={_sig_one(v)}" for k, v in sorted(kwargs.items())]
    return ";".join(parts)


@dataclasses.dataclass(frozen=True)
class TuningKey:
    """Cache key: a tuned configuration is only valid for the exact problem
    shape/dtype on the platform it was measured on."""

    kernel: str
    backend: str
    shape: str
    dtype: str
    platform: str

    def as_str(self) -> str:
        return "|".join((self.kernel, self.backend, self.shape, self.dtype,
                         self.platform))


def _platform() -> str:
    try:
        return jax.devices()[0].platform
    except Exception:  # pragma: no cover - no jax backend at all
        return "unknown"


def make_key(kernel: PortableKernel, *args: Any, backend: str,
             **kwargs: Any) -> TuningKey:
    dtypes = [str(a.dtype) for a in args if hasattr(a, "dtype")]
    return TuningKey(
        kernel=kernel.name,
        backend=backend,
        shape=shape_signature(*args, **kwargs),
        dtype=dtypes[0] if dtypes else "-",
        platform=_platform(),
    )


# --------------------------------------------------------------------------
# persistent cache
# --------------------------------------------------------------------------
def default_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "tuning.json"


class TuningCache:
    """Persistent JSON map ``key-string -> {"params", "seconds"}``.

    Writes are atomic (tmp file + rename) so concurrent runs cannot leave a
    torn file behind, and each ``put`` merges the on-disk state back in
    first, so two processes tuning different kernels keep each other's
    entries (the race on one *identical* key is last-writer-wins, which is
    fine — both wrote a valid measurement).  Cached ``seconds`` are
    historical: they skip the re-search, but anything computing a ratio
    against a fresh timing must re-time at the cached params
    (``benchmarks/portability.py`` does).
    """

    def __init__(self, path: Optional[os.PathLike] = None) -> None:
        self.path = Path(path) if path is not None else default_cache_path()
        self._data: Optional[Dict[str, Dict[str, Any]]] = None

    def _load(self) -> Dict[str, Dict[str, Any]]:
        if self._data is None:
            try:
                self._data = json.loads(self.path.read_text())
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def get(self, key: TuningKey) -> Optional[Dict[str, Any]]:
        return self._load().get(key.as_str())

    def put(self, key: TuningKey, params: Dict[str, Any],
            seconds: float) -> None:
        data = self._load()
        try:
            on_disk = json.loads(self.path.read_text())
        except (OSError, ValueError):
            on_disk = {}
        for k, v in on_disk.items():
            data.setdefault(k, v)
        data[key.as_str()] = {"params": dict(params),
                              "seconds": float(seconds)}
        self._save(data)

    def _save(self, data: Dict[str, Dict[str, Any]]) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        return len(self._load())


# --------------------------------------------------------------------------
# the sweep
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TuningResult:
    """Outcome of one ``tune()`` call."""

    kernel: str
    backend: str
    params: Dict[str, Any]            # best point ({} = declared defaults)
    seconds: float                    # best median seconds per call
    swept: List[Tuple[Dict[str, Any], float]]  # every timed (point, seconds)
    cached: bool                      # True = served from the cache, no timing
    skipped: Optional[str] = None     # reason this backend was not tuned


def tune(kernel: PortableKernel, *args: Any, backend: str,
         cache: Optional[TuningCache] = None, iters: int = 3,
         warmup: int = 1, max_points: Optional[int] = None,
         **kwargs: Any) -> TuningResult:
    """Find (or recall) the best tunable point for one backend + inputs.

    Deterministic: the grid is walked in declaration order and ties break
    toward the earlier point, so two runs on the same host pick the same
    configuration.  A cache hit skips all timing.  An unavailable backend
    or a backend with an empty valid grid returns ``skipped=<reason>``
    with the declared defaults instead of raising.
    """
    b = kernel.backends.get(backend)
    if b is None:
        raise KeyError(
            f"kernel {kernel.name!r} has no backend {backend!r}; "
            f"have {sorted(kernel.backends)}")
    if not b.is_available():
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=[], cached=False,
            skipped=f"backend {backend!r} unavailable on platform "
                    f"{_platform()!r}")

    key = make_key(kernel, *args, backend=backend, **kwargs)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return TuningResult(
                kernel=kernel.name, backend=backend,
                params=dict(hit["params"]), seconds=float(hit["seconds"]),
                swept=[], cached=True)

    space = kernel.tunable_space(backend)
    if space is None:
        # not cached: a cache hit would flip skipped/swept on repeat runs,
        # and there is no search to skip anyway
        secs = kernel.time_backend(*args, backend=backend, iters=iters,
                                   warmup=warmup, **kwargs)
        return TuningResult(kernel=kernel.name, backend=backend, params={},
                            seconds=secs, swept=[({}, secs)], cached=False,
                            skipped="no tunable space declared")

    points = space.valid_points(*args, **kwargs)
    truncated = max_points is not None and len(points) > max_points
    if truncated:
        points = points[:max_points]
    if not points:
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=[], cached=False,
            skipped="no valid tunable point for these inputs")

    swept: List[Tuple[Dict[str, Any], float]] = []
    best_params: Optional[Dict[str, Any]] = None
    best_secs = float("inf")
    for point in points:
        try:
            secs = kernel.time_backend(*args, backend=backend, iters=iters,
                                       warmup=warmup, **point, **kwargs)
        except (ValueError, TypeError):
            # a point the constraint failed to exclude — record and move on
            swept.append((point, float("inf")))
            continue
        swept.append((point, secs))
        if secs < best_secs:
            best_secs, best_params = secs, point

    if best_params is None:
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=swept, cached=False,
            skipped="every tunable point failed to run")

    result = TuningResult(kernel=kernel.name, backend=backend,
                          params=best_params, seconds=best_secs, swept=swept,
                          cached=False)
    # a truncated sweep (smoke lane) must not poison the cache: its key is
    # identical to the full run's, which would then inherit the partial
    # search as if it were the tuned optimum
    if cache is not None and not truncated:
        cache.put(key, result.params, result.seconds)
    return result


_DEFAULT_CACHES: Dict[Path, TuningCache] = {}


def _default_cache() -> TuningCache:
    """Shared per-path default cache so hot callers (``tuned=True`` in a
    serving loop) parse the JSON file once, not per call."""
    path = default_cache_path()
    c = _DEFAULT_CACHES.get(path)
    if c is None:
        c = _DEFAULT_CACHES[path] = TuningCache(path)
    return c


def cached_best_params(kernel: PortableKernel, *args: Any, backend: str,
                       cache: Optional[TuningCache] = None,
                       **kwargs: Any) -> Dict[str, Any]:
    """Cache-lookup-only path used by ``PortableKernel.__call__(tuned=True)``:
    returns the recorded best params for this exact problem, or ``{}``
    (declared defaults) on a miss.  Never times anything."""
    if cache is None:
        cache = _default_cache()
    hit = cache.get(make_key(kernel, *args, backend=backend, **kwargs))
    return dict(hit["params"]) if hit else {}


def tune_registered(name: str, *args: Any, backend: str,
                    **kwargs: Any) -> TuningResult:
    """Convenience: ``tune()`` against the global registry by kernel name."""
    return tune(registry.get(name), *args, backend=backend, **kwargs)
