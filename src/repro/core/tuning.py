"""Registry-driven autotuning for PortableKernel backends.

Kokkos/Julia-style portability evaluations (Godoy et al., 2023) and the
Mojo paper's own methodology both time each kernel at its *best* tunable
configuration before computing Eq.-4 efficiencies — an untuned portable
kernel understates the metric.  This module supplies that measurement
spine:

  * each backend declares its tunable grid via
    ``PortableKernel.declare_tunables`` (block/tile sizes plus a
    divisibility constraint over the concrete inputs);
  * ``tune()`` walks the grid *deterministically* (declaration order),
    timing every valid point through ``PortableKernel.time_backend`` and
    picking the fastest (ties break toward the earlier point);
  * results persist in a JSON :class:`TuningCache` keyed by
    ``(kernel, backend, shape-signature, dtype, platform)`` so repeat runs
    — and ``PortableKernel.__call__(tuned=True)`` at serving time — skip
    the re-search entirely;
  * unavailable backends are *skipped with a reason*
    (``TuningResult.skipped``), never crashed into, so a CPU host can sweep
    a catalogue that also contains TPU-only backends.

Grids past ``COORD_THRESHOLD`` points switch (under ``search="auto"``) to a
budgeted coordinate descent: sweep one parameter at a time from a
deterministic start, repeat until a full pass stops improving or the timing
budget runs out.  ``search="model"`` goes further: the static cost model
(``repro.core.analysis.cost``) ranks every valid point by predicted
roofline time, points dominated on both modeled traffic and parallelism
are pruned, and only the top-k candidates are timed.  Partial results
(``"coordinate"``/``"model"``) are cached with their provenance marker and
are **never** served to a caller whose sweep would be exhaustive — a
partial search must not masquerade as the tuned optimum.

Cache location: ``$REPRO_TUNING_CACHE`` if set, else
``~/.cache/repro/tuning.json``.  Schema v2
(``{"schema": "repro.tuning/v2", "entries": {key: {"params", "seconds",
"search"}}}``, rewritten atomically): keys embed a hash of the backend
function's source, so editing a kernel invalidates its tuned params instead
of silently serving stale block sizes.  v1 files (flat, no code hash) are
discarded wholesale on load — that is the invalidation, not data loss.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import inspect
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import telemetry as tel
from repro.core.portable import (BackendUnavailableError, PortableKernel,
                                 registry)

__all__ = [
    "TuningKey",
    "TuningCache",
    "TuningResult",
    "make_key",
    "shape_signature",
    "backend_code_hash",
    "params_from_cache",
    "tune",
    "cached_best_params",
    "cached_entry",
    "default_cache_path",
    "COORD_THRESHOLD",
]

CACHE_ENV = "REPRO_TUNING_CACHE"
CACHE_SCHEMA = "repro.tuning/v2"


def params_from_cache(params: Dict[str, Any]) -> Dict[str, Any]:
    """Normalize a cached params dict for re-injection as call kwargs.

    Tunable values may be tuples (the stencil's ``shard_grid=(sz, sy)``);
    JSON has no tuple type, so they come back as lists.  Declared grids are
    flat, so a shallow list->tuple conversion restores the declared value —
    keeping cache-served params hashable and ``==`` to their swept twins.
    """
    return {k: tuple(v) if isinstance(v, list) else v
            for k, v in params.items()}

#: grids larger than this switch from exhaustive sweep to coordinate descent
#: under ``search="auto"``
COORD_THRESHOLD = 16


# --------------------------------------------------------------------------
# keys
# --------------------------------------------------------------------------
def _sig_one(x: Any) -> str:
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return f"{dtype}[{','.join(str(d) for d in shape)}]"
    return repr(x)


def shape_signature(*args: Any, **kwargs: Any) -> str:
    """Deterministic signature of the concrete call: dtypes+shapes of array
    arguments, ``repr`` of scalars, kwargs sorted by name."""
    parts = [_sig_one(a) for a in args]
    parts += [f"{k}={_sig_one(v)}" for k, v in sorted(kwargs.items())]
    return ";".join(parts)


@dataclasses.dataclass(frozen=True)
class TuningKey:
    """Cache key: a tuned configuration is only valid for the exact problem
    shape/dtype on the platform *and device count* it was measured on
    (``num_shards=8`` tuned under 8 devices must not be replayed on 2) —
    and only for the exact backend *code* it was measured against
    (``code`` hashes the backend function's source, so kernel edits
    invalidate their cached params)."""

    kernel: str
    backend: str
    shape: str
    dtype: str
    platform: str
    code: str = "-"
    devices: int = 1

    def as_str(self) -> str:
        return "|".join((self.kernel, self.backend, self.shape, self.dtype,
                         self.platform, self.code, f"d{self.devices}"))


_CODE_HASHES: Dict[int, Tuple[Any, str]] = {}


def _own_source(fn: Any) -> str:
    try:
        return inspect.getsource(fn)
    except (OSError, TypeError):
        code = getattr(fn, "__code__", None)
        return code.co_code.hex() if code is not None else repr(fn)


def _unwrap_callable(val: Any) -> Any:
    """Peel ``functools.partial`` / ``__wrapped__`` chains (jit, lru_cache)
    down to the underlying function; cycles and exotic wrappers fall back
    to the value itself."""
    for _ in range(16):
        if isinstance(val, functools.partial):
            val = val.func
        elif getattr(val, "__wrapped__", None) is not None:
            val = val.__wrapped__
        else:
            break
    return val


def _container_callables(val: Any) -> List[Any]:
    """Callables sitting in a plain dict/tuple/list global (dispatch tables
    like ``_STREAM_LOCAL`` map op names to (fn, ...) tuples)."""
    if isinstance(val, dict):
        vals = list(val.values())
    elif isinstance(val, (list, tuple)):
        vals = list(val)
    else:
        return []
    out = []
    for v in vals:
        if isinstance(v, (list, tuple)):
            out.extend(w for w in v if callable(w))
        elif callable(v):
            out.append(v)
    return out


def _referenced_file_hashes(fn: Any) -> List[str]:
    """sha1s of the repro source files a backend wrapper dispatches into.

    Registered backends are mostly thin wrappers (``laplacian_pallas`` is
    three lines around ``K.laplacian_3d``; ``laplacian_shard`` dispatches
    through an ``lru_cache``-wrapped shard_map builder), so hashing only
    their own source would miss the kernel-body edits this key exists to
    catch.  Starting from the wrapper's code, walk the modules/callables
    its globals reference — unwrapping jit/lru_cache/partial layers and
    looking inside plain dict/tuple dispatch tables — and pull in each
    referenced repro *file's* digest, recursing (bounded) through
    repro-defined functions so a wrapper -> cached builder -> kernel-ref
    chain still reaches ref.py.  Editing any file on that chain then
    changes the wrapper's key even though the wrapper text didn't move.
    Entries are keyed by repro-relative path so hosts sharing a cache via
    $REPRO_TUNING_CACHE agree on the hash for byte-identical code."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return []
    marker = os.sep + "repro" + os.sep
    digests: Dict[str, str] = {}
    seen = set()
    queue = [(code, getattr(fn, "__globals__", {}))]
    budget = 64

    def visit(val):
        if inspect.ismodule(val):
            path, target = getattr(val, "__file__", None), None
        elif callable(val):
            target = _unwrap_callable(val)
            mod = inspect.getmodule(target)
            path = getattr(mod, "__file__", None) if mod else None
        else:
            return
        if not path or marker not in path:
            return
        # key by the repro-package-relative path: the hash must agree
        # across checkouts/hosts sharing a cache, not encode where this
        # clone happens to live
        rel = path[path.rfind(marker) + 1:].replace(os.sep, "/")
        if rel not in digests:
            try:
                digests[rel] = hashlib.sha1(
                    Path(path).read_bytes()).hexdigest()
            except OSError:
                return
        tcode = getattr(target, "__code__", None)
        if tcode is not None and tcode not in seen:
            seen.add(tcode)
            queue.append((tcode, getattr(target, "__globals__", {})))

    while queue and budget > 0:
        budget -= 1
        c, g = queue.pop(0)
        for name in c.co_names:
            val = g.get(name)
            visit(val)
            for v in _container_callables(val):
                visit(v)
    return sorted(f"{p}={d}" for p, d in digests.items())


def backend_code_hash(fn: Any) -> str:
    """Short sha1 identifying the backend's *implementation*: its own
    source (jit wrappers and partials unwrapped first), the repr of its
    closure constants (factory-made wrappers share source but close over
    different ops), and the file digests of the repro modules/functions it
    dispatches into (thin wrappers change when the kernel body does).
    Falls back to bytecode, then repr, when source is unavailable — the
    hash only needs to *change when the kernel changes*, not be
    human-readable."""
    hit = _CODE_HASHES.get(id(fn))
    if hit is not None and hit[0] is fn:
        return hit[1]
    target, root = _unwrap_callable(fn), fn
    parts = [_own_source(target)]
    code = getattr(target, "__code__", None)
    closure = getattr(target, "__closure__", None) or ()
    for name, cell in zip(code.co_freevars if code else (), closure):
        try:
            val = cell.cell_contents
        except ValueError:  # pragma: no cover - still-empty cell
            continue
        parts.append(f"{name}:{_own_source(val)}"
                     if inspect.isfunction(val) else f"{name}={val!r}")
    parts.extend(_referenced_file_hashes(target))
    digest = hashlib.sha1("\n".join(parts).encode()).hexdigest()[:12]
    _CODE_HASHES[id(root)] = (root, digest)
    return digest


def _platform() -> str:
    try:
        return jax.devices()[0].platform
    except Exception:  # pragma: no cover - no jax backend at all
        return "unknown"


def _device_count() -> int:
    try:
        return jax.device_count()
    except Exception:  # pragma: no cover - no jax backend at all
        return 1


def make_key(kernel: PortableKernel, *args: Any, backend: str,
             **kwargs: Any) -> TuningKey:
    dtypes = [str(a.dtype) for a in args if hasattr(a, "dtype")]
    b = kernel.backends.get(backend)
    return TuningKey(
        kernel=kernel.name,
        backend=backend,
        shape=shape_signature(*args, **kwargs),
        dtype=dtypes[0] if dtypes else "-",
        platform=_platform(),
        code=backend_code_hash(b.fn) if b is not None else "-",
        devices=_device_count(),
    )


# --------------------------------------------------------------------------
# persistent cache
# --------------------------------------------------------------------------
def default_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "tuning.json"


class TuningCache:
    """Persistent JSON map ``key-string -> {"params", "seconds", "search"}``
    wrapped in a schema envelope (``CACHE_SCHEMA``).

    Writes are atomic (tmp file + rename) so concurrent runs cannot leave a
    torn file behind, and each ``put`` merges the on-disk state back in
    first, so two processes tuning different kernels keep each other's
    entries (the race on one *identical* key is last-writer-wins, which is
    fine — both wrote a valid measurement).  Cached ``seconds`` are
    historical: they skip the re-search, but anything computing a ratio
    against a fresh timing must re-time at the cached params
    (``benchmarks/portability.py`` does).  ``search`` records provenance
    (``"exhaustive"`` vs ``"coordinate"``); pre-v2 files lack the code-hash
    keys this schema exists for and are discarded on load.
    """

    def __init__(self, path: Optional[os.PathLike] = None) -> None:
        self.path = Path(path) if path is not None else default_cache_path()
        self._data: Optional[Dict[str, Dict[str, Any]]] = None

    @staticmethod
    def _read_entries(path: Path) -> Dict[str, Dict[str, Any]]:
        try:
            raw = json.loads(path.read_text())
        except (OSError, ValueError):
            return {}
        if not isinstance(raw, dict) or raw.get("schema") != CACHE_SCHEMA:
            return {}  # v1 (or foreign) file: stale keys, start over
        entries = raw.get("entries")
        return entries if isinstance(entries, dict) else {}

    def _load(self) -> Dict[str, Dict[str, Any]]:
        if self._data is None:
            self._data = self._read_entries(self.path)
        return self._data

    def get(self, key: TuningKey) -> Optional[Dict[str, Any]]:
        return self._load().get(key.as_str())

    def put(self, key: TuningKey, params: Dict[str, Any], seconds: float,
            search: str = "exhaustive") -> None:
        data = self._load()
        for k, v in self._read_entries(self.path).items():
            data.setdefault(k, v)
        data[key.as_str()] = {"params": dict(params),
                              "seconds": float(seconds),
                              "search": search}
        self._save(data)

    def _save(self, data: Dict[str, Dict[str, Any]]) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": CACHE_SCHEMA, "entries": data}, f,
                          indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        return len(self._load())


# --------------------------------------------------------------------------
# the sweep
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TuningResult:
    """Outcome of one ``tune()`` call."""

    kernel: str
    backend: str
    params: Dict[str, Any]            # best point ({} = declared defaults)
    seconds: float                    # best median seconds per call
    swept: List[Tuple[Dict[str, Any], float]]  # every timed (point, seconds)
    cached: bool                      # True = served from the cache, no timing
    skipped: Optional[str] = None     # reason this backend was not tuned
    search: str = "exhaustive"        # "exhaustive" | "coordinate" | "model"


#: provenances of partial searches — cache hits carrying one of these are
#: never served to a caller whose own sweep would be exhaustive
PARTIAL_SEARCHES = ("coordinate", "model")

#: distinct points the model-guided search times (the top-k of the ranked,
#: dominance-pruned grid)
MODEL_TOP_K = 4


def _coordinate_descent(kernel, space, points, budget, time_point):
    """Budgeted one-parameter-at-a-time search over the valid grid.

    Deterministic: starts at the first valid point, walks parameters in
    declaration order, moves only on strict improvement (ties keep the
    earlier point).  ``budget`` caps the number of *distinct* points timed;
    already-timed points are free.  Returns (best_params, best_secs).
    """
    names = list(space.params)
    index = {tuple(p[n] for n in names): p for p in points}
    timed: Dict[Tuple[Any, ...], float] = {}

    def measure(p):
        k = tuple(p[n] for n in names)
        if k in timed:
            return timed[k], False
        if len(timed) >= budget:
            return None, True
        timed[k] = time_point(p)
        return timed[k], False

    cur = points[0]
    cur_secs, exhausted = measure(cur)
    improved = True
    while improved and not exhausted:
        improved = False
        for name in names:
            for value in space.params[name]:
                cand_key = tuple(value if n == name else cur[n]
                                 for n in names)
                cand = index.get(cand_key)
                if cand is None:  # constraint excluded this neighbour
                    continue
                secs, exhausted = measure(cand)
                if exhausted:
                    break
                if secs < cur_secs:
                    cur, cur_secs, improved = cand, secs, True
            if exhausted:
                break
    return cur, cur_secs


def tune(kernel: PortableKernel, *args: Any, backend: str,
         cache: Optional[TuningCache] = None, iters: int = 3,
         warmup: int = 1, max_points: Optional[int] = None,
         search: str = "auto", budget: Optional[int] = None,
         **kwargs: Any) -> TuningResult:
    """Find (or recall) the best tunable point for one backend + inputs.

    Deterministic: the grid is walked in declaration order and ties break
    toward the earlier point, so two runs on the same host pick the same
    configuration.  A cache hit skips all timing.  An unavailable backend
    or a backend with an empty valid grid returns ``skipped=<reason>``
    with the declared defaults instead of raising.

    ``search`` picks the strategy: ``"exhaustive"`` times every valid
    point; ``"coordinate"`` runs a budgeted coordinate descent
    (``budget`` distinct points, default twice the summed per-parameter
    grid lengths); ``"model"`` ranks the grid by the static cost model
    (``repro.core.analysis.cost``), prunes points dominated on both
    modeled traffic and parallelism, and times only the top
    ``budget`` (default ``MODEL_TOP_K``) candidates; ``"auto"`` (default)
    uses coordinate descent only when the valid grid exceeds
    ``COORD_THRESHOLD`` points.  Partial results (coordinate/model) are
    cached with their provenance and are never served to a caller whose
    own sweep would be exhaustive.
    """
    if search not in ("auto", "exhaustive", "coordinate", "model"):
        raise ValueError(f"unknown search mode {search!r}")
    b = kernel.backends.get(backend)
    if b is None:
        raise KeyError(
            f"kernel {kernel.name!r} has no backend {backend!r}; "
            f"have {sorted(kernel.backends)}")
    if not b.is_available():
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=[], cached=False,
            skipped=f"backend {backend!r} unavailable on platform "
                    f"{_platform()!r}")

    key = make_key(kernel, *args, backend=backend, **kwargs)
    space = kernel.tunable_space(backend)
    if space is None:
        # not cached: a cache hit would flip skipped/swept on repeat runs,
        # and there is no search to skip anyway
        secs = kernel.time_backend(*args, backend=backend, iters=iters,
                                   warmup=warmup, **kwargs)
        return TuningResult(kernel=kernel.name, backend=backend, params={},
                            seconds=secs, swept=[({}, secs)], cached=False,
                            skipped="no tunable space declared")

    points = space.valid_points(*args, **kwargs)
    model = search == "model"
    coordinate = (search == "coordinate"
                  or (search == "auto" and len(points) > COORD_THRESHOLD))
    partial = coordinate or model

    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            hit_search = hit.get("search", "exhaustive")
            # a partial (coordinate/model) entry must not satisfy an
            # exhaustive request — fall through and run the full sweep
            if not (hit_search in PARTIAL_SEARCHES and not partial):
                tel.counter("tuning.cache.hit", proc="tuning")
                return TuningResult(
                    kernel=kernel.name, backend=backend,
                    params=params_from_cache(hit["params"]),
                    seconds=float(hit["seconds"]), swept=[], cached=True,
                    search=hit_search)
        tel.counter("tuning.cache.miss", proc="tuning")

    # max_points is the smoke lane's hard work bound and applies to ALL
    # strategies: exhaustive sweeps drop the grid tail, coordinate descent
    # and the model search cap their timing budgets — and no truncated
    # exhaustive result may persist
    truncated = max_points is not None and len(points) > max_points
    if truncated and not partial:
        points = points[:max_points]
    if not points:
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=[], cached=False,
            skipped="no valid tunable point for these inputs")

    swept: List[Tuple[Dict[str, Any], float]] = []
    mode = "model" if model else "coordinate" if coordinate else "exhaustive"

    def time_point(point):
        try:
            secs = kernel.time_backend(*args, backend=backend, iters=iters,
                                       warmup=warmup, **point, **kwargs)
        except (ValueError, TypeError):
            # a point the constraint failed to exclude — record and move on
            secs = float("inf")
        swept.append((point, secs))
        tel.instant("tuning.point", proc="tuning", kernel=kernel.name,
                    backend=backend, params=point, seconds=secs,
                    search=mode)
        return secs

    with tel.span("tuning.tune", proc="tuning", kernel=kernel.name,
                  backend=backend, search=mode, points=len(points)):
        if model:
            from repro.core.analysis import cost as _cost
            ranked = _cost.rank_points(kernel, backend, points, args, kwargs)
            keep = _cost.prune_dominated(ranked)
            top_k = budget if budget is not None else MODEL_TOP_K
            if max_points is not None:
                top_k = min(top_k, max_points)
            candidates = [r["params"] for r in keep[:max(1, top_k)]]
            tel.instant("tuning.model_prior", proc="tuning",
                        kernel=kernel.name, backend=backend,
                        points=len(points), pruned=len(points) - len(keep),
                        timed=len(candidates))
            best_params, best_secs = None, float("inf")
            for point in candidates:
                secs = time_point(point)
                if secs < best_secs:
                    best_secs, best_params = secs, point
        elif coordinate:
            if budget is None:
                budget = 2 * sum(len(v) for v in space.params.values())
            if max_points is not None:
                budget = min(budget, max_points)
            best_params, best_secs = _coordinate_descent(
                kernel, space, points, max(budget, 1), time_point)
        else:
            best_params, best_secs = None, float("inf")
            for point in points:
                secs = time_point(point)
                if secs < best_secs:
                    best_secs, best_params = secs, point

    if best_params is None or best_secs == float("inf"):
        return TuningResult(
            kernel=kernel.name, backend=backend, params={},
            seconds=float("inf"), swept=swept, cached=False,
            skipped="every tunable point failed to run")

    result = TuningResult(kernel=kernel.name, backend=backend,
                          params=best_params, seconds=best_secs, swept=swept,
                          cached=False, search=mode)
    # a truncated sweep (smoke lane) must not poison the cache: its key is
    # identical to the full run's, which would then inherit the partial
    # search as if it were the tuned optimum; coordinate/model results
    # persist, but carry their provenance so exhaustive callers re-search
    if cache is not None and not truncated:
        cache.put(key, result.params, result.seconds, search=mode)
    return result


_DEFAULT_CACHES: Dict[Path, TuningCache] = {}


def _default_cache() -> TuningCache:
    """Shared per-path default cache so hot callers (``tuned=True`` in a
    serving loop) parse the JSON file once, not per call."""
    path = default_cache_path()
    c = _DEFAULT_CACHES.get(path)
    if c is None:
        c = _DEFAULT_CACHES[path] = TuningCache(path)
    return c


def cached_entry(kernel: PortableKernel, *args: Any, backend: str,
                 cache: Optional[TuningCache] = None,
                 **kwargs: Any) -> Optional[Dict[str, Any]]:
    """Cache-lookup-only: the raw cache entry (``params``/``seconds``/
    ``search`` provenance) for this exact problem, or ``None`` on a miss.
    Never times anything — callers that need to *report* provenance
    (benchmark rows, dispatch logs) use this; plain param injection goes
    through :func:`cached_best_params`."""
    if cache is None:
        cache = _default_cache()
    hit = cache.get(make_key(kernel, *args, backend=backend, **kwargs))
    tel.counter("tuning.cache.hit" if hit is not None
                else "tuning.cache.miss", proc="tuning")
    return hit


def cached_best_params(kernel: PortableKernel, *args: Any, backend: str,
                       cache: Optional[TuningCache] = None,
                       **kwargs: Any) -> Dict[str, Any]:
    """Cache-lookup-only path used by ``PortableKernel.__call__(tuned=True)``:
    returns the recorded best params for this exact problem, or ``{}``
    (declared defaults) on a miss.  Never times anything."""
    hit = cached_entry(kernel, *args, backend=backend, cache=cache, **kwargs)
    return params_from_cache(hit["params"]) if hit else {}


def tune_registered(name: str, *args: Any, backend: str,
                    **kwargs: Any) -> TuningResult:
    """Convenience: ``tune()`` against the global registry by kernel name."""
    return tune(registry.get(name), *args, backend=backend, **kwargs)
