"""Three-term roofline model from compiled dry-run artifacts.

    compute term    = HLO_FLOPs       / (chips x peak_FLOP/s)
    memory term     = HLO_bytes       / (chips x HBM_bw)
    collective term = collective_bytes/ (chips x link_bw)

Hardware constants are the assignment's TPU v5e-class chip.  cost_analysis()
reports *per-partition* (single-program) numbers under SPMD, i.e. already
per-chip; we therefore do NOT divide FLOPs/bytes by the chip count again —
`chips` enters only through the per-chip peak rates.  Collective bytes parsed
from the SPMD module are likewise per-chip payloads.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional

from repro.core.hlo_analysis import CollectiveStats, parse_collective_bytes
from repro.core.hlo_cost import HloCost, analyze_hlo

__all__ = ["ChipSpec", "TPU_V5E", "NVIDIA_H100", "AMD_MI300A", "CPU_HOST",
           "CHIP_SPECS", "detect_chip", "RooflineTerms",
           "roofline_from_compiled", "model_flops"]


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float        # FLOP/s (bf16)
    hbm_bw: float            # bytes/s
    ici_bw: float            # bytes/s per link
    hbm_bytes: float         # capacity

    @property
    def ridge(self) -> float:
        """Arithmetic intensity (FLOP/byte) at the compute/memory knee."""
        return self.peak_flops / self.hbm_bw


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops=197e12,      # 197 TFLOP/s bf16
    hbm_bw=819e9,           # 819 GB/s
    ici_bw=50e9,            # ~50 GB/s/link
    hbm_bytes=16 * 2 ** 30,
)

# The paper's two GPU targets (Table: H100 PCIe/SXM and MI300A APU).
NVIDIA_H100 = ChipSpec(
    name="nvidia-h100",
    peak_flops=989e12,      # 989 TFLOP/s bf16 dense (SXM)
    hbm_bw=3.35e12,         # HBM3
    ici_bw=450e9,           # NVLink per direction
    hbm_bytes=80 * 2 ** 30,
)

AMD_MI300A = ChipSpec(
    name="amd-mi300a",
    peak_flops=981e12,      # 980.6 TFLOP/s bf16
    hbm_bw=5.3e12,          # unified HBM3
    ici_bw=128e9,           # Infinity Fabric link
    hbm_bytes=128 * 2 ** 30,
)

# Calibration floor for hosts without an accelerator (CI, laptops): a
# vectorized server core-complex.  Verdicts on this spec are only used
# relatively (the drift gate self-calibrates); the ridge (~16 FLOP/byte)
# is deliberately in the same decade as the real chips so bound verdicts
# transfer.
CPU_HOST = ChipSpec(
    name="cpu-host",
    peak_flops=5e11,
    hbm_bw=3e10,
    ici_bw=1e10,
    hbm_bytes=16 * 2 ** 30,
)

CHIP_SPECS: Dict[str, ChipSpec] = {
    c.name: c for c in (TPU_V5E, NVIDIA_H100, AMD_MI300A, CPU_HOST)
}


def detect_chip(platform: Optional[str] = None,
                device_kind: Optional[str] = None) -> ChipSpec:
    """Map the local jax backend (or explicit platform/device_kind strings)
    to the ChipSpec whose peaks the roofline verdict should name.

    TPU hosts get the assignment's v5e spec, GPU hosts are split H100 vs
    MI300A on the device-kind string, and everything else (the CPU CI
    lane, forced host devices) falls back to ``CPU_HOST``.
    """
    if platform is None:
        try:
            import jax
            dev = jax.devices()[0]
            platform = dev.platform
            device_kind = getattr(dev, "device_kind", "") or ""
        except Exception:
            return CPU_HOST
    platform = (platform or "").lower()
    kind = (device_kind or "").lower()
    if platform == "tpu":
        return TPU_V5E
    if platform in ("gpu", "cuda", "rocm"):
        if "mi300" in kind or "amd" in kind or platform == "rocm":
            return AMD_MI300A
        return NVIDIA_H100
    return CPU_HOST


@dataclasses.dataclass
class RooflineTerms:
    """Per-step roofline terms, in seconds, for one (arch, shape, mesh)."""

    flops: float                  # per-chip HLO FLOPs
    hbm_bytes: float              # per-chip HLO bytes accessed
    collective_bytes: float       # per-chip collective payload bytes
    compute_s: float
    memory_s: float
    collective_s: float
    collectives: Dict[str, Dict[str, int]]
    # memory_analysis numbers (per chip)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0
    # raw cost_analysis (loop bodies counted once — lower bounds)
    xla_flops: float = 0.0
    xla_bytes: float = 0.0
    unknown_trip_loops: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self) | {
            "dominant": self.dominant, "bound_s": self.bound_s}


def roofline_from_compiled(compiled: Any, chip: ChipSpec = TPU_V5E,
                           hlo_text: Optional[str] = None,
                           kernel_adjusted: bool = False) -> RooflineTerms:
    """Derive RooflineTerms from a jax `Compiled` object.

    Primary source is the trip-count-aware HLO walk (core/hlo_cost.py):
    XLA's own cost_analysis() counts while-loop bodies once, which under
    scan-over-layers + microbatching understates FLOPs by orders of
    magnitude.  Raw cost_analysis numbers are retained in `xla_*` fields
    for cross-checking (they form a lower bound).

    kernel_adjusted=True costs the named-scope tiles that the validated
    Pallas kernels (flash attention, WKV) keep VMEM-resident at zero HBM —
    the deployed-kernel roofline vs the plain-XLA roofline.
    """
    from repro.core.hlo_cost import KERNEL_VMEM_SCOPES
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text, vmem_scopes=KERNEL_VMEM_SCOPES
                     if kernel_adjusted else ())

    from repro.core.hlo_analysis import xla_cost_analysis
    cost = xla_cost_analysis(compiled)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    mem = compiled.memory_analysis()
    arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
    out_b = int(getattr(mem, "output_size_in_bytes", 0))
    tmp_b = int(getattr(mem, "temp_size_in_bytes", 0))
    alias_b = int(getattr(mem, "alias_size_in_bytes", 0))
    # peak live = args + outputs + temps - aliased (donated args reused)
    peak_b = arg_b + out_b + tmp_b - alias_b

    flops = max(hc.flops, xla_flops)
    hbm_bytes = hc.hbm_bytes if kernel_adjusted \
        else max(hc.hbm_bytes, xla_bytes)
    coll_bytes = hc.collective_bytes
    colls = {k: {"count": int(hc.collective_count_by_kind.get(k, 0)),
                 "bytes": int(v)}
             for k, v in sorted(hc.collective_bytes_by_kind.items())}

    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=coll_bytes,
        compute_s=flops / chip.peak_flops,
        memory_s=hbm_bytes / chip.hbm_bw,
        collective_s=coll_bytes / chip.ici_bw,
        collectives=colls,
        argument_bytes=arg_b,
        output_bytes=out_b,
        temp_bytes=tmp_b,
        peak_bytes=peak_b,
        xla_flops=xla_flops,
        xla_bytes=xla_bytes,
        unknown_trip_loops=hc.unknown_trip_loops,
    )


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training; 2·N·D for a forward/decode pass.

    For MoE, pass the *active* parameter count.
    """
    per_token = 6.0 if kind == "train" else 2.0
    return per_token * n_params_active * tokens
