"""CLI entry point: ``python -m repro.core.telemetry summarize <trace>``."""

import sys

from repro.core.telemetry.summarize import main

if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
