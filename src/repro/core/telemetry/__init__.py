"""Registry-wide runtime telemetry (schema ``repro.telemetry/v1``).

One shared substrate for every measured number in the repo: structured
spans (monotonic start/duration, parent nesting), counters, and gauges in a
thread-safe bounded ring buffer, with three exporters (JSONL event log,
Chrome/Perfetto ``trace.json``, flat metrics snapshot for ``BENCH_*.json``
artifacts) and a CLI::

    python -m repro.core.telemetry summarize <trace>   # count/total/p50/p95/p99

Control is environmental and zero-cost when off::

    REPRO_TELEMETRY=off          # default: module-level no-op fast path
    REPRO_TELEMETRY=on           # record into the in-memory ring
    REPRO_TELEMETRY=jsonl:PATH   # record + flush the JSONL log at exit
    REPRO_TELEMETRY_CAP=65536    # ring capacity (events)

Instrumentation sites call the module-level helpers::

    from repro.core import telemetry as tel
    with tel.span("serving.decode_step", proc="engine", active=n):
        ...                       # around the jit call, never inside it
    tel.counter("tuning.cache.hit")
    tel.gauge("serving.queue_depth", len(queue), proc="engine")

When disabled (the default) ``span`` returns a shared no-op context manager
and ``instant``/``counter``/``gauge`` return immediately — instrumented hot
paths pay one module-attribute load and one ``is None`` check.  Events must
fire at the Python/driver level only (trace-time-safe: a jitted consumer
emits execution events once per call, not once per trace), and enabling
telemetry must never change compiled numerics.

Enabling telemetry also installs the ``jax.monitoring`` bridge
(:mod:`repro.core.telemetry.jaxmon`): XLA backend compiles become the
``jax.compile.backend_compile`` counter plus ``jax.compile`` spans, so
recompile storms — the runtime twin of the static auditor's ``recompile``
pass — are visible in every trace.
"""

from __future__ import annotations

import atexit
import os
from typing import Any, Dict, List, Optional

from repro.core.telemetry.recorder import (DEFAULT_CAPACITY, NOOP_SPAN,
                                           Recorder, RingLog, SCHEMA,
                                           safe_attrs)
from repro.core.telemetry.export import (chrome_trace, metrics_snapshot,
                                         read_events, write_chrome_trace,
                                         write_jsonl)
from repro.core.telemetry.summarize import (format_summary, percentile,
                                            summarize_events, summarize_file)

__all__ = [
    "SCHEMA", "ENV", "CAP_ENV", "Recorder", "RingLog", "configure",
    "enabled", "recorder", "span", "instant", "counter", "gauge",
    "snapshot", "reset", "safe_attrs", "write_jsonl", "write_chrome_trace",
    "chrome_trace", "read_events", "metrics_snapshot", "summarize_file",
    "summarize_events", "format_summary", "percentile", "DEFAULT_CAPACITY",
]

ENV = "REPRO_TELEMETRY"
CAP_ENV = "REPRO_TELEMETRY_CAP"

_recorder: Optional[Recorder] = None      # None <=> disabled fast path
_jsonl_path: Optional[str] = None


def configure(mode: Optional[str] = None,
              capacity: Optional[int] = None) -> Optional[Recorder]:
    """(Re)configure global telemetry; returns the active recorder or None.

    ``mode`` follows the env contract: ``"off"``/``""``/None disables,
    ``"on"`` records in memory, ``"jsonl:<path>"`` records and flushes the
    JSONL log at interpreter exit (or on :func:`flush`).  Reconfiguring
    replaces the recorder (prior events are dropped — snapshot first).
    """
    global _recorder, _jsonl_path
    mode = (mode or "off").strip()
    if mode.lower() in ("", "off", "0", "false"):
        _recorder, _jsonl_path = None, None
        return None
    if capacity is None:
        capacity = int(os.environ.get(CAP_ENV, DEFAULT_CAPACITY))
    path: Optional[str] = None
    if mode.lower().startswith("jsonl:"):
        path = mode[len("jsonl:"):]
        if not path:
            raise ValueError(f"{ENV}=jsonl:<path> needs a path")
    elif mode.lower() not in ("on", "1", "true"):
        raise ValueError(
            f"bad {ENV} value {mode!r}: expected off|on|jsonl:<path>")
    _recorder = Recorder(capacity=capacity)
    _jsonl_path = path
    from repro.core.telemetry import jaxmon
    jaxmon.install()
    return _recorder


def enabled() -> bool:
    return _recorder is not None


def recorder() -> Optional[Recorder]:
    """The active recorder (None when disabled)."""
    return _recorder


# ---- recording fast paths ------------------------------------------------
def span(name: str, proc: str = "main", **attrs: Any):
    rec = _recorder
    if rec is None:
        return NOOP_SPAN
    return rec.span(name, proc=proc, **attrs)


def instant(name: str, proc: str = "main", **attrs: Any) -> None:
    rec = _recorder
    if rec is not None:
        rec.instant(name, proc=proc, **attrs)


def counter(name: str, value: float = 1.0, proc: str = "main") -> None:
    rec = _recorder
    if rec is not None:
        rec.counter(name, value, proc=proc)


def gauge(name: str, value: float, proc: str = "main") -> None:
    rec = _recorder
    if rec is not None:
        rec.gauge(name, value, proc=proc)


def snapshot() -> Dict[str, Any]:
    """Metrics snapshot of the active recorder ({} when disabled)."""
    rec = _recorder
    return rec.snapshot() if rec is not None else {}


def events() -> List[Dict[str, Any]]:
    rec = _recorder
    return rec.event_list() if rec is not None else []


def reset() -> None:
    """Clear the active recorder's events and aggregates (keep recording)."""
    rec = _recorder
    if rec is not None:
        rec.clear()


def flush(path: Optional[str] = None) -> Optional[str]:
    """Write the JSONL log now (to ``path`` or the ``jsonl:`` env path)."""
    rec = _recorder
    target = path or _jsonl_path
    if rec is None or target is None:
        return None
    write_jsonl(target, rec)
    return target


@atexit.register
def _flush_at_exit() -> None:  # pragma: no cover - exercised via subprocess
    try:
        flush()
    except Exception:
        pass


# env bootstrap: a bad value must fail loudly at import, not silently
# record nothing while the user thinks they are tracing
configure(os.environ.get(ENV))
