"""Exporters: JSONL event log, Chrome/Perfetto trace.json, metrics snapshot.

Three consumers, three formats:

  * ``write_jsonl`` — the archival form.  Line 1 is a schema header, then
    one event object per line, then a footer carrying the aggregated
    counters/gauges and the ring-eviction count.  ``read_events`` reads it
    back (and also accepts a Chrome ``trace.json``, so the summarize CLI
    works on either artifact).
  * ``chrome_trace`` / ``write_chrome_trace`` — a ``chrome://tracing`` /
    Perfetto-loadable ``{"traceEvents": [...]}`` document: spans become
    complete events (``ph: "X"`` with microsecond ``ts``/``dur``),
    counters/gauges become counter tracks (``ph: "C"``), instants become
    ``ph: "i"``, and the logical ``proc``/``tid`` labels map to stable
    pid/tid ids declared via ``process_name``/``thread_name`` metadata
    (``ph: "M"``) — so the engine's prefill/decode timeline and its worker
    threads land on separate labelled tracks.
  * ``Recorder.snapshot()`` (re-exported here as ``metrics_snapshot``) —
    the flat dict benchmarks embed in their ``BENCH_*.json`` artifacts.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Union

from repro.core.telemetry.recorder import Recorder, SCHEMA

EventSource = Union[Recorder, Iterable[Dict[str, Any]]]


def _events_of(source: EventSource) -> List[Dict[str, Any]]:
    if isinstance(source, Recorder):
        return source.event_list()
    return list(source)


def write_jsonl(path: str, source: EventSource,
                meta: Optional[Dict[str, Any]] = None,
                footer_data: Optional[Dict[str, Any]] = None) -> int:
    """Write header + events + footer; returns the number of event lines.

    ``footer_data`` overrides the footer aggregates — callers that drained
    a recorder's ring incrementally pass the recorder's final ``snapshot()``
    here so the counters still land in the file.
    """
    events = _events_of(source)
    header: Dict[str, Any] = {"schema": SCHEMA, "kind": "header"}
    footer: Dict[str, Any] = {"kind": "footer"}
    if isinstance(source, Recorder):
        header["t0_unix"] = source.epoch_unix
        snap = source.snapshot()
        footer.update(counters=snap["counters"], gauges=snap["gauges"],
                      events_dropped=snap["events_dropped"])
    if footer_data:
        footer.update({k: v for k, v in footer_data.items()
                       if k in ("counters", "gauges", "events_dropped")})
    if meta:
        header.update(meta)
    with open(path, "w") as f:
        f.write(json.dumps(header, sort_keys=True) + "\n")
        for ev in events:
            f.write(json.dumps(ev, sort_keys=True) + "\n")
        f.write(json.dumps(footer, sort_keys=True) + "\n")
    return len(events)


def read_events(path: str) -> Dict[str, Any]:
    """Load a trace file into ``{"header", "events", "footer"}``.

    Accepts both the JSONL event log and a Chrome ``trace.json`` (detected
    by its ``traceEvents`` key; ``ph: "X"`` rows are mapped back to span
    events with seconds-valued ``ts``/``dur`` so summarize treats the two
    formats identically).
    """
    with open(path) as f:
        first = f.read(4096)
    if first.lstrip().startswith("{") and '"traceEvents"' in first:
        doc = json.loads(open(path).read())
        events = []
        for te in doc.get("traceEvents", []):
            if te.get("ph") == "X":
                events.append({
                    "kind": "span", "name": te["name"],
                    "ts": te["ts"] / 1e6, "dur": te.get("dur", 0.0) / 1e6,
                    "proc": str(te.get("pid", "main")),
                    "tid": str(te.get("tid", "main")),
                    "sid": None, "parent": None,
                    "attrs": te.get("args", {}),
                })
            elif te.get("ph") == "C":
                args = te.get("args", {})
                val = next(iter(args.values()), 0.0)
                events.append({"kind": "counter", "name": te["name"],
                               "ts": te["ts"] / 1e6, "value": val,
                               "proc": str(te.get("pid", "main")),
                               "tid": str(te.get("tid", "main")),
                               "attrs": {}})
        return {"header": {"schema": SCHEMA, "format": "chrome"},
                "events": events, "footer": {}}

    header: Dict[str, Any] = {}
    footer: Dict[str, Any] = {}
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            kind = obj.get("kind")
            if kind == "header":
                header = obj
            elif kind == "footer":
                footer = obj
            else:
                events.append(obj)
    return {"header": header, "events": events, "footer": footer}


def chrome_trace(source: EventSource,
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Build the Chrome tracing document (pure dict — json.dump it)."""
    events = _events_of(source)
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    trace: List[Dict[str, Any]] = []

    def pid_of(proc: str) -> int:
        if proc not in pids:
            pids[proc] = len(pids) + 1
            trace.append({"ph": "M", "name": "process_name",
                          "pid": pids[proc], "tid": 0,
                          "args": {"name": proc}})
        return pids[proc]

    def tid_of(proc: str, tid: str) -> int:
        key = (proc, tid)
        if key not in tids:
            tids[key] = len(tids) + 1
            trace.append({"ph": "M", "name": "thread_name",
                          "pid": pid_of(proc), "tid": tids[key],
                          "args": {"name": tid}})
        return tids[key]

    for ev in events:
        proc = ev.get("proc", "main")
        tid = ev.get("tid", "main")
        base = {"pid": pid_of(proc), "tid": tid_of(proc, tid),
                "ts": ev["ts"] * 1e6, "name": ev["name"], "cat": ev["kind"]}
        if ev["kind"] == "span":
            args = dict(ev.get("attrs", {}))
            if ev.get("parent") is not None:
                args["parent_sid"] = ev["parent"]
            trace.append({**base, "ph": "X", "dur": ev["dur"] * 1e6,
                          "args": args})
        elif ev["kind"] in ("counter", "gauge"):
            trace.append({**base, "ph": "C", "cat": ev["kind"],
                          "args": {ev["name"]: ev.get("value", 0.0)}})
        else:
            trace.append({**base, "ph": "i", "s": "t",
                          "args": dict(ev.get("attrs", {}))})
    doc = {"traceEvents": trace, "displayTimeUnit": "ms",
           "otherData": {"schema": SCHEMA, **(meta or {})}}
    return doc


def write_chrome_trace(path: str, source: EventSource,
                       meta: Optional[Dict[str, Any]] = None) -> int:
    doc = chrome_trace(source, meta=meta)
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for te in doc["traceEvents"] if te["ph"] != "M")


def metrics_snapshot(recorder: Recorder) -> Dict[str, Any]:
    """Alias for ``Recorder.snapshot()`` so benchmark code imports one
    exporter module for all three output forms."""
    return recorder.snapshot()
