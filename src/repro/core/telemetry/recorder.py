"""Structured-event recorder: spans, counters, gauges in a bounded ring.

The paper's Eq.-4 portability metric and the serving SLO report are only as
trustworthy as the instrumentation behind them, so every measured number in
this repo should be able to carry provenance: *what* ran, *when*, *under
which parameters*, nested inside *which* larger operation.  This module is
the zero-dependency (stdlib-only) substrate for that:

  * :class:`Recorder` holds a thread-safe bounded ring buffer of event
    dicts (schema ``repro.telemetry/v1``) plus aggregated counters and
    last-value gauges that never suffer ring eviction;
  * spans measure ``time.perf_counter()`` start/duration and nest — each
    thread keeps its own span stack, so a child span records its parent's
    id and exporters can rebuild the tree;
  * events are timestamped relative to the recorder's epoch (monotonic),
    with the wall-clock epoch recorded once for provenance.

Event fields (all events)::

    kind   "span" | "instant" | "counter" | "gauge"
    name   dotted event name ("serving.decode_step", "tuning.cache.hit")
    ts     seconds since recorder epoch (monotonic)
    proc   logical process/track label ("engine", "tuning", ...)
    tid    recording thread's name
    attrs  {str: scalar} tags (kernel, backend, uid, ...)

plus ``dur`` (seconds) / ``sid`` / ``parent`` on spans and ``value`` on
counter/gauge samples.

Instrumented hot paths must stay trace-time-safe: record only at the
Python/driver level (around ``jit`` calls, never inside traced code), so an
instrumented program emits execution events once per *call*, not once per
*trace* — and compiled numerics are bitwise independent of whether
telemetry is on.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

SCHEMA = "repro.telemetry/v1"

#: default ring capacity (events); override per-Recorder or via
#: ``REPRO_TELEMETRY_CAP`` (read in __init__.py's env bootstrap)
DEFAULT_CAPACITY = 65536

_SCALARS = (bool, int, float, str, tuple, type(None))


def safe_attrs(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only JSON-friendly scalar tags; everything else becomes repr.

    Instrumentation sites pass whatever they have (params dicts may hold
    tuples, callers may pass numpy ints) — the ring must never hold live
    array references.
    """
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, _SCALARS):
            out[k] = list(v) if isinstance(v, tuple) else v
        elif isinstance(v, dict):
            out[k] = safe_attrs(v)
        elif hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            out[k] = v.item()          # numpy/jax scalar
        else:
            out[k] = repr(v)
    return out


class _Span:
    """Context manager recording one span event on exit."""

    __slots__ = ("_rec", "name", "proc", "attrs", "sid", "parent", "_t0")

    def __init__(self, rec: "Recorder", name: str, proc: str,
                 attrs: Dict[str, Any]):
        self._rec = rec
        self.name = name
        self.proc = proc
        self.attrs = attrs
        self.sid = next(rec._ids)
        self.parent: Optional[int] = None
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        stack = self._rec._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.sid)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        stack = self._rec._stack()
        if stack and stack[-1] == self.sid:
            stack.pop()
        self._rec._record({
            "kind": "span", "name": self.name,
            "ts": self._t0 - self._rec.epoch, "dur": t1 - self._t0,
            "sid": self.sid, "parent": self.parent,
            "proc": self.proc, "tid": threading.current_thread().name,
            "attrs": self.attrs,
        })


class NoopSpan:
    """Shared do-nothing span for the disabled fast path (reentrant,
    stateless — one instance serves every call site)."""

    __slots__ = ()

    def __enter__(self) -> "NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NOOP_SPAN = NoopSpan()


class Recorder:
    """Thread-safe bounded event ring + counter/gauge aggregates."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self.capacity = int(capacity)
        self.events: Deque[Dict[str, Any]] = deque(maxlen=self.capacity)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.dropped = 0                     # events evicted from the ring
        self.epoch = time.perf_counter()     # monotonic zero for ts fields
        self.epoch_unix = time.time()        # wall-clock provenance
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()

    # ---- internals ----------------------------------------------------
    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self.events) == self.capacity:
                self.dropped += 1
            self.events.append(ev)

    def _now(self) -> float:
        return time.perf_counter() - self.epoch

    # ---- recording API -------------------------------------------------
    def span(self, name: str, proc: str = "main", **attrs: Any) -> _Span:
        return _Span(self, name, proc, safe_attrs(attrs))

    def instant(self, name: str, proc: str = "main", **attrs: Any) -> None:
        stack = self._stack()
        self._record({
            "kind": "instant", "name": name, "ts": self._now(),
            "parent": stack[-1] if stack else None, "proc": proc,
            "tid": threading.current_thread().name,
            "attrs": safe_attrs(attrs),
        })

    def counter(self, name: str, value: float = 1.0,
                proc: str = "main") -> float:
        """Increment an aggregated counter (and log the new total as a
        counter sample so Chrome tracing can draw the track)."""
        with self._lock:
            total = self.counters.get(name, 0.0) + value
            self.counters[name] = total
            if len(self.events) == self.capacity:
                self.dropped += 1
            self.events.append({
                "kind": "counter", "name": name, "ts": self._now(),
                "value": total, "proc": proc,
                "tid": threading.current_thread().name, "attrs": {},
            })
        return total

    def gauge(self, name: str, value: float, proc: str = "main") -> None:
        """Record the current value of a sampled quantity (queue depth,
        slot occupancy).  Last value wins in the snapshot; every sample
        lands in the ring for the trace timeline."""
        with self._lock:
            self.gauges[name] = float(value)
            if len(self.events) == self.capacity:
                self.dropped += 1
            self.events.append({
                "kind": "gauge", "name": name, "ts": self._now(),
                "value": float(value), "proc": proc,
                "tid": threading.current_thread().name, "attrs": {},
            })

    # ---- reading -------------------------------------------------------
    def drain(self) -> List[Dict[str, Any]]:
        """Copy-and-clear the event ring (aggregates are kept)."""
        with self._lock:
            out = list(self.events)
            self.events.clear()
        return out

    def event_list(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self.events)

    def snapshot(self) -> Dict[str, Any]:
        """Flat metrics dict benchmarks can embed in their artifacts:
        counters, gauges (last value), per-span-name count/total, and the
        ring-eviction count (so a truncated trace is visible as such)."""
        with self._lock:
            events = list(self.events)
            counters = dict(self.counters)
            gauges = dict(self.gauges)
            dropped = self.dropped
        spans: Dict[str, Dict[str, float]] = {}
        for ev in events:
            if ev["kind"] != "span":
                continue
            agg = spans.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += ev["dur"]
        return {"schema": SCHEMA, "counters": counters, "gauges": gauges,
                "spans": spans, "events_recorded": len(events),
                "events_dropped": dropped}

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.counters.clear()
            self.gauges.clear()
            self.dropped = 0


class RingLog:
    """Tiny always-on bounded record stream for subsystems that must keep
    their own history regardless of whether global telemetry is enabled
    (``models/attention``'s dispatch log).  Thread-safe; eviction drops the
    oldest records, never the newest."""

    def __init__(self, capacity: int = 256) -> None:
        self._events: Deque[Dict[str, Any]] = deque(maxlen=int(capacity))
        self._lock = threading.Lock()

    def append(self, record: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(record)

    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(r) for r in self._events]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
