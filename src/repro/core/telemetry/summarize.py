"""Per-span-name latency summary of a recorded trace.

``python -m repro.core.telemetry summarize <trace>`` prints, for every span
name in a JSONL event log or Chrome ``trace.json``::

    name  count  total_ms  p50_ms  p95_ms  p99_ms

plus the aggregated counters from the footer (compile events, cache
hit/miss, dispatch counts) when the file carries them.  This is the
human-facing end of the telemetry pipeline: run a benchmark with
``REPRO_TELEMETRY=jsonl:/tmp/trace.jsonl``, then summarize the file.

Percentiles use linear interpolation between order statistics — the same
definition as ``numpy.percentile``'s default — implemented in pure Python
so the telemetry package stays stdlib-only.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence

from repro.core.telemetry.export import read_events


def percentile(values: Sequence[float], q: float) -> float:
    """numpy-compatible linear-interpolation percentile (0 <= q <= 100)."""
    if not values:
        raise ValueError("percentile() of empty sequence")
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def summarize_events(events: List[Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    """{span name -> {count, total_ms, p50_ms, p95_ms, p99_ms}}."""
    durs: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("kind") == "span" and "dur" in ev:
            durs.setdefault(ev["name"], []).append(float(ev["dur"]))
    out: Dict[str, Dict[str, Any]] = {}
    for name in sorted(durs):
        ms = [d * 1e3 for d in durs[name]]
        out[name] = {
            "count": len(ms),
            "total_ms": sum(ms),
            "p50_ms": percentile(ms, 50),
            "p95_ms": percentile(ms, 95),
            "p99_ms": percentile(ms, 99),
        }
    return out


def summarize_file(path: str) -> Dict[str, Any]:
    doc = read_events(path)
    return {
        "schema": doc["header"].get("schema", "?"),
        "spans": summarize_events(doc["events"]),
        "counters": doc["footer"].get("counters", {}),
        "gauges": doc["footer"].get("gauges", {}),
        "events": len(doc["events"]),
        "events_dropped": doc["footer"].get("events_dropped", 0),
    }


def format_summary(summary: Dict[str, Any]) -> str:
    lines = [f"trace: {summary['events']} events "
             f"({summary['events_dropped']} dropped) "
             f"schema {summary['schema']}"]
    spans = summary["spans"]
    if spans:
        w = max(len(n) for n in spans)
        lines.append(f"{'span'.ljust(w)}  {'count':>6} {'total_ms':>10} "
                     f"{'p50_ms':>9} {'p95_ms':>9} {'p99_ms':>9}")
        for name, s in spans.items():
            lines.append(
                f"{name.ljust(w)}  {s['count']:>6d} {s['total_ms']:>10.3f} "
                f"{s['p50_ms']:>9.3f} {s['p95_ms']:>9.3f} "
                f"{s['p99_ms']:>9.3f}")
    else:
        lines.append("(no span events)")
    if summary["counters"]:
        lines.append("counters:")
        for name in sorted(summary["counters"]):
            lines.append(f"  {name} = {summary['counters'][name]:g}")
    return "\n".join(lines)


def main(argv: List[str]) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.telemetry",
        description="summarize a repro.telemetry/v1 trace")
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summarize",
                       help="per-span count/total/p50/p95/p99 of a trace")
    s.add_argument("trace", help="JSONL event log or Chrome trace.json")
    s.add_argument("--json", action="store_true",
                   help="machine-readable output instead of the table")
    args = ap.parse_args(argv)

    summary = summarize_file(args.trace)
    if args.json:
        print(json.dumps(summary, indent=1, sort_keys=True))
    else:
        print(format_summary(summary))
    return 0
