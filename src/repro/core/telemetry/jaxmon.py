"""jax.monitoring bridge: count compilations as telemetry events.

PR 7's static auditor proves *statically* that no backend builder bakes
scalars into its compile key; this is the runtime twin — a recompile storm
(the fixed-shape serving engine retracing mid-benchmark, a tuning sweep
recompiling per point) becomes a visible counter in every trace and in the
serving artifact's ``jax_compile_events`` column.

jax 0.4.37 reports compilation through ``jax.monitoring`` duration events:

  * ``/jax/core/compile/backend_compile_duration`` — one per XLA backend
    compile (the expensive step; this is what we count as a compilation),
  * ``/jax/core/compile/jaxpr_trace_duration`` — one per Python trace,
  * ``/jax/compilation_cache/*`` plain events — persistent-cache hits.

``install()`` registers one forwarding listener, once per process
(jax.monitoring has no per-listener unregister, and
``clear_event_listeners`` would nuke listeners we don't own).  The listener
reads the *current* global recorder on every event, so disabling telemetry
makes it a cheap no-op and re-enabling picks the new recorder up without
re-registration.  Counter names are the jax event path with ``/`` -> ``.``
(``jax.core.compile.jaxpr_trace_duration``); the backend compile
additionally lands as a ``jax.compile`` span so summarize reports
compile-time percentiles, and as the :data:`COMPILE_COUNTER` aggregate the
serving artifact reports.
"""

from __future__ import annotations

import threading
from typing import Optional

BACKEND_COMPILE = "/jax/core/compile/backend_compile_duration"
TRACE = "/jax/core/compile/jaxpr_trace_duration"

#: aggregated-counter name for backend compiles (the "recompile storm"
#: runtime metric reported in BENCH_serving.json)
COMPILE_COUNTER = "jax.compile.backend_compile"

_installed = False
_lock = threading.Lock()


def _counter_name(event: str) -> str:
    # "/jax/core/compile/jaxpr_trace_duration" -> "jax.core.compile...."
    return event.strip("/").replace("/", ".")


def install() -> bool:
    """Register the forwarding listeners (idempotent).  Returns True when
    the listeners are active, False when jax is unimportable."""
    global _installed
    with _lock:
        if _installed:
            return True
        try:
            from jax import monitoring
        except Exception:  # pragma: no cover - jax always present here
            return False

        def on_duration(event: str, duration: float, **kw) -> None:
            rec = _current()
            if rec is None or not event.startswith("/jax/"):
                return
            if event == BACKEND_COMPILE:
                rec.counter(COMPILE_COUNTER, proc="jax")
                # a span with an end-anchored window: monitoring reports
                # duration only, so place it ending "now"
                rec._record({
                    "kind": "span", "name": "jax.compile",
                    "ts": max(rec._now() - duration, 0.0),
                    "dur": duration, "sid": next(rec._ids), "parent": None,
                    "proc": "jax",
                    "tid": threading.current_thread().name, "attrs": {}})
            elif event == TRACE:
                rec.counter(_counter_name(event), proc="jax")

        def on_event(event: str, **kw) -> None:
            rec = _current()
            if rec is None or not event.startswith("/jax/"):
                return
            rec.counter(_counter_name(event), proc="jax")

        monitoring.register_event_duration_secs_listener(on_duration)
        monitoring.register_event_listener(on_event)
        _installed = True
        return True


def _current() -> Optional[object]:
    from repro.core import telemetry
    return telemetry._recorder
