"""Static analysis of lowered/compiled XLA artifacts.

`compiled.cost_analysis()` gives HLO FLOPs and bytes-accessed, but not
collective traffic.  This module parses the (compiled, post-SPMD-partitioning)
HLO text and sums the operand bytes of every collective op — the paper's
profiling role (ncu) played by the compiler IR, as fits a dry-run-only
environment.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Iterable, Mapping, Optional, Tuple

__all__ = [
    "CollectiveStats",
    "parse_collective_bytes",
    "dtype_bytes",
    "parse_shape_bytes",
    "xla_cost_analysis",
]


def xla_cost_analysis(compiled) -> Mapping[str, float]:
    """`compiled.cost_analysis()` as a flat dict on every jax version.

    Older jax returns a one-element list of per-device dicts; newer jax
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

# XLA HLO collective op mnemonics we account for.
_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "s4": 1, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u2": 1, "u4": 1, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}


def dtype_bytes(dtype: str) -> int:
    try:
        return _DTYPE_BYTES[dtype]
    except KeyError as e:
        raise ValueError(f"unknown HLO dtype {dtype!r}") from e


# An HLO shape like  bf16[256,4096]{1,0}  or  f32[] — capture dtype + dims.
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[a-z0-9]*)?)\[([0-9,]*)\]")

# Start of an HLO instruction line:  %name = <shape-or-tuple> opcode(
# We match the result type region then look for the collective opcode.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(" + "|".join(_COLLECTIVE_KINDS) + r")(?:-start|-done)?\b",
)


def parse_shape_bytes(shape_text: str) -> int:
    """Sum bytes over all array shapes appearing in `shape_text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue  # e.g. token[] / opaque
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective byte/opcount totals for one HLO module."""

    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> Dict[str, Dict[str, int]]:
        return {
            k: {"count": self.count_by_kind.get(k, 0),
                "bytes": self.bytes_by_kind.get(k, 0)}
            for k in sorted(set(self.bytes_by_kind) | set(self.count_by_kind))
        }


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective instruction.

    We use the *result* shape of each collective (the tuple/array on the LHS):
    for all-gather that is the gathered (larger) output, for reduce-scatter
    the scattered (smaller) output, for all-reduce the full buffer — a
    reasonable, conservative proxy for link traffic per op.  `-start/-done`
    async pairs are counted once (on `-start`; bare ops counted normally).
    """
    bytes_by_kind: Dict[str, int] = defaultdict(int)
    count_by_kind: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[1].split("(", 1)[0]:
            # async completion: payload already counted at -start
            continue
        result_region, kind = m.group(1), m.group(2)
        nbytes = parse_shape_bytes(result_region)
        bytes_by_kind[kind] += nbytes
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind=dict(bytes_by_kind),
                           count_by_kind=dict(count_by_kind))
