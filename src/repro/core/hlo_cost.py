"""Trip-count-aware cost model over compiled (post-SPMD, post-fusion) HLO.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE — under
scan-over-layers + microbatch accumulation that understates FLOPs by orders
of magnitude (e.g. 47x for a 24-layer model with 16 microbatches).  This
module parses the optimized HLO text, recovers static trip counts from loop
condition computations (`lax.scan` lowers to `while(i < N)`), and walks the
call graph multiplying costs by loop multiplicity:

  flops        dot/convolution from shapes + contraction dims; elementwise
               and reduces counted inside fusion bodies
  hbm bytes    operands + results of *top-level* (fusion-boundary) ops —
               i.e. post-fusion traffic, which is what HBM actually sees
  collectives  result bytes of all-gather/all-reduce/reduce-scatter/
               all-to-all/collective-permute, x multiplicity

All numbers are per-chip (the SPMD module is the per-chip program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo", "arithmetic_intensity"]

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction:  [ROOT] %name = <shape> opcode(...) , attrs
# The shape region may be an arbitrarily nested tuple — match lazily up to
# the first bare word immediately followed by '(' (the opcode).
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(.*?)\s+"
    r"([a-z][\w\-]*)\(")

_COMP_HEADER = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_COUNT = re.compile(r'known_trip_count"?:?\s*\{"?n"?:\s*"?(\d+)')
_SHAPE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*)\[([0-9,]*)\]")
_CALLED = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                     r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_ATTR = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine", "power",
    "erf", "atan2", "floor", "ceil", "round-nearest-afz", "sign",
    "select", "compare", "and", "or", "xor", "not", "clamp",
}


_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _operand_names(ins_line: str) -> List[str]:
    """Operand instruction names of `... = <shape> op(<operands>), attrs`.

    Depending on the XLA version the operand list is either bare names
    (`dot(%a, %b)`) or typed (`dot(f32[64,128]{1,0} %a, f32[...] %b)`); the
    latter breaks naive comma-splitting because shapes embed commas.  `%name`
    tokens are unambiguous in both formats.  The operand list is located
    *after* the opcode — a tuple-shaped result (`(f32[..], f32[..]) fusion`)
    puts an earlier paren group on the line that must not be mistaken for it.
    """
    m = _INSTR.match(ins_line)
    if m:
        body = ins_line[m.end():].split(")", 1)[0]
    else:
        args = re.search(r"\(([^)]*)\)", ins_line)
        if not args:
            return []
        body = args.group(1)
    names = _OPERAND_NAME.findall(body)
    if names:
        return names
    # no '%' sigils at all (stripped dumps): fall back to comma-split words
    return [a.strip().split()[-1] for a in body.split(",") if a.strip()]


def _shape_elems_bytes(shape_text: str) -> Tuple[int, int]:
    """total (elements, bytes) across all array shapes in the text."""
    elems = tot = 0
    for dtype, dims in _SHAPE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dtype]
    return elems, tot


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_bytes_by_kind.items():
            self.collective_bytes_by_kind[k] += v * mult
        for k, v in other.collective_count_by_kind.items():
            self.collective_count_by_kind[k] += v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


class _Module:
    def __init__(self, text: str):
        self.computations: Dict[str, List[_Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            header = _COMP_HEADER.match(line)
            if header and line.endswith("{"):
                current = header.group(1)
                self.computations[current] = []
                if raw.lstrip().startswith("ENTRY"):
                    self.entry = current
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            m = _INSTR.match(line)
            if m:
                self.computations[current].append(
                    _Instr(name=m.group(1), shape=m.group(2),
                           opcode=m.group(3), line=line))

    # ---- trip counts ---------------------------------------------------
    def trip_count(self, cond_comp: str) -> Optional[int]:
        """Recover N from a scan-style condition: compare(i, N), LT."""
        instrs = self.computations.get(cond_comp, [])
        consts: Dict[str, int] = {}
        for ins in instrs:
            if ins.opcode == "constant":
                cm = re.search(r"constant\((-?[0-9]+)\)", ins.line)
                if cm:
                    consts[ins.name] = int(cm.group(1))
        for ins in instrs:
            if ins.opcode == "compare" and "direction=LT" in ins.line:
                args = re.findall(r"\(([^)]*)\)", ins.line)
                if args:
                    names = [a.strip().lstrip("%")
                             for a in args[0].split(",")]
                    for n in names:
                        if n in consts:
                            return consts[n]
        # single constant in the whole condition is a safe fallback
        if len(consts) == 1:
            return next(iter(consts.values()))
        return None

    # ---- per-instruction local cost -------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {i.name: i.shape for i in self.computations.get(comp, [])}

    def _dot_flops(self, ins: _Instr, symbols: Dict[str, str]) -> float:
        out_elems, _ = _shape_elems_bytes(ins.shape)
        names = _operand_names(ins.line)
        if not names:
            return 0.0
        lhs_shape = symbols.get(names[0], "")
        sm = _SHAPE.search(lhs_shape)
        if not sm:
            return 0.0
        dims = [int(d) for d in sm.group(2).split(",") if d]
        cdims = _DIMS_ATTR.search(ins.line)
        k = 1
        if cdims:
            for idx in cdims.group(1).split(","):
                if idx:
                    k *= dims[int(idx)]
        return 2.0 * out_elems * k

    def instr_cost(self, ins: _Instr, comp: str, in_fusion: bool,
                   symbols: Dict[str, str],
                   vmem_scopes: Tuple[str, ...] = ()) -> HloCost:
        c = HloCost()
        op = ins.opcode
        out_elems, out_bytes = _shape_elems_bytes(ins.shape)
        # kernel-adjusted mode: ops inside a named scope that a validated
        # Pallas kernel keeps VMEM-resident are costed at zero HBM traffic
        # (dot operand loads excepted — the kernel DMAs those blocks in).
        in_vmem_scope = any(s in ins.line for s in vmem_scopes)

        if op == "dot":
            c.flops += self._dot_flops(ins, symbols)
        elif op in _ELEMENTWISE_FLOP_OPS:
            c.flops += out_elems
            if op in ("exponential", "log", "tanh", "logistic", "rsqrt",
                      "sqrt", "power", "sine", "cosine", "erf",
                      "exponential-minus-one", "log-plus-one"):
                c.transcendentals += out_elems
        elif op == "reduce" or op == "reduce-window":
            # count reduction input elements
            names = _operand_names(ins.line)
            if names:
                in_elems, _ = _shape_elems_bytes(symbols.get(names[0], ""))
                c.flops += in_elems
        elif op.startswith("all-") or op.startswith("reduce-scatter") \
                or op.startswith("collective-permute"):
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                c.collective_bytes += out_bytes
                c.collective_bytes_by_kind[base] += out_bytes
                c.collective_count_by_kind[base] += 1

        # HBM traffic: at fusion boundaries only (top level of a computation
        # that is not itself fused).  Count result + operand bytes for data
        # movers and math ops; skip control/metadata ops (their bodies are
        # walked separately) and slicing ops whose true traffic is the slice,
        # not the sliced-into buffer.
        if in_vmem_scope:
            # FLOPs/collectives counted above as usual; HBM traffic is only
            # the operand blocks the kernel DMAs in for its matmuls.
            if op == "dot":
                for a in _operand_names(ins.line):
                    if a in symbols:
                        _, ob = _shape_elems_bytes(symbols[a])
                        c.hbm_bytes += ob
            return c
        if not in_fusion and op not in (
                "parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "while",
                "conditional", "call", "optimization-barrier"):
            if op in ("dynamic-slice", "gather", "broadcast", "iota",
                      "slice"):
                c.hbm_bytes += 2 * out_bytes          # read slice + write
            elif op in ("dynamic-update-slice", "scatter"):
                # traffic ~= the update payload (result aliases the buffer)
                parts = _operand_names(ins.line)
                upd_bytes = out_bytes
                if len(parts) >= 2 and parts[1] in symbols:
                    _, upd_bytes = _shape_elems_bytes(symbols[parts[1]])
                c.hbm_bytes += 2 * upd_bytes
            else:
                operand_bytes = 0
                for a in _operand_names(ins.line):
                    if a in symbols:
                        _, ob = _shape_elems_bytes(symbols[a])
                        operand_bytes += ob
                c.hbm_bytes += out_bytes + operand_bytes
        return c

    # ---- recursive walk --------------------------------------------------
    def comp_cost(self, comp: str, in_fusion: bool = False,
                  _memo: Optional[Dict] = None,
                  vmem_scopes: Tuple[str, ...] = ()) -> HloCost:
        if _memo is None:
            _memo = {}
        key = (comp, in_fusion)
        if key in _memo:
            return _memo[key]
        total = HloCost()
        symbols = self._symbols(comp)
        for ins in self.computations.get(comp, []):
            total.add(self.instr_cost(ins, comp, in_fusion, symbols,
                                      vmem_scopes))
            if ins.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                # preferred: XLA's own known_trip_count backend config
                tc = _TRIP_COUNT.search(ins.line)
                trip: Optional[int] = int(tc.group(1)) if tc else None
                if trip is None and cond:
                    trip = self.trip_count(cond)
                if trip is None:
                    trip = 1
                    total.unknown_trip_loops += 1
                if body:
                    total.add(self.comp_cost(body, False, _memo,
                                             vmem_scopes), trip)
            elif ins.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    total.add(self.comp_cost(fm.group(1), True, _memo,
                                             vmem_scopes))
            elif ins.opcode in ("call", "conditional", "async-start"):
                for group in _CALLED.finditer(ins.line):
                    for name in group.group(1).split(","):
                        name = name.strip().lstrip("%")
                        if name in self.computations:
                            total.add(self.comp_cost(name, in_fusion, _memo,
                                                     vmem_scopes))
        _memo[key] = total
        return total


# named scopes whose HBM traffic a validated Pallas kernel eliminates
# (models mark these with jax.named_scope; kernels/ hold the kernels)
KERNEL_VMEM_SCOPES = ("attn_tile", "wkv_tile")


def arithmetic_intensity(cost: HloCost) -> float:
    """FLOP per HBM byte of an analyzed module (the roofline x-axis).

    Guards the zero-traffic case (e.g. a module whose entry is a single
    fused constant) so callers can compare AIs without special-casing."""
    return cost.flops / max(cost.hbm_bytes, 1.0)


def analyze_hlo(hlo_text: str,
                vmem_scopes: Tuple[str, ...] = ()) -> HloCost:
    mod = _Module(hlo_text)
    if mod.entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    cost = mod.comp_cost(mod.entry, vmem_scopes=vmem_scopes)
    cost.collective_bytes_by_kind = dict(cost.collective_bytes_by_kind)
    cost.collective_count_by_kind = dict(cost.collective_count_by_kind)
    return cost
