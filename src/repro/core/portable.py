"""PortableKernel — the paper's contribution as a composable JAX abstraction.

The Mojo paper's thesis: write a kernel ONCE against a portable, compile-time
specialized abstraction, lower it to multiple targets, and measure efficiency
against each target's "vendor" baseline.  Here:

  * a *kernel spec* is a named operation with a figure-of-merit model
    (FLOPs / moved bytes as a function of the input shapes — paper Eqs. 1-3);
  * *backends* are alternative implementations of the same spec:
      - ``xla``              pure-jnp oracle, what XLA autotunes (the "vendor"
                             baseline analogue of CUDA/HIP),
      - ``pallas``           the Pallas-TPU kernel (MLIR compile-time
                             specialized, the "Mojo" analogue),
      - ``pallas_interpret`` the same Pallas kernel body interpreted on CPU
                             (correctness validation path used by CI);
  * backends declare *availability* (pallas-TPU only runs on TPU) and are
    skipped — never crashed into — when unavailable
    (``BackendUnavailableError`` carries the reason);
  * backends declare a *tunable space* (block/tile sizes); the autotuner in
    ``repro.core.tuning`` sweeps it deterministically and persists the best
    point per (kernel, backend, shape, dtype, platform), so Eq.-4 efficiency
    is always measured at each backend's best configuration — untuned
    portable kernels understate the metric (Godoy et al., 2023);
  * the registry can *validate* any backend against the oracle and *time* all
    backends to feed the performance-portability metric (paper Eq. 4).

Framework layers (attention, RWKV, MoE dispatch, science kernels) register
here so deployments choose backends by name and CI sweeps them uniformly;
``benchmarks/portability.py`` walks this registry to produce the tuned Eq.-4
table.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import jax
import numpy as np

from repro.core import telemetry as tel

__all__ = [
    "Backend",
    "BackendUnavailableError",
    "TunableSpace",
    "PortableKernel",
    "KernelRegistry",
    "registry",
    "register_kernel",
    "get_kernel",
    "on_tpu",
]


class BackendUnavailableError(RuntimeError):
    """A backend exists in the registry but cannot run on this host."""


@dataclasses.dataclass(frozen=True)
class Backend:
    """One implementation of a kernel spec."""

    name: str
    fn: Callable[..., Any]
    # True when this backend is expected to run on the *current* process
    # (pallas-TPU kernels only run on TPU; interpret/xla run anywhere).
    available: Callable[[], bool] = lambda: True
    # why the last is_available() said False — conformance skips and the
    # static-auditor report surface it instead of a bare False
    unavailable_reason: Optional[str] = dataclasses.field(
        default=None, compare=False)

    def is_available(self) -> bool:
        try:
            ok = bool(self.available())
        except Exception as exc:
            object.__setattr__(
                self, "unavailable_reason",
                f"availability probe raised {type(exc).__name__}: {exc}")
            return False
        reason = None
        if not ok:
            pred = getattr(self.available, "__qualname__",
                           repr(self.available))
            reason = f"availability predicate {pred} returned False"
        object.__setattr__(self, "unavailable_reason", reason)
        return ok

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self.fn(*args, **kwargs)


@dataclasses.dataclass(frozen=True)
class TunableSpace:
    """Declared tunable parameters of one backend.

    ``params`` maps parameter name -> candidate values (declaration order is
    the deterministic sweep order).  ``constraint(point, *args, **kwargs)``
    filters points that are invalid for the concrete inputs (e.g. a block
    size that does not divide the array extent).
    """

    params: Mapping[str, Tuple[Any, ...]]
    constraint: Optional[Callable[..., bool]] = None

    def points(self) -> Iterator[Dict[str, Any]]:
        """Deterministic cartesian product over the declared grid."""
        names = list(self.params)
        for values in itertools.product(*(self.params[n] for n in names)):
            yield dict(zip(names, values))

    def valid_points(self, *args: Any, **kwargs: Any) -> List[Dict[str, Any]]:
        pts = []
        for p in self.points():
            if self.constraint is None or self.constraint(p, *args, **kwargs):
                pts.append(p)
        return pts


@dataclasses.dataclass
class PortableKernel:
    """A named kernel spec with multiple backends and a figure-of-merit model.

    ``flops_model`` / ``bytes_model`` take the same (abstract) arguments as
    the kernel and return the paper-defined operation/byte counts used for
    the GFLOP/s and effective-bandwidth figures of merit.
    """

    name: str
    backends: Dict[str, Backend] = dataclasses.field(default_factory=dict)
    oracle: str = "xla"
    flops_model: Optional[Callable[..., float]] = None
    bytes_model: Optional[Callable[..., float]] = None
    doc: str = ""
    tunables: Dict[str, TunableSpace] = dataclasses.field(default_factory=dict)
    #: dtype every reduction in this kernel must accumulate in (or wider);
    #: the static auditor flags psum/dot_general eqns reducing narrower
    accum_dtype: str = "float32"
    #: backend name -> declared communication contract (see
    #: ``declare_comm_contract``); audited against the traced jaxpr
    comm_contracts: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: backend name -> grid-coverage metadata (see ``declare_grid_contract``)
    grid_contracts: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    #: backend name -> static performance expectations (see
    #: ``declare_roofline_contract``); audited by ``analysis.cost``
    roofline_contracts: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    #: False for kernels whose backends are host-side driver loops (e.g. the
    #: serving engine) rather than pure jax functions — the static auditor
    #: and jaxpr-based passes skip them; conformance still runs them.
    jaxpr_traceable: bool = True

    # ---- registration -------------------------------------------------
    def add_backend(self, name: str, fn: Callable[..., Any],
                    available: Callable[[], bool] = lambda: True) -> None:
        self.backends[name] = Backend(name=name, fn=fn, available=available)

    def declare_tunables(
            self, backends: Union[str, Sequence[str]], *,
            constraint: Optional[Callable[..., bool]] = None,
            **params: Sequence[Any]) -> None:
        """Declare the tunable grid for one or more backends.

        ``declare_tunables(("pallas", "pallas_interpret"), by=(8, 16, 32))``
        registers the same space under both names (the interpret backend is
        the same kernel body, so it shares the space).
        """
        space = TunableSpace(
            params={k: tuple(v) for k, v in params.items()},
            constraint=constraint)
        names = [backends] if isinstance(backends, str) else list(backends)
        for n in names:
            self.tunables[n] = space

    def tunable_space(self, backend: str) -> Optional[TunableSpace]:
        return self.tunables.get(backend)

    def declare_comm_contract(self, backends: Union[str, Sequence[str]],
                              contract: Any) -> None:
        """Declare the collective traffic one (sharded) backend may emit.

        ``contract`` is either a dict
        ``{"ppermute": n, "psum": n, "all_gather": n}`` (one traced variant,
        default call parameters), or a callable ``contract(*case_args)``
        returning a list of ``(variant_kwargs, expectation_dict)`` pairs —
        the auditor traces the backend once per variant.  An expectation may
        also carry ``"overlap_shape": tuple``: the variant must contain an
        interior compute of that shape with no data dependency on any
        ``ppermute`` output (the halo/compute-overlap contract).  Backends
        with no declared contract are audited against *zero* collectives.
        """
        names = [backends] if isinstance(backends, str) else list(backends)
        for n in names:
            self.comm_contracts[n] = contract

    def comm_contract(self, backend: str) -> Any:
        return self.comm_contracts.get(backend)

    def declare_grid_contract(self, backends: Union[str, Sequence[str]], *,
                              accumulator_outputs: Sequence[int] = ()) -> None:
        """Declare Pallas grid-coverage metadata for one or more backends.

        ``accumulator_outputs`` lists output indices whose block is *meant*
        to be revisited across grid steps (sequential accumulators like the
        BabelStream dot partial or flash attention's online-softmax output).
        Any other revisited output block is a write race; unvisited blocks
        are holes — both are auditor findings.
        """
        names = [backends] if isinstance(backends, str) else list(backends)
        for n in names:
            self.grid_contracts[n] = {
                "accumulator_outputs": tuple(accumulator_outputs)}

    def grid_contract(self, backend: str) -> Dict[str, Any]:
        return self.grid_contracts.get(backend, {})

    def declare_roofline_contract(
            self, backends: Union[str, Sequence[str]], *,
            bound: Optional[str] = None,
            traffic_inflation_limit: Optional[float] = None) -> None:
        """Pin the static performance auditor's expectations for a backend.

        ``bound`` is the expected roofline verdict at the conformance-case
        shape ("memory" | "compute" | "collective") — declare it only where
        the verdict is platform-robust; the auditor flags a flip as a
        regression.  ``traffic_inflation_limit`` overrides the default
        modeled-traffic-over-compulsory-bytes limit for kernels whose halo
        re-reads or accumulator revisits are by design.
        """
        if bound is not None and bound not in ("memory", "compute",
                                               "collective"):
            raise ValueError(f"unknown roofline bound {bound!r}")
        contract: Dict[str, Any] = {}
        if bound is not None:
            contract["bound"] = bound
        if traffic_inflation_limit is not None:
            contract["traffic_inflation_limit"] = \
                float(traffic_inflation_limit)
        names = [backends] if isinstance(backends, str) else list(backends)
        for n in names:
            self.roofline_contracts[n] = contract

    def roofline_contract(self, backend: str) -> Dict[str, Any]:
        return self.roofline_contracts.get(backend, {})

    def backend(self, name: Optional[str] = None) -> Backend:
        if name is None:
            name = self.default_backend()
        if name not in self.backends:
            raise KeyError(
                f"kernel {self.name!r} has no backend {name!r}; "
                f"have {sorted(self.backends)}")
        return self.backends[name]

    def available_backends(self) -> List[str]:
        return [n for n in sorted(self.backends)
                if self.backends[n].is_available()]

    def default_backend(self) -> str:
        """Pallas on TPU, oracle elsewhere — the paper's portability story.

        Honors ``Backend.available``: an unavailable pallas backend falls
        back to the oracle, an unavailable oracle falls back to any
        available backend, and only when *nothing* can run do we raise
        ``BackendUnavailableError`` (never a crash inside the backend).
        """
        pallas = self.backends.get("pallas")
        if pallas is not None and _on_tpu() and pallas.is_available():
            return "pallas"
        oracle = self.backends.get(self.oracle)
        if oracle is None:
            # spec-only kernel (no backends registered yet): keep returning
            # the declared oracle name so callers get the usual KeyError.
            return self.oracle
        if oracle.is_available():
            return self.oracle
        for n in self.available_backends():
            return n
        raise BackendUnavailableError(
            f"kernel {self.name!r}: no backend available on this host "
            f"(registered: {sorted(self.backends)})")

    def _require_available(self, name: str) -> Backend:
        b = self.backend(name)
        if not b.is_available():
            raise BackendUnavailableError(
                f"kernel {self.name!r} backend {name!r} is not available on "
                f"this host: {b.unavailable_reason} "
                f"(available: {self.available_backends()})")
        return b

    def __call__(self, *args: Any, backend: Optional[str] = None,
                 tuned: bool = False, tuning_cache: Any = None,
                 **kwargs: Any) -> Any:
        """Run the kernel.

        With ``tuned=True`` the persistent tuning cache (see
        ``repro.core.tuning``) is consulted for the best block/tile sizes
        recorded for this (kernel, backend, shape, dtype, platform); cached
        parameters are merged *under* explicit kwargs, and a cache miss
        silently runs the declared defaults.
        """
        name = backend if backend is not None else self.default_backend()
        if tuned:
            from repro.core import tuning as _tuning
            best = _tuning.cached_best_params(
                self, *args, backend=name, cache=tuning_cache, **kwargs)
            kwargs = {**best, **kwargs}
        return self.backend(name)(*args, **kwargs)

    # ---- validation ----------------------------------------------------
    def validate(self, *args: Any, backend: str,
                 rtol: Optional[float] = None, atol: Optional[float] = None,
                 **kwargs: Any) -> None:
        """assert_allclose the given backend against the oracle.

        Default tolerances come from the conformance tables
        (``repro.core.conformance.oracle_tolerance``), so ad-hoc validation
        and the conformance matrix cannot disagree: a ``"bitwise"`` cell
        validates at rtol=atol=0, an unregistered kernel falls back to
        (1e-5, 1e-5).  Explicit ``rtol``/``atol`` override per call.

        Raises ``BackendUnavailableError`` (not an opaque crash from inside
        the kernel) when either side cannot run here.
        """
        if rtol is None or atol is None:
            from repro.core import conformance
            tol = conformance.oracle_tolerance(self.name, backend)
            d_rtol, d_atol = ((0.0, 0.0) if tol == "bitwise"
                              else tol if tol is not None else (1e-5, 1e-5))
            rtol = d_rtol if rtol is None else rtol
            atol = d_atol if atol is None else atol
        want = self._require_available(self.oracle)(*args, **kwargs)
        got = self._require_available(backend)(*args, **kwargs)
        jax.tree.map(
            lambda w, g: np.testing.assert_allclose(
                np.asarray(g, dtype=np.float64),
                np.asarray(w, dtype=np.float64), rtol=rtol, atol=atol),
            want, got)

    # ---- measurement ---------------------------------------------------
    def time_backend(self, *args: Any, backend: str, iters: int = 10,
                     warmup: int = 2, **kwargs: Any) -> float:
        """Median wall-clock seconds per call (post-warmup, paper §3).

        The paper discards the first (JIT) step and reports medians over many
        runs; we do the same.  ``warmup=0`` is allowed (the timed loop then
        includes compilation in its first sample — the median still drops it
        for ``iters >= 3``).

        Each call emits one ``registry.time_backend`` telemetry span tagged
        with (kernel, backend, params) — the per-measurement provenance the
        Eq.-4 table is built from — with per-iteration ``registry.measure``
        child spans inside it, plus one ``registry.time_backend.result``
        instant carrying the shape signature and median seconds (the join
        key the static auditor's drift gate re-traces predictions from).
        All events fire at the driver level, outside the measured regions'
        compiled code, and timing uses the same ``perf_counter`` reads as
        before: telemetry off is bitwise the status quo.
        """
        fn = self._require_available(backend)
        params = {k: v for k, v in kwargs.items()
                  if isinstance(v, (bool, int, float, str, tuple))}
        with tel.span("registry.time_backend", proc="registry",
                      kernel=self.name, backend=backend, iters=iters,
                      warmup=warmup, params=params):
            out = None
            for _ in range(warmup):
                out = fn(*args, **kwargs)
            jax.block_until_ready(out)
            times = []
            for _ in range(iters):
                with tel.span("registry.measure", proc="registry",
                              kernel=self.name, backend=backend):
                    t0 = time.perf_counter()
                    out = fn(*args, **kwargs)
                    jax.block_until_ready(out)
                    times.append(time.perf_counter() - t0)
        tel.counter("registry.time_backend.calls", proc="registry")
        median_s = float(np.median(times))
        if tel.enabled():
            import json as _json

            from repro.core import tuning as _tuning
            base = {k: v for k, v in kwargs.items() if k not in params}
            tel.instant(
                "registry.time_backend.result", proc="registry",
                kernel=self.name, backend=backend,
                shape=_tuning.shape_signature(*args, **base),
                params_json=_json.dumps(params, sort_keys=True, default=repr),
                seconds=median_s, iters=iters,
                devices=jax.device_count(),
                platform=jax.devices()[0].platform)
        return median_s

    def figure_of_merit(self, elapsed_s: float, *args: Any,
                        **kwargs: Any) -> Dict[str, float]:
        """GFLOP/s and GB/s from the paper's operation/byte models."""
        out: Dict[str, float] = {"seconds": elapsed_s}
        if self.flops_model is not None:
            out["gflops_per_s"] = self.flops_model(*args, **kwargs) / elapsed_s / 1e9
        if self.bytes_model is not None:
            out["gbytes_per_s"] = self.bytes_model(*args, **kwargs) / elapsed_s / 1e9
        return out


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return False


#: availability predicate for compiled pallas-TPU backends (public so
#: kernel ops modules can pass ``available=on_tpu`` at registration).
on_tpu = _on_tpu


class KernelRegistry:
    """Global name → PortableKernel map (the framework's kernel catalogue)."""

    def __init__(self) -> None:
        self._kernels: Dict[str, PortableKernel] = {}

    def register(self, kernel: PortableKernel) -> PortableKernel:
        if kernel.name in self._kernels:
            raise ValueError(f"duplicate kernel {kernel.name!r}")
        self._kernels[kernel.name] = kernel
        return kernel

    def get(self, name: str) -> PortableKernel:
        try:
            return self._kernels[name]
        except KeyError:
            raise KeyError(
                f"no kernel {name!r} registered; "
                f"registered kernels: {self.names()}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._kernels

    def names(self) -> Sequence[str]:
        return sorted(self._kernels)


registry = KernelRegistry()


def register_kernel(name: str, *, oracle: str = "xla",
                    flops_model: Optional[Callable[..., float]] = None,
                    bytes_model: Optional[Callable[..., float]] = None,
                    doc: str = "",
                    jaxpr_traceable: bool = True) -> PortableKernel:
    """Create-or-get a PortableKernel in the global registry."""
    if name in registry:
        return registry.get(name)
    return registry.register(PortableKernel(
        name=name, oracle=oracle, flops_model=flops_model,
        bytes_model=bytes_model, doc=doc, jaxpr_traceable=jaxpr_traceable))


def get_kernel(name: str) -> PortableKernel:
    return registry.get(name)
