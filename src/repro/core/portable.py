"""PortableKernel — the paper's contribution as a composable JAX abstraction.

The Mojo paper's thesis: write a kernel ONCE against a portable, compile-time
specialized abstraction, lower it to multiple targets, and measure efficiency
against each target's "vendor" baseline.  Here:

  * a *kernel spec* is a named operation with a figure-of-merit model
    (FLOPs / moved bytes as a function of the input shapes — paper Eqs. 1-3);
  * *backends* are alternative implementations of the same spec:
      - ``xla``              pure-jnp oracle, what XLA autotunes (the "vendor"
                             baseline analogue of CUDA/HIP),
      - ``pallas``           the Pallas-TPU kernel (MLIR compile-time
                             specialized, the "Mojo" analogue),
      - ``pallas_interpret`` the same Pallas kernel body interpreted on CPU
                             (correctness validation path used by CI);
  * the registry can *validate* any backend against the oracle and *time* all
    backends to feed the performance-portability metric (paper Eq. 4).

Framework layers (attention, RWKV, MoE dispatch, science kernels) register
here so deployments choose backends by name and CI sweeps them uniformly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = [
    "Backend",
    "PortableKernel",
    "KernelRegistry",
    "registry",
    "register_kernel",
    "get_kernel",
]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One implementation of a kernel spec."""

    name: str
    fn: Callable[..., Any]
    # True when this backend is expected to run on the *current* process
    # (pallas-TPU kernels only run on TPU; interpret/xla run anywhere).
    available: Callable[[], bool] = lambda: True

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self.fn(*args, **kwargs)


@dataclasses.dataclass
class PortableKernel:
    """A named kernel spec with multiple backends and a figure-of-merit model.

    ``flops_model`` / ``bytes_model`` take the same (abstract) arguments as
    the kernel and return the paper-defined operation/byte counts used for
    the GFLOP/s and effective-bandwidth figures of merit.
    """

    name: str
    backends: Dict[str, Backend] = dataclasses.field(default_factory=dict)
    oracle: str = "xla"
    flops_model: Optional[Callable[..., float]] = None
    bytes_model: Optional[Callable[..., float]] = None
    doc: str = ""

    # ---- registration -------------------------------------------------
    def add_backend(self, name: str, fn: Callable[..., Any],
                    available: Callable[[], bool] = lambda: True) -> None:
        self.backends[name] = Backend(name=name, fn=fn, available=available)

    def backend(self, name: Optional[str] = None) -> Backend:
        if name is None:
            name = self.default_backend()
        if name not in self.backends:
            raise KeyError(
                f"kernel {self.name!r} has no backend {name!r}; "
                f"have {sorted(self.backends)}")
        return self.backends[name]

    def default_backend(self) -> str:
        """Pallas on TPU, oracle elsewhere — the paper's portability story."""
        if "pallas" in self.backends and _on_tpu():
            return "pallas"
        return self.oracle

    def __call__(self, *args: Any, backend: Optional[str] = None,
                 **kwargs: Any) -> Any:
        return self.backend(backend)(*args, **kwargs)

    # ---- validation ----------------------------------------------------
    def validate(self, *args: Any, backend: str, rtol: float = 1e-5,
                 atol: float = 1e-5, **kwargs: Any) -> None:
        """assert_allclose the given backend against the oracle."""
        want = self.backend(self.oracle)(*args, **kwargs)
        got = self.backend(backend)(*args, **kwargs)
        jax.tree.map(
            lambda w, g: np.testing.assert_allclose(
                np.asarray(g, dtype=np.float64),
                np.asarray(w, dtype=np.float64), rtol=rtol, atol=atol),
            want, got)

    # ---- measurement ---------------------------------------------------
    def time_backend(self, *args: Any, backend: str, iters: int = 10,
                     warmup: int = 2, **kwargs: Any) -> float:
        """Median wall-clock seconds per call (post-warmup, paper §3).

        The paper discards the first (JIT) step and reports medians over many
        runs; we do the same.
        """
        fn = self.backend(backend)
        for _ in range(warmup):
            out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    def figure_of_merit(self, elapsed_s: float, *args: Any,
                        **kwargs: Any) -> Dict[str, float]:
        """GFLOP/s and GB/s from the paper's operation/byte models."""
        out: Dict[str, float] = {"seconds": elapsed_s}
        if self.flops_model is not None:
            out["gflops_per_s"] = self.flops_model(*args, **kwargs) / elapsed_s / 1e9
        if self.bytes_model is not None:
            out["gbytes_per_s"] = self.bytes_model(*args, **kwargs) / elapsed_s / 1e9
        return out


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return False


class KernelRegistry:
    """Global name → PortableKernel map (the framework's kernel catalogue)."""

    def __init__(self) -> None:
        self._kernels: Dict[str, PortableKernel] = {}

    def register(self, kernel: PortableKernel) -> PortableKernel:
        if kernel.name in self._kernels:
            raise ValueError(f"duplicate kernel {kernel.name!r}")
        self._kernels[kernel.name] = kernel
        return kernel

    def get(self, name: str) -> PortableKernel:
        return self._kernels[name]

    def __contains__(self, name: str) -> bool:
        return name in self._kernels

    def names(self) -> Sequence[str]:
        return sorted(self._kernels)


registry = KernelRegistry()


def register_kernel(name: str, *, oracle: str = "xla",
                    flops_model: Optional[Callable[..., float]] = None,
                    bytes_model: Optional[Callable[..., float]] = None,
                    doc: str = "") -> PortableKernel:
    """Create-or-get a PortableKernel in the global registry."""
    if name in registry:
        return registry.get(name)
    return registry.register(PortableKernel(
        name=name, oracle=oracle, flops_model=flops_model,
        bytes_model=bytes_model, doc=doc))


def get_kernel(name: str) -> PortableKernel:
    return registry.get(name)
