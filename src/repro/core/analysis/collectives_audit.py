"""Pass 3 — collective-traffic audit against declared contracts.

Every backend either declares what it may ppermute/psum/all_gather
(``PortableKernel.declare_comm_contract``) or is held to zero collectives.
The declared contract is normalized to a list of *variants*: call-kwarg
overrides plus the expected census, so one backend can be audited under
several decompositions (slab vs pencil, overlap on/off) from one
declaration.  An expectation may carry:

  * ``"overlap_shape"``: the local interior shape that must be computable
    without any ``ppermute``-derived operand — the static witness that the
    halo exchange is issued *before* (and independently of) the interior
    compute, i.e. overlappable by the scheduler;
  * ``"all_gather": 0`` is implied when absent — an undeclared all_gather
    is always a finding (it re-materializes the whole array and silently
    defeats the decomposition).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

from repro.core.analysis import jaxpr_utils as JU
from repro.core.analysis.report import Finding

Variant = Tuple[Dict[str, Any], Dict[str, Any]]


def normalize_contract(contract: Any, args: tuple) -> List[Variant]:
    """dict -> one default-call variant; callable -> its variant list.
    The no-contract expectation deliberately omits the ``all_gather`` key
    so a traced all_gather reports as ``undeclared-all-gather`` (its own
    code) rather than a generic count mismatch."""
    if contract is None:
        return [({}, {"ppermute": 0, "psum": 0})]
    if callable(contract):
        return [(dict(kw), dict(exp)) for kw, exp in contract(*args)]
    return [({}, dict(contract))]


def check_counts(kernel: str, backend: str, closed: Any,
                 expected: Dict[str, Any],
                 declared: bool, variant: str = "") -> List[Finding]:
    """Compare the traced collective census to one variant's expectation."""
    findings: List[Finding] = []
    counts = JU.count_collectives(closed.jaxpr)
    tag = f" [{variant}]" if variant else ""
    for kind in JU.COLLECTIVE_KINDS:
        want = int(expected.get(kind, 0))
        got = counts[kind]
        if got == want:
            continue
        undeclared_gather = kind == "all_gather" and kind not in expected
        code = ("undeclared-all-gather" if undeclared_gather
                else "undeclared-collective" if not declared
                else "comm-contract-mismatch")
        findings.append(Finding(
            kernel=kernel, backend=backend, pass_name="collectives",
            code=code,
            message=(f"{kind} count{tag}: traced {got}, contract says "
                     f"{want}"
                     + ("" if declared else
                        " (backend declares no communication contract)")),
            detail={"kind": kind, "traced": got, "declared": want,
                    "variant": variant}))

    shape = expected.get("overlap_shape")
    if shape is not None:
        ok = any(JU.independent_compute_exists(body, tuple(shape))
                 for body in JU.find_shard_map_bodies(closed.jaxpr))
        if not ok:
            findings.append(Finding(
                kernel=kernel, backend=backend, pass_name="collectives",
                code="overlap-not-independent",
                message=(f"overlap contract{tag}: no interior compute of "
                         f"shape {tuple(shape)} is independent of the "
                         f"ppermute halo traffic — halo exchange and "
                         f"compute cannot overlap"),
                detail={"shape": list(shape), "variant": variant}))
    return findings
