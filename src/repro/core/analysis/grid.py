"""Pass 2 — Pallas grid/BlockSpec coverage audit.

For every ``pallas_call`` in the traced backend (including calls nested in
``shard_map`` bodies), evaluate each *output* BlockSpec index map over the
whole (static) grid and prove, without running the kernel:

  * **no holes** — every block index of the output array is visited by at
    least one grid step (an unvisited block is uninitialized memory);
  * **no write races** — a block index visited by more than one grid step
    is only legal for outputs declared as sequential accumulators
    (``PortableKernel.declare_grid_contract(accumulator_outputs=...)``):
    the BabelStream dot partial and the online-softmax attention outputs
    revisit by design, everything else is the static analogue of the
    paper's atomic-update pitfalls;
  * **in-bounds tiles** — no index map may address a block outside the
    ceil(extent / block) index space (Blocked indexing clips the last
    tile, so the boundary tile itself is legal; an index *beyond* it is
    not).

The registry's declared ``TunableSpace.constraint`` is cross-checked by
the full audit: every constraint-valid tunable point is re-traced and must
still satisfy the three proofs (``repro.core.analysis.audit_cell`` drives
that sweep).
"""

from __future__ import annotations

import itertools
from typing import Any, List, Sequence, Tuple

from repro.core.analysis import jaxpr_utils as JU
from repro.core.analysis.report import Finding

#: refuse to enumerate absurd grids (no registry kernel is near this)
MAX_GRID_POINTS = 262144


def audit_grid_mapping(kernel: str, backend: str, gm: Any,
                       accumulator_outputs: Sequence[int],
                       variant: str = "") -> List[Finding]:
    """Audit one pallas_call's output coverage.  Pure index-map math."""
    findings: List[Finding] = []
    grid = tuple(int(g) for g in gm.grid)
    npoints = 1
    for g in grid:
        npoints *= g
    tag = f" [{variant}]" if variant else ""
    if npoints > MAX_GRID_POINTS:
        findings.append(Finding(
            kernel=kernel, backend=backend, pass_name="grid",
            code="grid-too-large", severity="warning",
            message=(f"grid {grid}{tag} has {npoints} points — coverage "
                     f"not enumerated (cap {MAX_GRID_POINTS})"),
            detail={"grid": list(grid)}))
        return findings

    for out_idx, bm in JU.output_block_mappings(gm):
        mode = type(getattr(bm, "indexing_mode", None)).__name__
        if mode not in ("Blocked", "NoneType"):
            findings.append(Finding(
                kernel=kernel, backend=backend, pass_name="grid",
                code="unaudited-indexing-mode", severity="warning",
                message=(f"output {out_idx}{tag} uses indexing mode "
                         f"{mode}; coverage proof only models Blocked"),
                detail={"output": out_idx, "mode": mode}))
            continue
        block = tuple(int(b) for b in bm.block_shape)
        arr_shape = tuple(int(s) for s in bm.array_shape_dtype.shape)
        nblocks = tuple(-(-s // b) for s, b in zip(arr_shape, block))

        visits: dict = {}
        for idx in JU.grid_points(grid):
            bi = JU.eval_index_map(bm.index_map_jaxpr, idx)
            visits[bi] = visits.get(bi, 0) + 1

        oob = sorted(bi for bi in visits
                     if any(i < 0 or i >= n for i, n in zip(bi, nblocks)))
        if oob:
            findings.append(Finding(
                kernel=kernel, backend=backend, pass_name="grid",
                code="out-of-bounds-tile",
                message=(f"output {out_idx}{tag}: index map addresses "
                         f"block(s) {oob[:4]} outside the "
                         f"{nblocks} block space"),
                detail={"output": out_idx, "oob": [list(b) for b in oob],
                        "nblocks": list(nblocks)}))

        holes = sorted(bi for bi in
                       itertools.product(*(range(n) for n in nblocks))
                       if bi not in visits)
        if holes:
            findings.append(Finding(
                kernel=kernel, backend=backend, pass_name="grid",
                code="coverage-hole",
                message=(f"output {out_idx}{tag}: block(s) {holes[:4]} of "
                         f"{nblocks} never written — uninitialized output"),
                detail={"output": out_idx,
                        "holes": [list(h) for h in holes[:16]],
                        "nblocks": list(nblocks)}))

        revisited = sorted(bi for bi, c in visits.items()
                           if c > 1 and bi not in set(map(tuple, oob)))
        if revisited and out_idx not in tuple(accumulator_outputs):
            findings.append(Finding(
                kernel=kernel, backend=backend, pass_name="grid",
                code="write-race",
                message=(f"output {out_idx}{tag}: block(s) "
                         f"{revisited[:4]} written by multiple grid steps "
                         f"but output {out_idx} is not a declared "
                         f"accumulator (declare_grid_contract)"),
                detail={"output": out_idx,
                        "revisited": [list(r) for r in revisited[:16]]}))
    return findings


def run(kernel: str, backend: str, closed: Any,
        accumulator_outputs: Sequence[int],
        variant: str = "") -> Tuple[List[Finding], int]:
    """Audit every pallas_call in a traced cell.  Returns (findings,
    number of pallas_calls audited) — zero calls means the pass was
    vacuous for this backend (pure-XLA), which the caller records."""
    findings: List[Finding] = []
    gms = JU.find_pallas_grid_mappings(closed.jaxpr)
    for gm in gms:
        findings.extend(audit_grid_mapping(
            kernel, backend, gm, accumulator_outputs, variant))
    return findings, len(gms)
