"""Pass 1 — dtype/promotion lint.

Two checks, both on traces of the conformance-case inputs:

  * **float64 promotion**: the kernel is re-traced under
    ``jax.experimental.enable_x64()`` with its (float32) case inputs.  Any
    eqn producing a float64/complex128 value with no float64 input operand
    is a latent promotion — under the default x64-disabled config jax
    silently clamps it back to f32, but the same source run with x64
    enabled (or ported to a backend without the clamp) doubles its memory
    traffic and splits from the oracle.  The classic trigger is
    ``jnp.where(mask, py_float, py_float)``: with no array operand to
    anchor the dtype, both weak scalars materialize as f64.  Integer
    widening (i32→i64 index math) is deliberately NOT flagged — it is the
    documented x64 behaviour for index arithmetic and harmless.

  * **accumulation downgrade**: on the normal trace, every ``psum`` /
    ``dot_general`` must produce at least the kernel's declared
    ``accum_dtype`` (default float32) when its inputs are floating — a
    reduction carried in bf16/f16 silently loses the oracle's precision.
"""

from __future__ import annotations

from typing import Any, List

import jax
import numpy as np

from jax.core import Literal

from repro.core.analysis import jaxpr_utils as JU
from repro.core.analysis.report import Finding

#: reduction primitives audited against the declared accumulation dtype
_ACCUM_PRIMITIVES = JU.PSUM_PRIMITIVES + ("dot_general",)

_WIDE = (np.float64, np.complex128)


def _dtype_of(var: Any):
    return getattr(getattr(var, "aval", None), "dtype", None)


def run_f64_lint(kernel: str, backend: str, fn, args: tuple,
                 kwargs: dict) -> List[Finding]:
    """Re-trace under x64 and flag float64 eqns with no float64 operand."""
    from jax.experimental import enable_x64
    with enable_x64():
        closed = JU.trace(fn, args, kwargs)
    findings = []
    seen = set()
    for eqn in JU.iter_eqns(closed.jaxpr):
        wide_out = [v for v in eqn.outvars
                    if _dtype_of(v) is not None and _dtype_of(v) in _WIDE]
        if not wide_out:
            continue
        # a wide *traced* operand means the promotion happened upstream —
        # flag it once, there.  A wide Literal is the opposite: it IS the
        # unanchored weak scalar, so it must not anchor the eqn.
        if any(_dtype_of(v) in _WIDE for v in eqn.invars
               if not isinstance(v, Literal)):
            continue
        key = (eqn.primitive.name, tuple(str(_dtype_of(v))
                                         for v in wide_out))
        if key in seen:
            continue
        seen.add(key)
        findings.append(Finding(
            kernel=kernel, backend=backend, pass_name="dtypes",
            code="f64-promotion",
            message=(f"{eqn.primitive.name} produces "
                     f"{_dtype_of(wide_out[0])} from non-wide inputs under "
                     f"x64 — a weak Python scalar (e.g. a scalar-scalar "
                     f"jnp.where) is unanchored to the working dtype"),
            detail={"primitive": eqn.primitive.name,
                    "dtype": str(_dtype_of(wide_out[0]))}))
    return findings


def run_accum_check(kernel: str, backend: str, closed,
                    accum_dtype: str) -> List[Finding]:
    """Flag psum/dot_general eqns reducing narrower than declared."""
    declared = np.dtype(accum_dtype)
    findings = []
    seen = set()
    for eqn in JU.iter_eqns(closed.jaxpr):
        if eqn.primitive.name not in _ACCUM_PRIMITIVES:
            continue
        for v in eqn.outvars:
            dt = _dtype_of(v)
            if dt is None or not jax.numpy.issubdtype(dt, np.floating):
                continue
            if np.dtype(dt).itemsize < declared.itemsize:
                key = (eqn.primitive.name, str(dt))
                if key in seen:
                    continue
                seen.add(key)
                findings.append(Finding(
                    kernel=kernel, backend=backend, pass_name="dtypes",
                    code="accum-downgrade",
                    message=(f"{eqn.primitive.name} accumulates in {dt} "
                             f"but the kernel declares accum_dtype="
                             f"{accum_dtype}"),
                    detail={"primitive": eqn.primitive.name,
                            "dtype": str(dt),
                            "declared": accum_dtype}))
    return findings
