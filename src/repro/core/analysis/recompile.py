"""Pass 4 — AST-level recompilation-hazard detector.

The PR-4 bug class: an ``lru_cache``-wrapped builder that closes a jitted /
shard_mapped / pallas program over its Python arguments turns every
distinct *value* of those arguments into a separate compiled program.
That is correct (and intended) for shapes, tile sizes and other genuinely
static configuration — and a silent compile storm for runtime scalars that
should have been traced operands.

The detector is purely syntactic (no imports are executed for the scanned
module beyond reading its source):

  * a **builder** is an ``lru_cache``-decorated function whose body calls
    ``jit`` / ``shard_map`` / ``pallas_call`` / ``pmap``;
  * a **hazard** is a builder call site passing ``float(...)``, a float
    literal, or a bare name bound to an enclosing function parameter with
    a float default — the syntactic signature of a runtime scalar entering
    the cache key;
  * a builder may **waive** its scalar keys with a structured comment
    anywhere in its body or decorators::

        # audit: compile-time-constant(scalar) — Mojo-alias analogue,
        # one program per value is the declared contract

    Waived hazards stay in the report (as ``waived``) so the contract is
    visible, not silent.
"""

from __future__ import annotations

import ast
import functools
import re
from typing import Any, Dict, List, Optional

#: callables whose presence makes an lru_cache'd function trace-producing
TRACE_PRODUCERS = ("jit", "shard_map", "pallas_call", "pmap")

_WAIVER_RE = re.compile(
    r"audit:\s*compile-time-constant\s*(?:\(([^)]*)\))?[^\n]*")


def _call_name(node: ast.AST) -> Optional[str]:
    """Last dotted segment of a call target: jax.jit -> 'jit'."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_lru_cache(dec: ast.AST) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    return _call_name(target) in ("lru_cache", "cache")


def _find_builders(tree: ast.Module) -> List[ast.FunctionDef]:
    builders = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not any(_is_lru_cache(d) for d in node.decorator_list):
            continue
        calls = {_call_name(c.func) for c in ast.walk(node)
                 if isinstance(c, ast.Call)}
        if calls & set(TRACE_PRODUCERS):
            builders.append(node)
    return builders


def _builder_waiver(node: ast.FunctionDef, lines: List[str]) -> Optional[str]:
    start = min([node.lineno]
                + [d.lineno for d in node.decorator_list]) - 1
    end = getattr(node, "end_lineno", node.lineno)
    m = _WAIVER_RE.search("\n".join(lines[start:end]))
    return m.group(0).strip() if m else None


def _float_defaults(fn: ast.FunctionDef) -> Dict[str, float]:
    """Parameter name -> default, for params with float-literal defaults."""
    out: Dict[str, float] = {}
    a = fn.args
    pos = a.posonlyargs + a.args
    for name, default in zip([p.arg for p in pos[len(pos) - len(a.defaults):]],
                             a.defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value,
                                                            float):
            out[name] = default.value
    for p, default in zip(a.kwonlyargs, a.kw_defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value,
                                                            float):
            out[p.arg] = default.value
    return out


def _hazardous_arg(arg: ast.AST,
                   enclosing_float_params: Dict[str, float]) -> Optional[str]:
    if isinstance(arg, ast.Call) and _call_name(arg.func) == "float":
        return f"float({ast.unparse(arg.args[0]) if arg.args else ''})"
    if isinstance(arg, ast.Constant) and isinstance(arg.value, float):
        return f"float literal {arg.value}"
    if isinstance(arg, ast.Name) and arg.id in enclosing_float_params:
        return (f"parameter {arg.id!r} (float default "
                f"{enclosing_float_params[arg.id]})")
    return None


def scan_source(src: str, where: str = "<string>") -> List[Dict[str, Any]]:
    """Scan one module's source.  Returns raw hazard dicts: the caller
    wraps them into Findings with its own kernel/backend attribution."""
    tree = ast.parse(src, filename=where)
    lines = src.splitlines()
    builders = {b.name: b for b in _find_builders(tree)}
    if not builders:
        return []
    hazards: List[Dict[str, Any]] = []
    seen = set()

    class Visitor(ast.NodeVisitor):
        def __init__(self) -> None:
            self.stack: List[ast.FunctionDef] = []

        def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node: ast.Call) -> None:
            name = _call_name(node.func)
            builder = builders.get(name)
            if builder is not None and (name, node.lineno) not in seen:
                floats = {}
                for fn in self.stack:
                    floats.update(_float_defaults(fn))
                reasons = []
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    why = _hazardous_arg(arg, floats)
                    if why is not None:
                        reasons.append(why)
                if reasons:
                    seen.add((name, node.lineno))
                    waiver = _builder_waiver(builder, lines)
                    hazards.append({
                        "builder": name,
                        "module": where,
                        "line": node.lineno,
                        "scalars": reasons,
                        "waiver": waiver,
                    })
            self.generic_visit(node)

    Visitor().visit(tree)
    return hazards


@functools.lru_cache(maxsize=None)
def scan_module(module_name: str) -> tuple:
    """Scan an importable module by name (cached — pass 4 is per-module,
    many registry cells share a module).  Unreadable sources scan empty."""
    import importlib
    import inspect
    try:
        mod = importlib.import_module(module_name)
        src = inspect.getsource(mod)
    except (ImportError, OSError, TypeError):
        return ()
    return tuple(
        tuple(sorted(h.items(), key=lambda kv: kv[0]))
        for h in scan_source(src, module_name))


def module_of(fn: Any) -> Optional[str]:
    """Defining module of a backend fn, unwrapping functools.partial."""
    while isinstance(fn, functools.partial):
        fn = fn.func
    return getattr(fn, "__module__", None)
