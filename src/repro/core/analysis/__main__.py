"""CLI: ``python -m repro.core.analysis`` — audit the whole registry.

Walks the derived (kernel, backend) matrix, runs the seven static passes
(four correctness + the PR-9 traffic/roofline/drift performance passes),
writes a ``repro.analysis/v2`` JSON report, and exits nonzero iff any
non-waived finding survives.  The sharded backends only *trace* on a
multi-device topology, so when the parent process is pinned to one device
the CLI re-execs itself under ``--xla_force_host_platform_device_count=8``
(appended to — never clobbering — the user's XLA_FLAGS, exactly like
``benchmarks/scaling.py``).  ``--smoke`` skips the re-exec and the
per-tunable-point sweep: the seconds-scale drift-lane subset.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ARTIFACT = "ANALYSIS_report.json"
DEFAULT_DEVICES = 8
_CHILD_ENV = "REPRO_ANALYSIS_CHILD"


def _print_summary(report) -> None:
    s = report["summary"]
    print(f"static analysis: {s['cells']} cells, {s['audited']} audited, "
          f"{s['findings']} finding(s), {s['waived']} waived, "
          f"{s['skips']} skip(s) "
          f"[device_count={report['device_count']}"
          f"{', smoke' if report['smoke'] else ''}]")
    drift = report.get("drift", {})
    if drift:
        cal = drift.get("calibration")
        cal_s = f"{cal:.1f}x" if cal is not None else "n/a"
        print(f"  perf model: chip={report.get('chip')}, "
              f"{len(report.get('cost', {}))} cells costed, drift joins "
              f"{drift.get('joined', 0)}/{drift.get('measurements', 0)} "
              f"(calibration {cal_s}, band {drift.get('band')}x)")
    for f in report["findings"]:
        print(f"  FINDING {f['kernel']}[{f['backend']}] {f['pass_name']}/"
              f"{f['code']}: {f['message']}")
    for f in report["waived"]:
        print(f"  waived  {f['kernel']}[{f['backend']}] {f['pass_name']}/"
              f"{f['code']}: {f['waive_reason']}")
    for s_ in report["skips"]:
        print(f"  skip    {s_['kernel']}[{s_['backend']}] "
              f"{s_['pass_name']}: {s_['reason']}")


def _audit_here(args) -> int:
    from repro.core import analysis
    report = analysis.audit_registry(smoke=args.smoke,
                                     tuning_cache=args.tuning_cache,
                                     telemetry_trace=args.telemetry,
                                     drift_band=args.drift_band)
    analysis.write_report(report, args.json)
    _print_summary(report)
    return 1 if report["summary"]["findings"] else 0


def _reexec(args, devices: int) -> int:
    from repro.launch.hostsim import merged_xla_flags
    env = dict(os.environ)
    env["XLA_FLAGS"] = merged_xla_flags(devices, env)
    env[_CHILD_ENV] = "1"
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.core.analysis",
           "--json", os.path.abspath(args.json), "--devices", str(devices)]
    if args.smoke:
        cmd.append("--smoke")
    if args.tuning_cache:
        cmd += ["--tuning-cache", os.path.abspath(args.tuning_cache)]
    if args.telemetry:
        cmd += ["--telemetry", os.path.abspath(args.telemetry)]
    if args.drift_band is not None:
        cmd += ["--drift-band", str(args.drift_band)]
    return subprocess.call(cmd, env=env)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.analysis",
        description="static kernel auditor over the live registry")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-kernel subset, default params only, no "
                         "multi-device re-exec (PR-time drift check)")
    ap.add_argument("--json", default=ARTIFACT,
                    help=f"report path (default {ARTIFACT})")
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES,
                    help="forced host-device count for the sharded cells")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning cache JSON joined by the drift gate "
                         "(default: the process cache, $REPRO_TUNING_CACHE "
                         "or ~/.cache/repro/tuning.json)")
    ap.add_argument("--telemetry", default=None,
                    help="telemetry JSONL trace whose "
                         "registry.time_backend.result events feed the "
                         "drift gate")
    ap.add_argument("--drift-band", type=float, default=None,
                    help="drift tolerance band (x the calibrated median; "
                         "default 8.0)")
    args = ap.parse_args(argv)

    if not args.smoke and not os.environ.get(_CHILD_ENV):
        import jax
        if jax.device_count() < 2:
            # jax reads XLA_FLAGS once at backend init — too late for this
            # process, so the full audit forks a multi-device child
            raise SystemExit(_reexec(args, args.devices))
    raise SystemExit(_audit_here(args))


if __name__ == "__main__":
    main()
