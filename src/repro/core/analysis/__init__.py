"""Registry-wide static kernel auditor.

The conformance suite (PR 5) is dynamic: it executes one case per cell and
cannot see a write race in a Pallas grid, an accidental f64 promotion, an
extra collective inside a ``check_rep=False`` shard_map body, or a Python
scalar baked into an ``lru_cache``'d compiled closure.  This package is the
ahead-of-execution layer: every (kernel, backend) cell of the live registry
is traced to a closed jaxpr on its conformance-case inputs —
``jax.make_jaxpr`` abstract-evaluates, so compiled ``pallas`` backends
audit off-TPU and sharded backends audit under forced host devices — and
four passes run without executing anything:

  1. **dtypes** (`analysis.dtypes`) — float64-promotion lint under a forced
     x64 trace + accumulation-dtype downgrade check for psum/dot_general;
  2. **grid** (`analysis.grid`) — Pallas BlockSpec coverage proof: every
     output block written exactly once (holes / write races / OOB tiles),
     swept over every constraint-valid tunable point in the full audit;
  3. **collectives** (`analysis.collectives_audit`) — ppermute/psum/
     all_gather census vs each backend's declared communication contract
     (slab stencil: 2 ppermutes, pencil: 4; overlap variants additionally
     prove an interior compute independent of the halo traffic; any
     undeclared all_gather is a finding);
  4. **recompile** (`analysis.recompile`) — AST scan for lru_cache'd
     trace-producing builders keyed on runtime Python scalars.

PR 9 added the static *performance* auditor (`analysis.cost`) on the same
traces — the execution-free twin of `benchmarks/portability.py`:

  5. **traffic** — HBM byte/FLOP census with loop/grid multiplicities and
     BlockSpec-enumerated halo re-reads + accumulator revisits; traffic
     beyond the declared inflation limit over the compulsory boundary
     bytes is a finding;
  6. **roofline** — arithmetic intensity × the detected ChipSpec →
     predicted ms, memory/compute/collective bound verdict, statically
     attainable Eq.-4 fraction; a flip vs `declare_roofline_contract` is
     a finding;
  7. **drift** — predictions joined against measured time (PR-2 tuning
     cache + PR-8 telemetry), self-calibrated by the median
     measured/predicted ratio; a cell beyond the tolerance band is the
     "left N× on the table" finding.

The audited matrix derives from ``conformance.conformance_pairs()`` — never
a hand-written list.  ``python -m repro.core.analysis`` walks it (re-execing
under 8 forced host devices when needed) and writes a ``repro.analysis/v2``
JSON report; ``tests/test_static_analysis.py`` parametrizes the same matrix.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.core.analysis import collectives_audit, cost, dtypes, grid, recompile
from repro.core.analysis import jaxpr_utils as JU
from repro.core.analysis.report import (PASSES, SCHEMA, CellResult, Finding,
                                        SkipRecord, assemble_report)

__all__ = [
    "PASSES",
    "SCHEMA",
    "SMOKE_KERNELS",
    "Finding",
    "SkipRecord",
    "CellResult",
    "audit_cell",
    "audit_pairs",
    "audit_registry",
    "write_report",
]

#: tier-1 smoke subset: one kernel per audited shape of trouble (a pallas
#: sequential accumulator, a halo-exchange stencil, the f64-lint regression
#: site, and the revisited online-softmax decode output).  The smoke matrix
#: is still *derived*: conformance_pairs() filtered to these kernels.
SMOKE_KERNELS = ("stencil7", "babelstream.dot", "minibude.fasten",
                 "attention.decode")

#: bound on the constraint-valid tunable points swept per cell by the full
#: audit; anything dropped is recorded as a skip, never silently truncated
MAX_TUNABLE_POINTS = 32


def audit_pairs(smoke: bool = False) -> List[Tuple[str, str]]:
    """The audited (kernel, backend) matrix — conformance_pairs(), whole or
    filtered to the smoke kernels.  Derived from the live registry.

    Kernels registered with ``jaxpr_traceable=False`` (host-side driver
    loops like ``serving.engine``) are excluded: they have no single jaxpr
    to audit — conformance still executes them."""
    from repro.core import conformance
    from repro.core.portable import registry
    pairs = [(k, b) for k, b in conformance.conformance_pairs()
             if registry.get(k).jaxpr_traceable]
    if smoke:
        pairs = [(k, b) for k, b in pairs if k in SMOKE_KERNELS]
    return pairs


def _short(exc: BaseException) -> str:
    msg = str(exc).split("\n")[0]
    return f"{type(exc).__name__}: {msg[:200]}"


def _variant_tag(kwargs: Dict[str, Any]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(kwargs.items()))


def _recompile_findings(kernel: str, backend: str, fn: Any) -> List[Finding]:
    module = recompile.module_of(fn)
    if module is None or not module.startswith("repro"):
        return []
    findings = []
    for items in recompile.scan_module(module):
        h = dict(items)
        waived = h["waiver"] is not None
        findings.append(Finding(
            kernel=kernel, backend=backend, pass_name="recompile",
            code="scalar-cache-key",
            message=(f"{h['module']}:{h['line']} calls lru_cache'd "
                     f"trace-producing builder {h['builder']!r} with "
                     f"runtime scalar(s): {', '.join(h['scalars'])} — one "
                     f"compiled program per distinct value"),
            waived=waived, waive_reason=h["waiver"],
            detail={"module": h["module"], "line": h["line"],
                    "builder": h["builder"], "scalars": list(h["scalars"])}))
    return findings


def audit_cell(kernel: str, backend: str, *,
               smoke: bool = False) -> CellResult:
    """Run the four static passes on one registry cell.

    Never executes the kernel.  Cells this host cannot even *trace* (a
    sharded backend on a 1-device process) come back with per-pass
    ``SkipRecord``s carrying the reason — the CLI re-execs under forced
    host devices so the full report has none of those.
    """
    from repro.core import conformance
    from repro.core.portable import registry

    k = registry.get(kernel)
    b = k.backend(backend)
    res = CellResult(kernel=kernel, backend=backend)
    passes_run: List[str] = []

    # pass 4 is source-level: it runs even for cells that cannot trace
    res.findings.extend(_recompile_findings(kernel, backend, b.fn))
    passes_run.append("recompile")

    case = conformance.CASES.get(kernel)
    if case is None:
        for p in ("dtypes", "grid", "collectives"):
            res.skips.append(SkipRecord(
                kernel, backend, p,
                "no conformance case (conformance itself fails this cell)"))
        res.passes_run = tuple(passes_run)
        return res
    args, kwargs = case()

    variants = collectives_audit.normalize_contract(
        k.comm_contract(backend), args)
    declared = backend in k.comm_contracts

    traces: Dict[Tuple[Tuple[str, Any], ...], Any] = {}

    def trace_with(extra: Dict[str, Any]):
        key = tuple(sorted({**kwargs, **extra}.items(),
                           key=lambda kv: kv[0]))
        if key not in traces:
            traces[key] = JU.trace(b.fn, args, {**kwargs, **extra})
        return traces[key]

    # --- pass 3: collectives, one trace per contract variant ------------
    coll_ok = True
    for vkw, expected in variants:
        try:
            closed = trace_with(vkw)
        except Exception as exc:
            res.skips.append(SkipRecord(kernel, backend, "collectives",
                                        f"variant {_variant_tag(vkw)} "
                                        f"untraceable: {_short(exc)}"))
            coll_ok = False
            continue
        res.findings.extend(collectives_audit.check_counts(
            kernel, backend, closed, expected, declared,
            variant=_variant_tag(vkw)))
    if coll_ok:
        passes_run.append("collectives")

    # --- passes 1 + 2 run on the default-variant trace -------------------
    default_kw = variants[0][0]
    try:
        closed = trace_with(default_kw)
    except Exception as exc:
        for p in ("dtypes", "grid"):
            res.skips.append(SkipRecord(kernel, backend, p, _short(exc)))
        res.passes_run = tuple(passes_run)
        return res

    res.findings.extend(dtypes.run_accum_check(
        kernel, backend, closed, k.accum_dtype))
    try:
        res.findings.extend(dtypes.run_f64_lint(
            kernel, backend, b.fn, args, {**kwargs, **default_kw}))
        passes_run.append("dtypes")
    except Exception as exc:
        res.skips.append(SkipRecord(kernel, backend, "dtypes",
                                    f"x64 trace failed: {_short(exc)}"))

    accum = k.grid_contract(backend).get("accumulator_outputs", ())
    gfindings, ncalls = grid.run(kernel, backend, closed, accum,
                                 variant=_variant_tag(default_kw))
    res.findings.extend(gfindings)
    passes_run.append("grid")

    # --- passes 5 + 6: traffic census + roofline verdict -----------------
    from repro.core.roofline import detect_chip
    chip = detect_chip()
    try:
        tr = cost.census(closed)
        v = cost.verdict(tr, chip)
        res.findings.extend(cost.traffic_findings(
            kernel, backend, k, tr, variant=_variant_tag(default_kw)))
        res.findings.extend(cost.roofline_findings(kernel, backend, k, tr, v))
        res.cost = {"chip": chip.name, "traffic": tr.to_json(),
                    "verdict": v.to_json(), "points": [],
                    "best_predicted": None}
        passes_run.extend(["traffic", "roofline"])
    except Exception as exc:
        for p in ("traffic", "roofline"):
            res.skips.append(SkipRecord(kernel, backend, p, _short(exc)))

    # full audit: cross-check the declared TunableSpace constraint — every
    # constraint-valid point must still satisfy the coverage proof AND get
    # its own traffic census (a block size that re-streams whole operands
    # is a per-point defect the default point can't show)
    space = k.tunable_space(backend)
    if not smoke and ncalls and space is not None:
        try:
            points = space.valid_points(*args, **kwargs)
        except Exception as exc:
            points = []
            res.skips.append(SkipRecord(
                kernel, backend, "grid",
                f"constraint not evaluable here: {_short(exc)}"))
        if len(points) > MAX_TUNABLE_POINTS:
            res.skips.append(SkipRecord(
                kernel, backend, "grid",
                f"tunable sweep capped at {MAX_TUNABLE_POINTS} of "
                f"{len(points)} valid points"))
            points = points[:MAX_TUNABLE_POINTS]
        for pt in points:
            try:
                pclosed = trace_with({**default_kw, **pt})
            except Exception as exc:
                res.findings.append(Finding(
                    kernel=kernel, backend=backend, pass_name="grid",
                    code="constraint-admits-untraceable-point",
                    message=(f"constraint-valid point {pt} does not even "
                             f"trace: {_short(exc)}"),
                    detail={"point": {n: repr(v) for n, v in pt.items()}}))
                continue
            pfind, _ = grid.run(kernel, backend, pclosed, accum,
                                variant=_variant_tag(pt))
            res.findings.extend(pfind)
            if res.cost is None:
                continue
            try:
                ptr = cost.census(pclosed)
                pv = cost.verdict(ptr, chip)
            except Exception as exc:
                res.skips.append(SkipRecord(
                    kernel, backend, "traffic",
                    f"point {_variant_tag(pt)} not costable: {_short(exc)}"))
                continue
            res.findings.extend(cost.traffic_findings(
                kernel, backend, k, ptr, variant=_variant_tag(pt)))
            res.cost["points"].append({
                "params": {n: repr(v) for n, v in pt.items()},
                "flops": ptr.flops, "hbm_bytes": ptr.hbm_bytes,
                "inflation": ptr.inflation,
                "predicted_ms": pv.predicted_s * 1e3, "bound": pv.bound})

    if res.cost is not None and res.cost["points"]:
        best = min(res.cost["points"], key=lambda p: p["predicted_ms"])
        res.cost["best_predicted"] = best["params"]

    res.passes_run = tuple(passes_run)
    return res


def audit_registry(*, smoke: bool = False, tuning_cache: Any = None,
                   telemetry_trace: Optional[str] = None,
                   drift_band: Optional[float] = None) -> Dict[str, Any]:
    """Audit the whole derived matrix and assemble the v2 report.

    The per-cell passes (1–6) run first; the registry-level drift gate
    (pass 7) then joins the tuning cache (``tuning_cache`` path, default
    the process cache) and optional ``telemetry_trace`` JSONL against the
    static predictions for the same matrix.
    """
    import jax

    from repro.core.roofline import detect_chip

    pairs = audit_pairs(smoke)
    cells = [audit_cell(k, b, smoke=smoke) for k, b in pairs]
    drift = cost.drift_gate(cache_path=tuning_cache,
                            trace_path=telemetry_trace,
                            pairs=set(pairs), band=drift_band)
    return assemble_report(cells, device_count=jax.device_count(),
                           smoke=smoke, chip=detect_chip().name, drift=drift)


def write_report(report: Dict[str, Any], path: str) -> None:
    import json
    import os
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
