"""Shared jaxpr machinery for the static auditor.

Everything here works on *traced* programs only — ``jax.make_jaxpr``
abstract-evaluates the backend on the conformance-case inputs, so compiled
``pallas`` backends trace off-TPU and ``shard_map`` bodies trace on any
host with enough (possibly forced) devices, all without executing a single
kernel.  The recursive walk descends into every eqn param that holds a
sub-jaxpr (``pjit``, ``scan``, ``while``, ``shard_map``, ``pallas_call``,
``custom_*`` — anything carrying a ``Jaxpr``/``ClosedJaxpr`` or a
list/tuple of them), so a collective or a float64 eqn cannot hide inside a
nested trace.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
from jax.core import ClosedJaxpr, Jaxpr, Literal

#: shard_map spells psum as ``psum2`` since jax 0.4.31; both count as psum.
#: ``pbroadcast`` is replication bookkeeping, not data movement — ignored.
PSUM_PRIMITIVES = ("psum", "psum2")
COLLECTIVE_KINDS = ("ppermute", "psum", "all_gather")


def _iter_subjaxprs(params: Dict[str, Any]) -> Iterator[Jaxpr]:
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for s in vals:
            inner = getattr(s, "jaxpr", s)
            if isinstance(inner, Jaxpr):
                yield inner


def iter_eqns(jaxpr: Jaxpr) -> Iterator[Any]:
    """Every eqn of ``jaxpr`` and (recursively) of every nested sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn
        for inner in _iter_subjaxprs(eqn.params):
            yield from iter_eqns(inner)


def trace(fn: Callable[..., Any], args: tuple, kwargs: dict) -> ClosedJaxpr:
    """Closed jaxpr of ``fn(*args, **kwargs)`` — abstract eval, no run."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


def count_collectives(jaxpr: Jaxpr) -> Dict[str, int]:
    """Collective-primitive census: ppermute / psum(+psum2) / all_gather."""
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in PSUM_PRIMITIVES:
            counts["psum"] += 1
        elif name in ("ppermute", "all_gather"):
            counts[name] += 1
    return counts


def find_pallas_grid_mappings(jaxpr: Jaxpr) -> List[Any]:
    """``grid_mapping`` of every ``pallas_call`` eqn, however nested."""
    return [eqn.params["grid_mapping"] for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name == "pallas_call"]


def find_shard_map_bodies(jaxpr: Jaxpr) -> List[Jaxpr]:
    """Body jaxprs of every ``shard_map`` eqn, however nested."""
    bodies = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == "shard_map":
            body = eqn.params["jaxpr"]
            bodies.append(getattr(body, "jaxpr", body))
    return bodies


def independent_compute_exists(body: Jaxpr, shape: Tuple[int, ...]) -> bool:
    """True when ``body`` contains an eqn output of ``shape`` that depends
    on a body input but on NO ``ppermute`` output — the static witness of
    halo/compute overlap (the interior stencil must be schedulable while
    the halo traffic is in flight).  Non-overlapped bodies compute only on
    the halo-padded block, so every full-shape eqn is ppermute-tainted."""
    tainted: set = set()
    from_input = {str(v) for v in body.invars}
    found = False
    for eqn in body.eqns:
        ins = [str(v) for v in eqn.invars if not isinstance(v, Literal)]
        is_tainted = (eqn.primitive.name == "ppermute"
                      or any(v in tainted for v in ins))
        depends = any(v in from_input for v in ins)
        for v in eqn.outvars:
            if is_tainted:
                tainted.add(str(v))
            if depends:
                from_input.add(str(v))
        if (not is_tainted and depends
                and any(tuple(getattr(v.aval, "shape", ())) == tuple(shape)
                        for v in eqn.outvars)):
            found = True
    return found


def eval_index_map(index_map_jaxpr: ClosedJaxpr,
                   idx: Tuple[int, ...]) -> Tuple[int, ...]:
    """Evaluate one BlockSpec index map at a concrete grid point."""
    out = jax.core.eval_jaxpr(index_map_jaxpr.jaxpr, index_map_jaxpr.consts,
                              *idx)
    return tuple(int(v) for v in out)


def output_block_mappings(grid_mapping: Any) -> List[Tuple[int, Any]]:
    """(output_index, BlockMapping) for each pallas output, identified by
    the mapping's ``origin`` with a positional fallback (inputs precede
    outputs in ``block_mappings``; scalar-prefetch operands have none)."""
    mappings = list(grid_mapping.block_mappings)
    outs = [bm for bm in mappings
            if bm is not None and "output" in str(getattr(bm, "origin", ""))]
    if not outs:
        n_out = grid_mapping.num_outputs
        outs = [m for m in mappings[-n_out:] if m is not None]
    return list(enumerate(outs))


def grid_points(grid: Tuple[int, ...]) -> Iterator[Tuple[int, ...]]:
    """Row-major walk of the (static) grid index space."""
    import itertools
    yield from itertools.product(*(range(int(g)) for g in grid))
