"""Finding / skip records and the ``repro.analysis/v2`` report assembly.

v2 (PR 9) adds the static performance auditor: three new passes
(``traffic``, ``roofline``, ``drift``), a per-cell ``cost`` section
(traffic census + roofline verdict + per-tunable-point predictions), the
audited ``chip`` name, and a ``drift`` section with the measurement joins
and the host calibration factor.  v1 consumers that only read
``findings``/``waived``/``skips``/``summary`` keep working unchanged."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

SCHEMA = "repro.analysis/v2"

#: the seven static passes, in report order (4 correctness + 3 performance)
PASSES = ("dtypes", "grid", "collectives", "recompile",
          "traffic", "roofline", "drift")

SEVERITIES = ("error", "warning")


@dataclasses.dataclass
class Finding:
    """One defect the auditor can prove from the trace (or source) alone."""

    kernel: str
    backend: str
    pass_name: str          # one of PASSES
    code: str               # stable slug, e.g. "f64-promotion", "write-race"
    message: str
    severity: str = "error"
    waived: bool = False
    waive_reason: Optional[str] = None
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SkipRecord:
    """A (cell, pass) the auditor could not run here, and why."""

    kernel: str
    backend: str
    pass_name: str
    reason: str

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CellResult:
    """Audit outcome of one (kernel, backend) registry cell."""

    kernel: str
    backend: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    skips: List[SkipRecord] = dataclasses.field(default_factory=list)
    passes_run: Tuple[str, ...] = ()
    #: the performance auditor's census/verdict for this cell (v2), keyed
    #: ``{"chip", "traffic", "verdict", "points", "best_predicted"}``
    cost: Optional[Dict[str, Any]] = None

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self) -> List[Finding]:
        return [f for f in self.findings if f.waived]


def _dedup_source_level(findings: List[Finding]) -> List[Finding]:
    """Pass-4 findings are per source location, not per cell: many registry
    cells share a defining module, so the report keeps one entry per
    (code, module, line) while per-cell results keep them all."""
    out, seen = [], set()
    for f in findings:
        if f.pass_name != "recompile":
            out.append(f)
            continue
        key = (f.code, f.detail.get("module"), f.detail.get("line"))
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def assemble_report(cells: List[CellResult], *, device_count: int,
                    smoke: bool, chip: Optional[str] = None,
                    drift: Optional[Tuple[List[Finding], Dict[str, Any]]]
                    = None) -> Dict[str, Any]:
    """The ``repro.analysis/v2`` JSON document.

    ``drift`` is the registry-level pass-7 outcome — its findings merge
    into the same findings/waived lists as the per-cell passes (so the CLI
    exit code and ``benchmarks/run.py --only analysis`` gate on them for
    free), and its join records land under the top-level ``drift`` key.
    """
    drift_findings, drift_summary = drift if drift is not None else ([], {})
    all_errors = [f for c in cells for f in c.errors] \
        + [f for f in drift_findings if not f.waived]
    all_waived = [f for c in cells for f in c.waived] \
        + [f for f in drift_findings if f.waived]
    findings = _dedup_source_level(all_errors)
    waived = _dedup_source_level(all_waived)
    skips = [s for c in cells for s in c.skips]
    return {
        "schema": SCHEMA,
        "smoke": bool(smoke),
        "device_count": int(device_count),
        "chip": chip,
        "passes": list(PASSES),
        "matrix": [[c.kernel, c.backend] for c in cells],
        "findings": [f.to_json() for f in findings],
        "waived": [f.to_json() for f in waived],
        "skips": [s.to_json() for s in skips],
        "cost": {f"{c.kernel}[{c.backend}]": c.cost
                 for c in cells if c.cost is not None},
        "drift": drift_summary,
        "summary": {
            "cells": len(cells),
            "audited": sum(1 for c in cells if c.passes_run),
            "findings": len(findings),
            "waived": len(waived),
            "skips": len(skips),
            "drift_joined": drift_summary.get("joined", 0),
        },
    }
