"""Passes 5–7 — the static performance auditor (traffic / roofline / drift).

PR 7 proved every (kernel, backend) registry cell *correct* without
executing it; this module proves every cell *fast enough* without executing
it.  Three execution-free passes over the same closed-jaxpr traces:

  5. **traffic** — a census of HBM bytes read/written and FLOPs, walked
     from the jaxpr with loop/grid multiplicities (``scan`` bodies count
     ``length`` times, ``pallas_call`` bodies once per grid step, the most
     expensive ``cond`` branch wins).  Pallas BlockSpecs are costed by the
     same index-map enumeration as the grid pass, so halo *re-reads* and
     accumulator *revisits* are counted as real traffic, not wished away.
     The jaxpr boundary (invars + consts + outvars) is the minimum-traffic
     floor; ``inflation = traffic / floor`` is the "how many times over the
     compulsory bytes does this kernel move" number, and a cell whose
     inflation exceeds its declared (or the default) limit is a finding.
  6. **roofline** — arithmetic intensity × the detected ``ChipSpec`` →
     three-term predicted seconds, a ``bound`` verdict
     (memory | compute | collective), and the statically attainable
     fraction of peak compute — the paper's Eq.-4 e_i upper bound computed
     without running anything.  Kernels may pin their expected bound via
     ``declare_roofline_contract``; a verdict flip is a finding.
  7. **drift** — join the predictions against *measured* time from the
     PR-2 tuning cache and PR-8 ``registry.time_backend`` telemetry.  The
     absolute scale of a static model is host-dependent, so the gate
     self-calibrates: the median measured/predicted ratio across all joined
     cells is the host factor, and a cell whose own ratio exceeds
     ``band ×`` the median is the "your kernel left N× on the table" lint.

The same cost model is the prior for ``tuning.tune(search="model")``:
:func:`rank_points` orders a tunable grid by predicted cost and
:func:`prune_dominated` drops points that are strictly worse on traffic
AND parallelism before anything is timed.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import math
import re
import statistics
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.analysis import jaxpr_utils as JU
from repro.core.analysis.grid import MAX_GRID_POINTS
from repro.core.analysis.report import Finding
from repro.core.roofline import ChipSpec, detect_chip

__all__ = [
    "Traffic",
    "Verdict",
    "census",
    "verdict",
    "traffic_findings",
    "roofline_findings",
    "drift_gate",
    "collect_measurements",
    "parse_shape_signature",
    "rank_points",
    "prune_dominated",
    "DEFAULT_INFLATION_LIMIT",
    "DEFAULT_DRIFT_BAND",
    "MIN_DRIFT_JOINS",
    "DRIFT_WAIVERS",
]

#: traffic over the compulsory floor tolerated without a declared limit —
#: generous enough for halo re-reads and online-softmax revisits, tight
#: enough that a block mapping re-streaming whole operands per grid step
#: (the planted fixture, a real O(grid) blowup) still fires
DEFAULT_INFLATION_LIMIT = 8.0

#: drift findings fire when a cell's measured/predicted ratio exceeds
#: ``band ×`` the registry-wide median ratio (the host calibration factor)
DEFAULT_DRIFT_BAND = 8.0

#: the calibration median is meaningless over fewer joins than this — the
#: gate reports the joins but emits no findings below it
MIN_DRIFT_JOINS = 3

#: (kernel, backend) cells whose drift is understood and accepted; the
#: finding still appears in the report's ``waived`` list
DRIFT_WAIVERS: Dict[Tuple[str, str], str] = {}


def _short(exc: BaseException) -> str:
    msg = str(exc).split("\n")[0]
    return f"{type(exc).__name__}: {msg[:200]}"


def _prod(xs) -> float:
    out = 1.0
    for x in xs:
        out *= float(x)
    return out


def _aval_bytes(aval: Any) -> float:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0.0
    try:
        return _prod(shape) * np.dtype(dtype).itemsize
    except TypeError:
        return 0.0


def _out_elems(eqn: Any) -> float:
    for v in eqn.outvars:
        shape = getattr(v.aval, "shape", None)
        if shape is not None:
            return _prod(shape)
    return 1.0


# FLOP weights per output element.  Deliberately conventional (everything
# elementwise is 1 FLOP/element, a dot_general is 2·M·N·K): the model is
# used for *relative* verdicts and priors, not absolute TFLOP/s claims.
_EW_PRIMS = frozenset((
    "add", "sub", "mul", "div", "rem", "pow", "atan2", "max", "min",
    "nextafter", "and", "or", "xor", "not", "neg", "abs", "sign", "floor",
    "ceil", "round", "exp", "exp2", "log", "log1p", "expm1", "tanh", "sin",
    "cos", "tan", "asin", "acos", "atan", "sinh", "cosh", "asinh", "acosh",
    "atanh", "sqrt", "rsqrt", "cbrt", "logistic", "erf", "erfc", "erf_inv",
    "square", "integer_pow", "is_finite", "eq", "ne", "lt", "le", "gt",
    "ge", "select_n", "clamp",
))
_REDUCE_PRIMS = frozenset((
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumprod", "cummax",
    "cummin", "cumlogsumexp",
))
_CONTAINER_PRIMS = frozenset((
    "pjit", "closed_call", "core_call", "remat", "checkpoint", "remat2",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_jvp_call_jaxpr",
))


@dataclasses.dataclass
class Traffic:
    """The census: one traced cell's modeled work and data movement.

    All byte/FLOP totals are *program-wide* (shard_map bodies are counted
    once per shard); :func:`verdict` divides the compute and memory terms
    by ``shards`` when predicting wall-clock.
    """

    flops: float = 0.0
    hbm_read_bytes: float = 0.0
    hbm_write_bytes: float = 0.0
    hbm_min_bytes: float = 0.0       # compulsory floor: invars+consts+outvars
    collective_bytes: float = 0.0
    collective_count: float = 0.0
    reread_bytes: float = 0.0        # pallas input blocks read more than once
    revisit_bytes: float = 0.0       # pallas accumulator blocks re-written
    pallas_calls: int = 0
    grid_steps: float = 0.0          # total pallas grid steps (× loop mult)
    approx_grids: int = 0            # grids costed without enumeration
    unknown_trip_loops: int = 0      # while-loops counted as one trip
    shards: int = 1

    @property
    def hbm_bytes(self) -> float:
        return self.hbm_read_bytes + self.hbm_write_bytes

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def inflation(self) -> float:
        return self.hbm_bytes / max(self.hbm_min_bytes, 1.0)

    def merge(self, other: "Traffic") -> None:
        self.flops += other.flops
        self.hbm_read_bytes += other.hbm_read_bytes
        self.hbm_write_bytes += other.hbm_write_bytes
        self.collective_bytes += other.collective_bytes
        self.collective_count += other.collective_count
        self.reread_bytes += other.reread_bytes
        self.revisit_bytes += other.revisit_bytes
        self.pallas_calls += other.pallas_calls
        self.grid_steps += other.grid_steps
        self.approx_grids += other.approx_grids
        self.unknown_trip_loops += other.unknown_trip_loops
        self.shards = max(self.shards, other.shards)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["hbm_bytes"] = self.hbm_bytes
        d["arithmetic_intensity"] = self.arithmetic_intensity
        d["inflation"] = self.inflation
        return d


def _clipped_block_bytes(bi: Tuple[int, ...], block: Tuple[int, ...],
                         shape: Tuple[int, ...], itemsize: int) -> float:
    elems = 1.0
    for i, b, s in zip(bi, block, shape):
        extent = min(b, s - i * b)
        if extent <= 0:
            return 0.0  # out-of-bounds tile: the grid pass owns that finding
        elems *= extent
    return elems * itemsize


def _pallas_traffic(gm: Any, mult: float, t: Traffic) -> float:
    """Blockwise HBM traffic of one pallas_call; returns the grid-step count
    (the body multiplicity for the FLOP walk)."""
    grid = tuple(int(g) for g in (getattr(gm, "grid", ()) or ()))
    steps = _prod(grid) if grid else 1.0
    mappings = [bm for bm in gm.block_mappings if bm is not None]
    out_ids = {id(bm) for _, bm in JU.output_block_mappings(gm)}
    enumerable = 0 < steps <= MAX_GRID_POINTS
    if not enumerable:
        t.approx_grids += 1
    for bm in mappings:
        try:
            block = tuple(int(b) for b in bm.block_shape)
            arr = bm.array_shape_dtype
            shape = tuple(int(s) for s in arr.shape)
            itemsize = int(np.dtype(arr.dtype).itemsize)
        except (TypeError, ValueError, AttributeError):
            continue  # non-Blocked/squeezed mapping: not modeled
        full_block = _prod(block) * itemsize
        arr_bytes = _prod(shape) * itemsize
        total = distinct = None
        if enumerable:
            try:
                visits: Dict[Tuple[int, ...], int] = {}
                for idx in JU.grid_points(grid):
                    bi = JU.eval_index_map(bm.index_map_jaxpr, idx)
                    visits[bi] = visits.get(bi, 0) + 1
                total, distinct = 0.0, 0.0
                for bi, cnt in visits.items():
                    cb = _clipped_block_bytes(bi, block, shape, itemsize)
                    total += cnt * cb
                    distinct += cb
            except Exception:
                total = None  # index map needs inputs we don't have
        if total is None:
            total = steps * full_block
            distinct = min(total, arr_bytes)
        extra = max(0.0, total - distinct)
        if id(bm) in out_ids:
            # every visit writes the block; a revisit additionally reads
            # the previous partial back (accumulator read-modify-write)
            t.hbm_write_bytes += total * mult
            t.hbm_read_bytes += extra * mult
            t.revisit_bytes += extra * mult
        else:
            t.hbm_read_bytes += total * mult
            t.reread_bytes += extra * mult
    return max(steps, 1.0)


def _walk(jaxpr: Any, mult: float, t: Traffic) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = float(eqn.params.get("length", 1) or 1)
            body = eqn.params.get("jaxpr")
            if body is not None:
                _walk(getattr(body, "jaxpr", body), mult * length, t)
        elif name == "while":
            t.unknown_trip_loops += 1
            body = eqn.params.get("body_jaxpr")
            if body is not None:
                _walk(getattr(body, "jaxpr", body), mult, t)
        elif name == "cond":
            best: Optional[Traffic] = None
            for br in eqn.params.get("branches", ()):
                tb = Traffic()
                _walk(getattr(br, "jaxpr", br), mult, tb)
                if best is None or (tb.flops + tb.hbm_bytes
                                    > best.flops + best.hbm_bytes):
                    best = tb
            if best is not None:
                t.merge(best)
        elif name == "shard_map":
            mesh = eqn.params.get("mesh")
            size = int(getattr(mesh, "size", 1) or 1)
            t.shards = max(t.shards, size)
            body = eqn.params.get("jaxpr")
            if body is not None:
                _walk(getattr(body, "jaxpr", body), mult * size, t)
        elif name == "pallas_call":
            t.pallas_calls += 1
            gm = eqn.params.get("grid_mapping")
            steps = 1.0
            if gm is not None:
                steps = _pallas_traffic(gm, mult, t)
                t.grid_steps += steps * mult
            body = eqn.params.get("jaxpr")
            if body is not None:
                _walk(getattr(body, "jaxpr", body), mult * steps, t)
        elif name in JU.PSUM_PRIMITIVES or name in ("ppermute", "all_to_all",
                                                    "reduce_scatter"):
            payload = sum(_aval_bytes(v.aval) for v in eqn.invars
                          if hasattr(v, "aval"))
            t.collective_bytes += payload * mult
            t.collective_count += mult
            if name in JU.PSUM_PRIMITIVES:
                t.flops += (payload / max(1, _itemsize_of(eqn))) * mult
        elif name == "all_gather":
            payload = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            t.collective_bytes += payload * mult
            t.collective_count += mult
        elif name == "dot_general":
            (lc, _rc), _batch = eqn.params["dimension_numbers"]
            lhs_shape = getattr(eqn.invars[0].aval, "shape", ())
            kdim = _prod(lhs_shape[i] for i in lc) if lc else 1.0
            t.flops += 2.0 * _out_elems(eqn) * kdim * mult
        elif name in _REDUCE_PRIMS:
            ins = [v for v in eqn.invars if hasattr(v, "aval")]
            elems = _prod(getattr(ins[0].aval, "shape", ())) if ins else 1.0
            t.flops += elems * mult
        elif name in _EW_PRIMS:
            t.flops += _out_elems(eqn) * mult
        else:
            # unknown containers (linear_call, ffi wrappers, ...): descend
            # into any sub-jaxpr so nested work is never silently dropped
            for inner in JU._iter_subjaxprs(eqn.params):
                _walk(inner, mult, t)


def _itemsize_of(eqn: Any) -> int:
    for v in eqn.invars:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is not None:
            return int(np.dtype(dt).itemsize)
    return 1


def census(closed: Any) -> Traffic:
    """Walk one closed jaxpr into a :class:`Traffic` record.  Pure trace
    math — nothing executes."""
    t = Traffic()
    jx = closed.jaxpr
    _walk(jx, 1.0, t)
    boundary_read = sum(_aval_bytes(v.aval) for v in jx.invars)
    for c in closed.consts:
        try:
            boundary_read += float(np.asarray(c).nbytes)
        except Exception:
            pass
    boundary_write = sum(_aval_bytes(v.aval) for v in jx.outvars)
    t.hbm_min_bytes = boundary_read + boundary_write
    # The boundary is the floor for *every* backend; the blockwise pallas
    # traffic replaces it only where it exceeds it (a fused XLA cell has no
    # per-block visibility, so its census IS the floor — inflation 1.0).
    t.hbm_read_bytes = max(t.hbm_read_bytes, boundary_read)
    t.hbm_write_bytes = max(t.hbm_write_bytes, boundary_write)
    return t


# --------------------------------------------------------------------------
# pass 6: roofline verdict
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Verdict:
    """Three-term static roofline of one cell on one chip."""

    chip: str
    compute_s: float
    memory_s: float
    collective_s: float
    predicted_s: float
    bound: str                      # "compute" | "memory" | "collective"
    attainable_frac: float          # statically attainable fraction of peak

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["predicted_ms"] = self.predicted_s * 1e3
        return d


def verdict(t: Traffic, chip: Optional[ChipSpec] = None) -> Verdict:
    """Eq.-4's e_i computed statically: the max of the three roofline terms
    is the predicted step time, its argmax the bound, and the compute term's
    share of it the attainable fraction of peak FLOP/s."""
    chip = chip if chip is not None else detect_chip()
    shards = max(1, t.shards)
    compute_s = t.flops / (chip.peak_flops * shards)
    memory_s = t.hbm_bytes / (chip.hbm_bw * shards)
    collective_s = t.collective_bytes / (chip.ici_bw * shards)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    predicted_s = max(terms.values())
    bound = max(terms, key=terms.get)
    attainable = compute_s / predicted_s if predicted_s > 0 else 1.0
    return Verdict(chip=chip.name, compute_s=compute_s, memory_s=memory_s,
                   collective_s=collective_s, predicted_s=predicted_s,
                   bound=bound, attainable_frac=attainable)


def traffic_findings(kernel: str, backend: str, k: Any, t: Traffic,
                     variant: str = "") -> List[Finding]:
    """Pass 5 check: modeled traffic vs the compulsory floor."""
    contract = k.roofline_contract(backend) if hasattr(
        k, "roofline_contract") else {}
    limit = float(contract.get("traffic_inflation_limit",
                               DEFAULT_INFLATION_LIMIT))
    tag = f" [{variant}]" if variant else ""
    if t.inflation <= limit:
        return []
    return [Finding(
        kernel=kernel, backend=backend, pass_name="traffic",
        code="traffic-inflation",
        message=(f"modeled HBM traffic{tag} is {t.inflation:.1f}× the "
                 f"compulsory {t.hbm_min_bytes:.0f} bytes "
                 f"(re-reads {t.reread_bytes:.0f}, revisits "
                 f"{t.revisit_bytes:.0f}); limit {limit:g}× — "
                 f"declare_roofline_contract to raise it if intended"),
        detail={"inflation": t.inflation, "limit": limit,
                "hbm_bytes": t.hbm_bytes, "floor_bytes": t.hbm_min_bytes,
                "reread_bytes": t.reread_bytes,
                "revisit_bytes": t.revisit_bytes, "variant": variant})]


def roofline_findings(kernel: str, backend: str, k: Any, t: Traffic,
                      v: Verdict) -> List[Finding]:
    """Pass 6 check: verdict vs the declared bound (when one is pinned)."""
    contract = k.roofline_contract(backend) if hasattr(
        k, "roofline_contract") else {}
    declared = contract.get("bound")
    if not declared or v.bound == declared:
        return []
    return [Finding(
        kernel=kernel, backend=backend, pass_name="roofline",
        code="bound-mismatch",
        message=(f"declared {declared}-bound but the {v.chip} roofline says "
                 f"{v.bound}-bound (AI {t.arithmetic_intensity:.2f} "
                 f"FLOP/byte, predicted {v.predicted_s * 1e3:.3f} ms)"),
        detail={"declared": declared, "verdict": v.bound,
                "arithmetic_intensity": t.arithmetic_intensity,
                "predicted_ms": v.predicted_s * 1e3, "chip": v.chip})]


# --------------------------------------------------------------------------
# pass 7: drift gate (predictions vs measured time)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Measurement:
    """One measured (kernel, backend, shape, params) → seconds sample."""

    kernel: str
    backend: str
    shape: str                      # tuning.shape_signature string
    params: Dict[str, Any]
    seconds: float
    source: str                     # "cache" | "telemetry"
    devices: int = 1
    platform: str = ""


_ARRAY_SIG = re.compile(r"^([A-Za-z_][A-Za-z_0-9]*)\[([0-9,]*)\]$")


def _np_dtype(name: str) -> Any:
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        special = getattr(jnp, name, None)
        if special is not None:
            return np.dtype(special)
        raise


def parse_shape_signature(
        sig: str) -> Optional[Tuple[Tuple[Any, ...], Dict[str, Any]]]:
    """Invert ``tuning.shape_signature``: ``f32[8,64];0.5;k=int32[2]`` →
    (positional arg structs/literals, kwargs).  Array parts come back as
    ``jax.ShapeDtypeStruct`` (traceable without materializing), scalar parts
    via ``ast.literal_eval``.  Returns ``None`` when any part is neither —
    that measurement simply can't be re-traced and is skipped."""
    import jax
    args: List[Any] = []
    kwargs: Dict[str, Any] = {}
    if sig == "":
        return tuple(args), kwargs
    for part in sig.split(";"):
        name = None
        if "=" in part and not part.startswith("="):
            maybe, rest = part.split("=", 1)
            if maybe.isidentifier():
                name, part = maybe, rest
        m = _ARRAY_SIG.match(part)
        if m:
            try:
                dtype = _np_dtype(m.group(1))
            except TypeError:
                return None
            dims = tuple(int(d) for d in m.group(2).split(",") if d)
            val: Any = jax.ShapeDtypeStruct(dims, dtype)
        else:
            try:
                val = ast.literal_eval(part)
            except (ValueError, SyntaxError):
                return None
        if name is None:
            args.append(val)
        else:
            kwargs[name] = val
    return tuple(args), kwargs


def _cache_measurements(cache_path: Any,
                        pairs: Optional[set]) -> List[Measurement]:
    from pathlib import Path

    from repro.core import tuning
    path = Path(cache_path) if cache_path is not None \
        else tuning.default_cache_path()
    entries = tuning.TuningCache._read_entries(path)
    out = []
    for key_str, entry in entries.items():
        parts = key_str.split("|")
        if len(parts) != 7:
            continue
        kernel, backend, shape, _dtype, platform, code, dev = parts
        if pairs is not None and (kernel, backend) not in pairs:
            continue
        try:
            devices = int(dev.lstrip("d"))
            seconds = float(entry.get("seconds", 0.0))
        except (TypeError, ValueError):
            continue
        if not (seconds > 0.0 and math.isfinite(seconds)):
            continue
        out.append(Measurement(
            kernel=kernel, backend=backend, shape=shape,
            params=tuning.params_from_cache(entry.get("params", {}) or {}),
            seconds=seconds, source="cache", devices=devices,
            platform=platform))
    return out


def _telemetry_measurements(trace_path: str,
                            pairs: Optional[set]) -> List[Measurement]:
    from repro.core import tuning
    from repro.core.telemetry import export
    try:
        doc = export.read_events(trace_path)
    except (OSError, ValueError):
        return []
    out = []
    for ev in doc.get("events", ()):
        if ev.get("name") != "registry.time_backend.result":
            continue
        attrs = ev.get("attrs", {}) or {}
        kernel, backend = attrs.get("kernel"), attrs.get("backend")
        shape, seconds = attrs.get("shape"), attrs.get("seconds")
        if not kernel or not backend or shape is None or seconds is None:
            continue
        if pairs is not None and (kernel, backend) not in pairs:
            continue
        try:
            seconds = float(seconds)
            params = json.loads(attrs.get("params_json", "{}"))
        except (TypeError, ValueError):
            continue
        if not (seconds > 0.0 and math.isfinite(seconds)):
            continue
        out.append(Measurement(
            kernel=kernel, backend=backend, shape=str(shape),
            params=tuning.params_from_cache(params or {}), seconds=seconds,
            source="telemetry", devices=int(attrs.get("devices", 1) or 1),
            platform=str(attrs.get("platform", ""))))
    return out


def collect_measurements(cache_path: Any = None,
                         trace_path: Optional[str] = None,
                         pairs: Optional[set] = None) -> List[Measurement]:
    """Measured samples joinable to static predictions, deduped on
    (kernel, backend, shape, params) keeping the best (smallest) seconds.
    Only measurements from *this* platform at a traceable device count are
    kept — a TPU-measured entry must not calibrate a CPU prediction."""
    import jax
    platform = jax.devices()[0].platform
    devices = jax.device_count()
    ms = _cache_measurements(cache_path, pairs)
    if trace_path:
        ms += _telemetry_measurements(trace_path, pairs)
    best: Dict[Tuple[str, str, str, str], Measurement] = {}
    for m in ms:
        if m.platform and m.platform != platform:
            continue
        if m.devices > devices:
            continue
        key = (m.kernel, m.backend, m.shape,
               json.dumps(m.params, sort_keys=True, default=repr))
        if key not in best or m.seconds < best[key].seconds:
            best[key] = m
    return [best[k] for k in sorted(best)]


def predict_seconds(m: Measurement,
                    chip: Optional[ChipSpec] = None) -> Optional[float]:
    """Static predicted seconds for one measurement's exact problem, or
    ``None`` when the cell can't be re-traced here (unknown kernel, stale
    code, sharded cell on a small host, unparsable signature)."""
    from repro.core import tuning
    from repro.core.portable import registry
    try:
        k = registry.get(m.kernel)
        b = k.backends[m.backend]
    except KeyError:
        return None
    parsed = parse_shape_signature(m.shape)
    if parsed is None:
        return None
    args, sig_kwargs = parsed
    try:
        closed = JU.trace(b.fn, args, {**sig_kwargs, **m.params})
    except Exception:
        return None
    v = verdict(census(closed), chip)
    return v.predicted_s if v.predicted_s > 0 else None


def drift_gate(*, cache_path: Any = None, trace_path: Optional[str] = None,
               pairs: Optional[set] = None,
               band: Optional[float] = None,
               chip: Optional[ChipSpec] = None,
               ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Pass 7: join measurements to predictions and flag outliers.

    The static model's absolute scale is host-dependent (a CPU lane runs
    everything ~1000× slower than the chip peaks predict), so the gate is
    *relative*: the median measured/predicted ratio is the host calibration
    factor, and only a cell whose own ratio exceeds ``band ×`` that median
    fires.  Fewer than :data:`MIN_DRIFT_JOINS` joins → records only, no
    findings (an empty cache keeps the CLI deterministic)."""
    band = float(band) if band is not None else DEFAULT_DRIFT_BAND
    chip = chip if chip is not None else detect_chip()
    measurements = collect_measurements(cache_path, trace_path, pairs)
    joined: List[Tuple[Measurement, float, float]] = []
    records: List[Dict[str, Any]] = []
    for m in measurements:
        p = predict_seconds(m, chip)
        rec = {"kernel": m.kernel, "backend": m.backend, "shape": m.shape,
               "params": {k: repr(v) for k, v in m.params.items()},
               "seconds": m.seconds, "source": m.source,
               "predicted_s": p}
        if p is not None:
            rec["ratio"] = m.seconds / p
            joined.append((m, p, m.seconds / p))
        records.append(rec)
    summary: Dict[str, Any] = {
        "band": band, "chip": chip.name,
        "measurements": len(measurements), "joined": len(joined),
        "min_joins": MIN_DRIFT_JOINS, "calibration": None,
        "records": records,
    }
    if len(joined) < MIN_DRIFT_JOINS:
        return [], summary
    med = statistics.median(r for _, _, r in joined)
    summary["calibration"] = med
    findings: List[Finding] = []
    for m, p, r in joined:
        rel = r / med if med > 0 else float("inf")
        for rec in records:
            if (rec["kernel"], rec["backend"], rec["shape"]) == \
                    (m.kernel, m.backend, m.shape):
                rec["relative"] = rel
        if rel <= band:
            continue
        reason = DRIFT_WAIVERS.get((m.kernel, m.backend))
        findings.append(Finding(
            kernel=m.kernel, backend=m.backend, pass_name="drift",
            code="perf-drift",
            message=(f"measured {m.seconds * 1e3:.3f} ms vs calibrated "
                     f"prediction {p * med * 1e3:.3f} ms — {rel:.1f}× left "
                     f"on the table (band {band:g}×, host calibration "
                     f"{med:.1f}×, source {m.source})"),
            waived=reason is not None, waive_reason=reason,
            detail={"seconds": m.seconds, "predicted_s": p,
                    "calibrated_predicted_s": p * med, "ratio": r,
                    "relative": rel, "band": band, "shape": m.shape,
                    "params": {k: repr(v) for k, v in m.params.items()},
                    "source": m.source}))
    return findings, summary


# --------------------------------------------------------------------------
# the model as a tuning prior
# --------------------------------------------------------------------------
def rank_points(kernel: Any, backend: str, points: Sequence[Dict[str, Any]],
                args: tuple, kwargs: dict,
                chip: Optional[ChipSpec] = None) -> List[Dict[str, Any]]:
    """Cost every tunable point statically and return them sorted by
    predicted seconds (ties keep declaration order — the same determinism
    rule as the exhaustive sweep).  Untraceable points sort last."""
    chip = chip if chip is not None else detect_chip()
    b = kernel.backend(backend)
    costed: List[Dict[str, Any]] = []
    for i, pt in enumerate(points):
        rec: Dict[str, Any] = {"params": dict(pt), "order": i}
        try:
            closed = JU.trace(b.fn, args, {**kwargs, **pt})
            t = census(closed)
            v = verdict(t, chip)
            rec.update(predicted_s=v.predicted_s, bound=v.bound,
                       hbm_bytes=t.hbm_bytes, flops=t.flops,
                       parallelism=max(t.grid_steps, 1.0) * t.shards)
        except Exception as exc:
            rec.update(predicted_s=float("inf"), error=_short(exc),
                       hbm_bytes=float("inf"), parallelism=0.0)
        costed.append(rec)
    return sorted(costed, key=lambda r: (r["predicted_s"], r["order"]))


def prune_dominated(ranked: Sequence[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Drop points strictly worse on traffic AND parallelism than some other
    point — they cannot win on either roofline term, so timing them buys
    nothing.  Points that failed to trace are dropped outright."""
    live = [r for r in ranked if "error" not in r]
    keep = []
    for r in live:
        dominated = any(
            o is not r
            and o["hbm_bytes"] < r["hbm_bytes"]
            and o["parallelism"] > r["parallelism"]
            for o in live)
        if not dominated:
            keep.append(r)
    return keep
