"""Core: the paper's portable-kernel contribution + metrics + roofline."""

from repro.core.portable import (  # noqa: F401
    Backend,
    BackendUnavailableError,
    KernelRegistry,
    PortableKernel,
    TunableSpace,
    get_kernel,
    register_kernel,
    registry,
)
from repro.core.tuning import (  # noqa: F401
    TuningCache,
    TuningKey,
    TuningResult,
    cached_best_params,
    tune,
)
from repro.core.metrics import (  # noqa: F401
    Efficiency,
    babelstream_bandwidth,
    babelstream_bytes,
    hartree_fock_quartets,
    minibude_gflops,
    minibude_ops,
    phi_bar,
    stencil7_effective_bandwidth,
    stencil7_effective_bytes,
)
from repro.core.roofline import (  # noqa: F401
    TPU_V5E,
    ChipSpec,
    RooflineTerms,
    model_flops,
    roofline_from_compiled,
)
from repro.core.hlo_analysis import (  # noqa: F401
    CollectiveStats,
    parse_collective_bytes,
)
