"""Figures of merit from the paper (Eqs. 1-4).

These are the *paper's own* analytic models — they intentionally count the
algorithmically-required bytes/ops, not what the compiler happened to move —
so that the bandwidth/GFLOPs numbers are comparable across implementations
(Mojo vs CUDA/HIP there; pallas vs xla here).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence

import numpy as np

__all__ = [
    "stencil7_effective_bytes",
    "stencil7_effective_bandwidth",
    "babelstream_bytes",
    "babelstream_bandwidth",
    "minibude_ops",
    "minibude_gflops",
    "hartree_fock_quartets",
    "phi_bar",
    "Efficiency",
]


# --------------------------------------------------------------------------
# Eq. 1 — seven-point stencil effective bandwidth
# --------------------------------------------------------------------------
def stencil7_effective_bytes(L: int, itemsize: int) -> float:
    """fetch + write effective bytes for an L^3 grid (paper Eq. 1)."""
    fetch = (L ** 3 - 8 - 12 * (L - 2)) * itemsize
    write = (L - 2) ** 3 * itemsize
    return float(fetch + write)


def stencil7_effective_bandwidth(L: int, itemsize: int,
                                 kernel_time_s: float) -> float:
    """Effective bandwidth in bytes/s (divide by 1e9 for the paper's GB/s)."""
    return stencil7_effective_bytes(L, itemsize) / kernel_time_s


# --------------------------------------------------------------------------
# Eq. 2 — BabelStream per-op bandwidth
# --------------------------------------------------------------------------
_STREAM_ARRAYS = {"copy": 2, "mul": 2, "add": 3, "triad": 3, "dot": 2}


def babelstream_bytes(op: str, vector_size: int, itemsize: int) -> float:
    """bytes moved for one op invocation (paper Eq. 2)."""
    op = op.lower()
    if op not in _STREAM_ARRAYS:
        raise ValueError(f"unknown BabelStream op {op!r}")
    return float(_STREAM_ARRAYS[op] * itemsize * vector_size)


def babelstream_bandwidth(op: str, vector_size: int, itemsize: int,
                          kernel_time_s: float) -> float:
    return babelstream_bytes(op, vector_size, itemsize) / kernel_time_s


# --------------------------------------------------------------------------
# Eq. 3 — miniBUDE GFLOP/s
# --------------------------------------------------------------------------
def minibude_ops(ppwi: int, nligands: int, nproteins: int,
                 nposes: int) -> float:
    """total FLOPs per fasten invocation (paper Eq. 3)."""
    ops_workgroup = (28 * ppwi
                     + nligands * (2 + 18 * ppwi
                                   + nproteins * (10 + 30 * ppwi)))
    return float(ops_workgroup) * (nposes / ppwi)


def minibude_gflops(ppwi: int, nligands: int, nproteins: int, nposes: int,
                    kernel_time_s: float) -> float:
    return minibude_ops(ppwi, nligands, nproteins, nposes) / kernel_time_s / 1e9


# --------------------------------------------------------------------------
# Hartree-Fock — wall-clock is the FoM; quartet count contextualizes it
# --------------------------------------------------------------------------
def hartree_fock_quartets(natoms: int, ngauss: int) -> float:
    """(ij|kl) quartet evaluations in the gather (symmetry-free) formulation."""
    return float(natoms) ** 4 * float(ngauss) ** 4


# --------------------------------------------------------------------------
# Eq. 4 — performance-portability metric  Φ̄
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Efficiency:
    """One e_i(a) term: portable perf relative to the platform baseline."""

    platform: str
    case: str
    portable_perf: float
    baseline_perf: float

    @property
    def e(self) -> float:
        if self.baseline_perf <= 0:
            raise ValueError("baseline perf must be positive")
        return self.portable_perf / self.baseline_perf


def phi_bar(terms: Sequence[Efficiency]) -> float:
    """Arithmetic-mean application efficiency across platforms (paper Eq. 4).

    The paper notes Φ̄ can be misleading when over-performance on one platform
    cancels under-performance on another (their Hartree-Fock case); callers
    should report the per-term e_i alongside, as `benchmarks/portability.py`
    does.
    """
    if not terms:
        raise ValueError("phi_bar needs at least one efficiency term")
    return float(np.mean([t.e for t in terms]))
