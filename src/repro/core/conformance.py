"""Registry-wide differential conformance: every backend vs its oracle.

Godoy et al. (2023) score portability models on *validated* cross-backend
parity, not just speed; this module is the single source of that contract:

  * ``CASES`` gives every registry kernel one small, deterministic input
    (a kernel without a case FAILS conformance — coverage is mandatory);
  * ``oracle_tolerance(kernel, backend)`` says how closely a backend must
    match the kernel's oracle — ``"bitwise"`` where PR 3/4 promised it
    (sharded oracle arithmetic), fp tolerances everywhere else;
  * ``BITWISE_TWIN`` names backends that must reproduce *another backend's*
    output bit-for-bit: a ``shard_pallas`` composite runs the same Pallas
    kernel source sharded, so sharding must not change its output at all
    (checked against ``pallas_interpret`` whenever the composite actually
    runs in interpret mode);
  * ``conformance_pairs()`` derives the (kernel, backend) matrix from the
    live registry — never a hand-written list — so every future backend is
    covered the moment it registers;
  * ``check_backend(kernel, backend)`` runs one cell of that matrix,
    raising ``BackendUnavailableError`` (an explicit, reasoned skip for the
    caller) when either side cannot run on this host, and
    ``AssertionError`` on any mismatch.

``tests/test_backend_conformance.py`` parametrizes over the matrix on any
host (multi-device backends skip on a 1-device pytest process);
``repro.distributed.selftest`` walks the same matrix under 8 forced host
devices, so the sharded backends get identical coverage there.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Tuple, Union

import numpy as np

import repro.kernels  # noqa: F401  (registers every backend)
from repro.core.portable import registry

Tolerance = Union[str, Tuple[float, float]]  # "bitwise" | (rtol, atol)


# --------------------------------------------------------------------------
# cases: one small deterministic input per registry kernel.  Sizes satisfy
# every backend's *default* tile constraints (nx=128 lanes, ny % 64, pose /
# block-row multiples) and divide by the 2/4/8 shard grids.
# --------------------------------------------------------------------------
def _f32(a):
    import jax.numpy as jnp
    return jnp.asarray(a, jnp.float32)


def _stencil_case():
    u = np.random.default_rng(0).standard_normal((8, 64, 128))
    return (_f32(u),), {}


def _stream_case(nargs):
    r = np.random.default_rng(1)
    n = 1 << 17
    return tuple(_f32(r.standard_normal(n)) for _ in range(nargs)), {}


def _minibude_case():
    from repro.kernels.minibude import ops as mb_ops
    return mb_ops.make_deck(natpro=16, natlig=4, nposes=512, seed=0), {}


def _hf_case():
    from repro.kernels.hartree_fock import ref as hf_ref
    return (hf_ref.helium_lattice(8), hf_ref.initial_density(8)), {}


def _flash_case():
    r = np.random.default_rng(2)
    b, h, s, dh = 1, 2, 128, 64
    return tuple(_f32(r.standard_normal((b, h, s, dh)) * 0.5)
                 for _ in range(3)), {}


def _decode_case():
    """Single-query decode against a ring-buffer cache that exercises both
    hard edges at once: row 0 has wrapped (slot order != position order),
    row 1 has empty slots (pos -1 holes)."""
    import jax.numpy as jnp
    r = np.random.default_rng(4)
    b, h, kv, t, dh = 2, 4, 2, 128, 64
    q = _f32(r.standard_normal((b, 1, h, dh)) * 0.5)
    k = _f32(r.standard_normal((b, t, kv, dh)) * 0.5)
    v = _f32(r.standard_normal((b, t, kv, dh)) * 0.5)
    pos = np.tile(np.arange(t, dtype=np.int32), (b, 1))
    pos[0, :5] += t                 # row 0: ring wrapped at slots 0..4
    pos[1, 41:] = -1                # row 1: cache only 41/128 full
    q_pos = np.asarray([[t + 5], [41]], np.int32)
    return (q, k, v, jnp.asarray(q_pos), jnp.asarray(pos)), {}


def _wkv_case():
    import jax.numpy as jnp
    r = np.random.default_rng(3)
    b, h, s, dh = 1, 2, 64, 32
    rr, kk, vv = (_f32(r.standard_normal((b, h, s, dh)) * 0.5)
                  for _ in range(3))
    lw = -jnp.exp(jnp.clip(_f32(r.standard_normal((b, h, s, dh))), -8, 1))
    u = _f32(r.standard_normal((h, dh)) * 0.5)
    return (rr, kk, vv, lw, u), {}


def _serving_case():
    """The serving engine's end-to-end token-stream case (see
    ``repro.serving.portable``): args are (params, cfg); every engine
    backend rebuilds its own deterministic trace internally."""
    from repro.serving import portable as serving_portable
    return serving_portable.case_args(), {}


CASES: Dict[str, Callable[[], Tuple[tuple, dict]]] = {
    "stencil7": _stencil_case,
    "babelstream.copy": lambda: _stream_case(1),
    "babelstream.mul": lambda: _stream_case(1),
    "babelstream.add": lambda: _stream_case(2),
    "babelstream.triad": lambda: _stream_case(2),
    "babelstream.dot": lambda: _stream_case(2),
    "minibude.fasten": _minibude_case,
    "hartree_fock.twoel": _hf_case,
    "attention.flash": _flash_case,
    "attention.decode": _decode_case,
    "rwkv6.wkv": _wkv_case,
    "serving.engine": _serving_case,
}

#: per-kernel default tolerance vs the oracle (from the families' own
#: validation suites)
ORACLE_TOL: Dict[str, Tolerance] = {
    "stencil7": (1e-5, 1e-5),
    "babelstream.copy": (1e-6, 1e-6),
    "babelstream.mul": (1e-6, 1e-6),
    "babelstream.add": (1e-6, 1e-6),
    "babelstream.triad": (1e-6, 1e-6),
    "babelstream.dot": (1e-4, 1e-3),
    "minibude.fasten": (2e-4, 2e-3),
    "hartree_fock.twoel": (1e-4, 1e-4),
    "attention.flash": (2e-4, 2e-4),
    "attention.decode": (2e-4, 2e-4),
    "rwkv6.wkv": (3e-4, 3e-4),
    # continuous batching, cache layout (contiguous vs paged), and driver
    # threading are scheduling concerns — they may never change a token
    "serving.engine": "bitwise",
}

#: (kernel, backend) overrides — bitwise where PR 3/4 promised it: the
#: sharded-oracle backends apply the unchanged oracle arithmetic
BACKEND_TOL: Dict[Tuple[str, str], Tolerance] = {
    ("stencil7", "xla_shard"): "bitwise",
    ("babelstream.copy", "xla_shard"): "bitwise",
    ("babelstream.mul", "xla_shard"): "bitwise",
    ("babelstream.add", "xla_shard"): "bitwise",
    ("babelstream.triad", "xla_shard"): "bitwise",
    ("minibude.fasten", "xla_shard"): "bitwise",
    # the attention xla backends ARE the serving engine's historical
    # plain-XLA `attend` path (PR 6): registering them as oracles is the
    # contract that dispatch's default route stays bitwise-identical
    ("attention.flash", "xla"): "bitwise",
    ("attention.decode", "xla"): "bitwise",
}

#: backend -> backend whose output it must reproduce *bitwise* (the
#: composite runs the same kernel source — sharding must not change it).
#: dot and hartree_fock are excluded: psum changes their summation order.
BITWISE_TWIN: Dict[Tuple[str, str], str] = {
    ("stencil7", "shard_pallas"): "pallas_interpret",
    ("babelstream.copy", "shard_pallas"): "pallas_interpret",
    ("babelstream.mul", "shard_pallas"): "pallas_interpret",
    ("babelstream.add", "shard_pallas"): "pallas_interpret",
    ("babelstream.triad", "shard_pallas"): "pallas_interpret",
    ("minibude.fasten", "shard_pallas"): "pallas_interpret",
}


def oracle_tolerance(kernel: str, backend: str) -> Tolerance:
    return BACKEND_TOL.get((kernel, backend), ORACLE_TOL.get(kernel))


def conformance_pairs() -> List[Tuple[str, str]]:
    """Every (kernel, backend) cell of the live registry, sorted.  Derived,
    never hand-written: a backend registered tomorrow appears here today."""
    return [(name, b) for name in registry.names()
            for b in sorted(registry.get(name).backends)]


def _assert_match(kernel: str, backend: str, against: str, want: Any,
                  got: Any, tol: Tolerance) -> None:
    import jax

    def one(w, g):
        w, g = np.asarray(w), np.asarray(g)
        if tol == "bitwise":
            if not np.array_equal(w, g):
                bad = int(np.sum(w != g))
                raise AssertionError(
                    f"{kernel}[{backend}] is not bitwise equal to "
                    f"{against} ({bad}/{w.size} cells differ)")
        else:
            rtol, atol = tol
            np.testing.assert_allclose(
                g.astype(np.float64), w.astype(np.float64), rtol=rtol,
                atol=atol,
                err_msg=f"{kernel}[{backend}] vs {against}")

    jax.tree.map(one, want, got)


@functools.lru_cache(maxsize=None)
def _case_and_oracle(kernel: str):
    """Deterministic case inputs + oracle output, computed once per kernel
    (the matrix walk compares many backends against the same oracle cell).
    Exceptions — including ``BackendUnavailableError`` from an oracle that
    cannot run here — are not cached and re-raise per call."""
    k = registry.get(kernel)
    args, kwargs = CASES[kernel]()
    want = k._require_available(k.oracle)(*args, **kwargs)
    return args, kwargs, want


def check_backend(kernel: str, backend: str) -> None:
    """Run one conformance cell: ``backend`` vs the kernel's oracle (and
    its bitwise twin, when one is declared and running the same mode).

    Raises ``KeyError`` for an unregistered kernel/backend,
    ``AssertionError`` for a missing case or a mismatch, and
    ``BackendUnavailableError`` when this host cannot run the pair — the
    caller turns that into an explicit, reasoned skip.
    """
    k = registry.get(kernel)
    case = CASES.get(kernel)
    if case is None:
        raise AssertionError(
            f"kernel {kernel!r} has no conformance case — every registered "
            f"kernel must add one to repro.core.conformance.CASES")
    tol = oracle_tolerance(kernel, backend)
    if tol is None:
        raise AssertionError(
            f"kernel {kernel!r} has no conformance tolerance — add it to "
            f"repro.core.conformance.ORACLE_TOL")
    args, kwargs, want = _case_and_oracle(kernel)
    got = k._require_available(backend)(*args, **kwargs)
    _assert_match(kernel, backend, k.oracle, want, got, tol)

    twin = BITWISE_TWIN.get((kernel, backend))
    if twin is None:
        return
    from repro.distributed.shard_pallas import default_interpret
    tb = k.backends.get(twin)
    # the twin claim only binds when the composite actually runs the
    # interpret path the twin runs (on TPU it runs the compiled kernel)
    if tb is not None and tb.is_available() and default_interpret():
        ref = tb(*args, **kwargs)
        _assert_match(kernel, backend, twin, ref, got, "bitwise")
