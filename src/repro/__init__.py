"""repro: performance-portable kernels + multi-pod LM framework (JAX/Pallas).

Reproduction of "Mojo: MLIR-Based Performance-Portable HPC Science Kernels
on GPUs for the Python Ecosystem" (SC-W'25), adapted to TPU.  See DESIGN.md.
"""

__version__ = "0.1.0"
