"""Error-feedback int8 gradient compression for slow (cross-pod) links.

Classic EF-SGD scheme: quantize (grad + residual) to int8 with a per-tensor
scale, all-reduce the int8 payload's dequantized value (under GSPMD the
quantize happens before the pod-axis reduction so the wire format is 1/4 the
bytes), and carry the quantization error forward.  Off by default; enabled
via TrainConfig.compress_pod_grads.  Property-tested: with error feedback the
*accumulated* applied update converges to the true gradient sum.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Returns (compressed-dequantized grads, new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
