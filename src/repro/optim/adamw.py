"""AdamW with fp32 moments, decoupled weight decay and global-norm clipping.

Self-contained (no optax dependency).  State layout keeps every moment tensor
shaped like its parameter so the sharding policy applies uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    lr_min_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # fp32, param-shaped
    nu: Any          # fp32, param-shaped


def init_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        update = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_mu, nu=new_nu), metrics
