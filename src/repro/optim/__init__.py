"""optim subsystem."""
