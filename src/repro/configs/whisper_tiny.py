"""whisper-tiny [audio]: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865
— enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

The conv frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings (B, frames, 384)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865, head_dim=64, norm="layernorm", mlp="gelu",
    use_rope=False, is_encoder_decoder=True, n_encoder_layers=4,
    encoder_frames=1500, frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, norm="layernorm", mlp="gelu",
    use_rope=False, is_encoder_decoder=True, n_encoder_layers=2,
    encoder_frames=24, frontend="audio_stub",
)
