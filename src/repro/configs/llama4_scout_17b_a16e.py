"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 — 16 routed top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Early-fusion multimodality is the same token-level stub as pixtral
(input_specs supplies patch embeddings)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, norm="rmsnorm", mlp="swiglu",
    n_experts=16, n_shared_experts=1, top_k=1,
    frontend="vision_stub", n_patches=256,
)

SMOKE = ModelConfig(
    name="llama4-scout-17b-a16e-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=256, head_dim=16, norm="rmsnorm", mlp="swiglu",
    n_experts=4, n_shared_experts=1, top_k=1,
    frontend="vision_stub", n_patches=8,
    moe_capacity_factor=8.0,
)
