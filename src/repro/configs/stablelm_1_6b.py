"""stablelm-1.6b [dense]: 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64, norm="layernorm", mlp="swiglu",
)

SMOKE = ModelConfig(
    name="stablelm-1.6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, norm="layernorm", mlp="swiglu",
)
