"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
— Finch — data-dependent decay [arXiv:2404.05892; hf].

Attention-free; O(1)-state decode => runs long_500k."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=8960,
    vocab_size=65536, head_dim=64, norm="layernorm", mlp="swiglu",
    rwkv=True, use_rope=False,
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=0, n_kv_heads=0, d_ff=448,
    vocab_size=256, head_dim=64, norm="layernorm", mlp="swiglu",
    rwkv=True, use_rope=False,
)
