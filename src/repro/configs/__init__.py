"""Architecture registry: ``--arch <id>`` -> ModelConfig."""

from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                                TRAIN_4K, ModelConfig, ShapeConfig)
from repro.configs import (deepseek_67b, deepseek_moe_16b, granite_3_8b,
                           hymba_1_5b, llama4_scout_17b_a16e, pixtral_12b,
                           rwkv6_3b, stablelm_1_6b, starcoder2_3b,
                           whisper_tiny)

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "stablelm-1.6b": stablelm_1_6b,
    "starcoder2-3b": starcoder2_3b,
    "deepseek-67b": deepseek_67b,
    "whisper-tiny": whisper_tiny,
    "pixtral-12b": pixtral_12b,
    "hymba-1.5b": hymba_1_5b,
    "rwkv6-3b": rwkv6_3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, else the recorded skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch; long_500k is run "
                       "only for sub-quadratic archs (DESIGN.md §5)")
    return True, ""


__all__ = ["ARCH_IDS", "get_config", "cell_applicable", "SHAPES",
           "ModelConfig", "ShapeConfig", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K"]
