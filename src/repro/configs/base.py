"""Model/shape configuration schema for all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact assigned dims live in configs/<id>.py)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 => attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0               # routed experts (0 => dense MLP)
    n_shared_experts: int = 0
    top_k: int = 0
    dense_prefix_layers: int = 0     # deepseek-moe: first layer(s) dense
    moe_capacity_factor: float = 1.25
    # d_ff above is the per-expert hidden size for MoE archs; dense prefix
    # layers use d_ff * (top_k + n_shared) as their hidden (deepseek layout).

    # --- hybrid / ssm ---
    ssm_state: int = 0               # mamba state per channel (hymba)
    window: int = 0                  # sliding-window size; 0 = full attention
    global_layers: Tuple[int, ...] = ()   # hymba full-attention layer ids
    rwkv: bool = False

    # --- encoder-decoder / multimodal frontends (stubs per assignment) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_frames: int = 1500       # whisper stub encoder length
    frontend: str = "none"           # none | audio_stub | vision_stub
    n_patches: int = 0               # vlm stub patch count per sample

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # embedding tables are padded to this multiple (standard production
    # practice: keeps the vocab dim shardable for every mesh; padded logits
    # are masked to -inf before the loss)
    vocab_pad_multiple: int = 256

    # --- beyond-paper performance levers (False => paper-faithful baseline;
    #     see EXPERIMENTS.md §Perf for the measured effect of each) ---
    # keep chunked-attention logits/probabilities in bf16 (f32 accumulate):
    # halves the attention HBM traffic that dominates the memory term
    attn_bf16_intermediates: bool = False
    # ZeRO-1-style compute weights: cast the fp32 FSDP-sharded master params
    # to bf16 ONCE per step and materialize them TP-sharded-only, instead of
    # re-all-gathering fp32 weights per layer x microbatch x fwd/bwd pass
    zero1_weights: bool = False
    # stop-gradient through the MoE dispatch/position one-hots (exact: they
    # are piecewise-constant a.e.; router gradients flow via the combine
    # gates) — kills the (G,gs,E,C) fp32 cotangent tensors and their
    # all-reduces that dominate MoE training's collective term
    moe_stopgrad_dispatch: bool = False
    # norm elementwise path in bf16 (reductions stay fp32): halves norm
    # traffic AND stops XLA sinking TP all-reduces past the fp32 upcast
    norm_bf16_mul: bool = False

    # --- serving attention dispatch ---
    # registry backend for self-attention ("xla" | "pallas" |
    # "pallas_interpret"); None = status-quo plain-XLA path.  The
    # REPRO_ATTN_BACKEND env var overrides this at trace time, and
    # unavailable Pallas backends fall back to XLA (see models/attention).
    attn_backend: Optional[str] = None

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-SWA / linear attention)."""
        return self.rwkv or (self.window > 0)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def dense_ff(self) -> int:
        """Hidden size of dense (prefix) MLP layers for MoE archs."""
        if not self.is_moe:
            return self.d_ff
        return self.d_ff * (self.top_k + max(self.n_shared_experts, 1))

    def active_params(self) -> float:
        """Approximate active parameter count (for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> float:
        return _param_count(self, active_only=False)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def _param_count(cfg: ModelConfig, active_only: bool) -> float:
    d, L = cfg.d_model, cfg.n_layers
    n = 0.0
    # embeddings (+ unembed)
    n += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.rwkv:
        # time-mix: r,k,v,g,o projections ~5 d^2 + decay lora; channel mix ~3*d*dff
        per_layer = 5 * d * d + 3 * d * cfg.d_ff + 2 * d * 96
    else:
        hd = cfg.head_dim
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d
        if cfg.is_moe:
            e_active = (cfg.top_k + cfg.n_shared_experts) if active_only \
                else (cfg.n_experts + cfg.n_shared_experts)
            mult = 3 if cfg.mlp == "swiglu" else 2
            mlp = e_active * mult * d * cfg.d_ff + d * cfg.n_experts
        else:
            mult = 3 if cfg.mlp == "swiglu" else 2
            mlp = mult * d * cfg.d_ff
        per_layer = attn + mlp
        if cfg.ssm_state:  # hymba parallel ssm head
            d_in = cfg.n_heads * hd
            per_layer += d * d_in + d_in * (2 * cfg.ssm_state + 2) + d_in * d
    n += L * per_layer
    if cfg.is_encoder_decoder:
        # encoder layers + cross attention in decoder
        enc = cfg.n_encoder_layers * per_layer
        cross = L * (2 * d * cfg.n_kv_heads * cfg.head_dim
                     + 2 * d * cfg.n_heads * cfg.head_dim)
        n += enc + cross
    return n
