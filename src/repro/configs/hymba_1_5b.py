"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676; hf].

Per the paper: three global-attention layers (first / middle / last), the
rest sliding-window (w=1024); every layer fuses the attention branch with a
parallel Mamba branch (mean of the normalized branch outputs).  Sub-quadratic
=> runs long_500k."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64, norm="rmsnorm", mlp="swiglu",
    ssm_state=16, window=1024, global_layers=(0, 15, 31),
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, norm="rmsnorm", mlp="swiglu",
    ssm_state=4, window=16, global_layers=(0, 2),
)
