"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed top-6, fine-grained,
first layer dense [arXiv:2401.06066; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, head_dim=128, norm="rmsnorm", mlp="swiglu",
    n_experts=64, n_shared_experts=2, top_k=6, dense_prefix_layers=1,
)

# smoke: high capacity factor => dropless routing, so decode == prefill
# exactly (capacity-drop behaviour is covered by dedicated MoE tests)
SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=256, head_dim=16, norm="rmsnorm", mlp="swiglu",
    n_experts=8, n_shared_experts=2, top_k=2, dense_prefix_layers=1,
    moe_capacity_factor=8.0,
)
