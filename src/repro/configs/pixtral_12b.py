"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo [hf:mistralai/Pixtral-12B-2409;
unverified].

The vision frontend is a STUB per the assignment: `input_specs()` supplies
precomputed patch embeddings (B, patches, 5120) fused at the sequence head."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128, norm="rmsnorm", mlp="swiglu",
    frontend="vision_stub", n_patches=256,
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, norm="rmsnorm", mlp="swiglu",
    frontend="vision_stub", n_patches=8,
)
