"""launch subsystem."""
