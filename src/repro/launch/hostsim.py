"""Simulated multi-device host topology via XLA_FLAGS — jax-import-safe.

``--xla_force_host_platform_device_count=N`` makes the CPU backend expose N
devices, which is how ``launch/dryrun.py`` compiles 512-chip meshes and how
the distributed subsystem (``repro.distributed``) and the scaling benchmark
run multi-device on a laptop.  The flag is only read at jax *backend init*,
so it must land in ``os.environ`` before the first device query — this
module therefore never imports jax.

Two contracts, both preserving every other flag the user set:

  * ``ensure_host_device_count(n)`` mutates ``os.environ`` in place for the
    *current* process (call before importing jax).  An existing
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS`` is
    respected, never overwritten — the user's explicit topology wins.
  * ``merged_xla_flags(n, env)`` is the pure variant: returns the merged
    flag string without touching anything (for ``subprocess`` env dicts).
"""

from __future__ import annotations

import os
from typing import Mapping, MutableMapping, Optional

__all__ = ["DEVICE_COUNT_FLAG", "merged_xla_flags",
           "ensure_host_device_count"]

DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def merged_xla_flags(n: int, env: Optional[Mapping[str, str]] = None) -> str:
    """The XLA_FLAGS value that forces ``n`` host devices while keeping every
    flag already present in ``env``.  If the device-count flag is already
    set, the existing value is respected (returned unchanged)."""
    if env is None:
        env = os.environ
    flags = env.get("XLA_FLAGS", "")
    if DEVICE_COUNT_FLAG in flags:
        return flags
    return (flags + " " if flags else "") + f"{DEVICE_COUNT_FLAG}={n}"


def ensure_host_device_count(
        n: int, env: Optional[MutableMapping[str, str]] = None) -> str:
    """Append the device-count flag to ``env['XLA_FLAGS']`` (default
    ``os.environ``) unless one is already present; returns the final value.
    Must run before jax initializes its backends."""
    if env is None:
        env = os.environ
    flags = merged_xla_flags(n, env)
    env["XLA_FLAGS"] = flags
    return flags
