"""ShapeDtypeStruct input specs for every (arch x shape) cell.

Everything here is allocation-free: full-size models exist only as abstract
shapes (the smoke tests instantiate reduced configs instead).  Modality
frontends are stubs per the assignment: `frames` / `patches` are precomputed
embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_caches, init_params
from repro.training.train_step import TrainConfig, make_train_state


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def modality_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        out["frames"] = sds((batch, cfg.encoder_frames, cfg.d_model),
                            cfg.compute_dtype)
    if cfg.frontend == "vision_stub":
        out["patches"] = sds((batch, cfg.n_patches, cfg.d_model),
                             cfg.compute_dtype)
    return out


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32),
             "targets": sds((b, s), jnp.int32),
             "mask": sds((b, s), jnp.float32)}
    batch.update(modality_specs(cfg, b))
    return batch


def train_state_specs(cfg: ModelConfig, tcfg: TrainConfig):
    def build():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return make_train_state(params, tcfg)
    return jax.eval_shape(build)


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, cache_len))


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    return {"tokens": sds((b, s), jnp.int32), **modality_specs(cfg, b)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """One-new-token serve step with a KV cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": sds((b, 1), jnp.int32),
             "positions": sds((b, 1), jnp.int32),
             "caches": cache_specs(cfg, b, s)}
    if cfg.is_encoder_decoder:
        specs["memory"] = sds((b, cfg.encoder_frames, cfg.d_model),
                              cfg.compute_dtype)
    return specs
