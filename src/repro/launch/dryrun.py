import os

from repro.launch.hostsim import ensure_host_device_count

# append to (never clobber) any user-set XLA_FLAGS; an explicit
# --xla_force_host_platform_device_count from the user is respected
ensure_host_device_count(512)

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * `.lower(**ShapeDtypeStructs).compile()` must succeed for the 16x16
    single-pod mesh AND the 2x16x16 multi-pod mesh for every applicable cell;
  * `compiled.memory_analysis()` proves the per-chip working set fits HBM;
  * `compiled.cost_analysis()` + HLO collective parsing feed the roofline
    table (EXPERIMENTS.md §Roofline).

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json.
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.roofline import TPU_V5E, model_flops, roofline_from_compiled
from repro.distributed.sharding import ShardingPolicy
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import forward
from repro.training.serve_step import decode_step
from repro.training.train_step import TrainConfig, train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def tcfg_for(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> TrainConfig:
    """Microbatching heuristic: bound live activations to ~1 row/chip for the
    widest models, 2 rows otherwise (see DESIGN.md §8)."""
    b = shape.global_batch
    # widest models, MoE (dispatch/combine tensors) and SSM-hybrid
    # (associative-scan intermediates, (B,S,Di,N) fp32) get 1 row/chip
    rows_per_chip = 1 if (cfg.d_model >= 8192 or cfg.is_moe
                          or cfg.ssm_state > 0) else 2
    micro = max(dp * rows_per_chip, 1)
    microbatches = max(1, b // micro) if b % micro == 0 else 1
    while b % microbatches:
        microbatches //= 2
    return TrainConfig(microbatches=max(microbatches, 1), remat=True)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, policy):
    """Returns (fn, kwargs_specs, in_shardings, donate, n_step_tokens)."""
    hints = policy.hints()
    if shape.kind == "train":
        tcfg = tcfg_for(cfg, shape, policy.dp_size)
        state = S.train_state_specs(cfg, tcfg)
        batch = S.train_batch_specs(cfg, shape)
        fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg, hints=hints)
        in_sh = (policy.tree_shardings(state), policy.batch_shardings(batch))
        out_sh = (policy.tree_shardings(state), None)
        args = (state, batch)
        return fn, args, in_sh, out_sh, (0,), shape.global_batch * shape.seq_len

    if shape.kind == "prefill":
        inp = S.prefill_input_specs(cfg, shape)
        params = S.params_specs(cfg)

        def fn(params_, tokens, frames=None, patches=None):
            logits, _, _ = forward(params_, cfg, tokens, frames=frames,
                                   patches=patches, hints=hints,
                                   last_only=True)
            return logits[:, -1]

        in_sh = (policy.tree_shardings(params),
                 *(policy.batch_shardings(inp[k]) for k in inp))
        args = (params, *inp.values())
        return fn, args, in_sh, None, (), shape.global_batch * shape.seq_len

    # decode
    inp = S.decode_input_specs(cfg, shape)
    params = S.params_specs(cfg)

    def fn(params_, tokens, positions, caches, memory=None):
        return decode_step(params_, cfg, tokens, positions, caches,
                           memory=memory, hints=hints)

    cache_sh = policy.cache_shardings(inp["caches"])
    in_sh = [policy.tree_shardings(params),
             policy.batch_shardings(inp["tokens"]),
             policy.batch_shardings(inp["positions"]),
             cache_sh]
    args = [params, inp["tokens"], inp["positions"], inp["caches"]]
    if "memory" in inp:
        in_sh.append(policy.batch_shardings(inp["memory"]))
        args.append(inp["memory"])
    out_sh = (None, cache_sh)
    return fn, tuple(args), tuple(in_sh), out_sh, (3,), shape.global_batch


VARIANTS = {
    # cfg overrides; the special "_kernel_adjusted" key switches the
    # roofline analysis to cost Pallas-kernel-resident tiles at zero HBM
    "baseline": {},
    "attn_bf16": {"attn_bf16_intermediates": True},
    "zero1": {"zero1_weights": True},
    "stopgrad": {"moe_stopgrad_dispatch": True},
    "bf16_norm": {"norm_bf16_mul": True},
    "flash": {"_kernel_adjusted": True},
    "opt": {"attn_bf16_intermediates": True, "zero1_weights": True,
            "moe_stopgrad_dispatch": True, "norm_bf16_mul": True,
            "_kernel_adjusted": True},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, variant: str = "baseline") -> Dict[str, Any]:
    cfg = get_config(arch)
    overrides = dict(VARIANTS.get(variant, {}))
    kernel_adjusted = overrides.pop("_kernel_adjusted", False)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    ok, reason = cell_applicable(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "variant": variant,
        "kind": shape.kind, "status": "skipped", "reason": reason,
    }
    if not ok:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape_name}.json"),
                  "w") as f:
            json.dump(record, f, indent=2)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    policy = ShardingPolicy(mesh, cfg)
    t0 = time.time()
    fn, args, in_sh, out_sh, donate, tokens = build_cell(cfg, shape, mesh,
                                                         policy)
    jit_kwargs: Dict[str, Any] = {"in_shardings": in_sh}
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    if donate:
        jit_kwargs["donate_argnums"] = donate
    with mesh:
        lowered = jax.jit(fn, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    terms = roofline_from_compiled(compiled, TPU_V5E, hlo_text=hlo,
                                   kernel_adjusted=kernel_adjusted)
    mem = compiled.memory_analysis()

    kind = "train" if shape.kind == "train" else "serve"
    mflops = model_flops(cfg.active_params(), tokens,
                         "train" if kind == "train" else "serve")
    # cost_analysis is per-partition under SPMD: scale up for the ratio
    useful_ratio = mflops / (terms.flops * n_chips) if terms.flops else 0.0

    record.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_chip": {
            "flops": terms.flops,
            "hbm_bytes": terms.hbm_bytes,
            "collective_bytes": terms.collective_bytes,
            "argument_bytes": terms.argument_bytes,
            "output_bytes": terms.output_bytes,
            "temp_bytes": terms.temp_bytes,
            "peak_bytes": terms.peak_bytes,
            "xla_flops_flat": terms.xla_flops,
            "xla_bytes_flat": terms.xla_bytes,
            "unknown_trip_loops": terms.unknown_trip_loops,
        },
        "roofline_s": {
            "compute": terms.compute_s,
            "memory": terms.memory_s,
            "collective": terms.collective_s,
        },
        "dominant": terms.dominant,
        "bound_s": terms.bound_s,
        "collectives": terms.collectives,
        "model_flops_total": mflops,
        "useful_flops_ratio": useful_ratio,
        "tokens_per_step": tokens,
        "fits_hbm": terms.peak_bytes <= TPU_V5E.hbm_bytes,
    })
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()
    if args.variant != "baseline":
        args.out = args.out.rstrip("/") + f"_{args.variant}"

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        tag = "multipod_2x16x16" if multi else "pod_16x16"
        out_dir = os.path.join(args.out, tag)
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi, out_dir,
                                   args.variant)
                except Exception as e:  # a failing cell is a bug: surface it
                    traceback.print_exc()
                    failures.append((tag, arch, shape, repr(e)))
                    print(f"FAIL  {tag:18s} {arch:24s} {shape:12s} {e!r}",
                          flush=True)
                    continue
                if rec["status"] == "skipped":
                    print(f"SKIP  {tag:18s} {arch:24s} {shape:12s} "
                          f"{rec['reason'][:60]}", flush=True)
                else:
                    pb = rec["per_chip"]["peak_bytes"] / 2 ** 30
                    print(f"OK    {tag:18s} {arch:24s} {shape:12s} "
                          f"dom={rec['dominant']:10s} "
                          f"bound={rec['bound_s']*1e3:8.2f}ms "
                          f"peak={pb:6.2f}GiB "
                          f"compile={rec['compile_s']:6.1f}s", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
