"""Production mesh construction (assignment-specified topology).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
    # newer jax; older versions treat every axis as Auto already.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this process actually has (CPU tests, examples)."""
    n = len(jax.devices())
    model = model if n % model == 0 else 1
    return _make_mesh((n // model, model), ("data", "model"))
