"""Serving-path tests: prefill/decode consistency + generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.training import serve_step as SS


@pytest.mark.parametrize(
    "arch", ["granite-3-8b", "starcoder2-3b"]
    + [pytest.param(a, marks=pytest.mark.slow)
       for a in ("rwkv6-3b", "hymba-1.5b", "deepseek-moe-16b",
                 "whisper-tiny")])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["memory"] = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model), jnp.float32) * 0.1
    full_logits, _, _ = T.forward(params, cfg, toks, **kw)
    caches = T.init_caches(cfg, B, 32)
    outs = []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches, _ = T.forward(params, cfg, toks[:, t:t + 1],
                                  positions=pos, caches=caches, **kw)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    err = float(jnp.max(jnp.abs(dec - full_logits.astype(jnp.float32))))
    assert err < 0.15, (arch, err)


def test_generate_greedy_deterministic():
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out1 = SS.generate(params, cfg, prompt, max_new_tokens=5, cache_len=32)
    out2 = SS.generate(params, cfg, prompt, max_new_tokens=5, cache_len=32)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 5)
    assert (np.asarray(out1) >= 0).all()
    assert (np.asarray(out1) < cfg.vocab_size).all()


def test_prefill_then_decode_continues():
    cfg = get_config("stablelm-1.6b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    last, caches, memory = SS.prefill(params, cfg, prompt, cache_len=32)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    logits, caches = SS.decode_step(params, cfg, tok, pos, caches,
                                    memory=memory)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
