"""Integration: the dry-run machinery on the host topology (1 device).

The full 512-device matrix runs via `python -m repro.launch.dryrun` (it must
set XLA_FLAGS before jax init, which pytest cannot); here we exercise the
same build/lower/compile/analyze path on the host mesh with reduced configs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_applicable, get_config
from repro.configs.base import ShapeConfig
from repro.core.roofline import TPU_V5E, roofline_from_compiled
from repro.distributed.sharding import ShardingPolicy
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_host_mesh


SMALL_TRAIN = ShapeConfig("train_small", 64, 4, "train")
SMALL_DECODE = ShapeConfig("decode_small", 64, 4, "decode")


@pytest.mark.parametrize(
    "arch", ["stablelm-1.6b",
             pytest.param("rwkv6-3b", marks=pytest.mark.slow),
             pytest.param("deepseek-moe-16b", marks=pytest.mark.slow)])
@pytest.mark.parametrize("shape", [SMALL_TRAIN, SMALL_DECODE])
def test_cell_lowers_compiles_and_analyzes(arch, shape):
    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, cfg)
    fn, args, in_sh, out_sh, donate, tokens = build_cell(cfg, shape, mesh,
                                                         policy)
    kwargs = {"in_shardings": in_sh}
    if out_sh is not None:
        kwargs["out_shardings"] = out_sh
    if donate:
        kwargs["donate_argnums"] = donate
    with mesh:
        compiled = jax.jit(fn, **kwargs).lower(*args).compile()
    terms = roofline_from_compiled(compiled, TPU_V5E)
    assert terms.flops > 0
    assert terms.hbm_bytes > 0
    assert terms.peak_bytes > 0
    assert terms.dominant in ("compute", "memory", "collective")
    assert terms.unknown_trip_loops == 0


def test_long_500k_skips_full_attention():
    ok, reason = cell_applicable(get_config("granite-3-8b"),
                                 SHAPES["long_500k"])
    assert not ok and "full-attention" in reason
    ok, _ = cell_applicable(get_config("rwkv6-3b"), SHAPES["long_500k"])
    assert ok
    ok, _ = cell_applicable(get_config("hymba-1.5b"), SHAPES["long_500k"])
    assert ok


def test_trip_count_aware_vs_flat_flops():
    """The roofline's trip-count-aware FLOPs must exceed XLA's flat count
    for a scanned model (the whole point of core/hlo_cost.py)."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, cfg)
    fn, args, in_sh, out_sh, donate, _ = build_cell(cfg, SMALL_TRAIN, mesh,
                                                    policy)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    terms = roofline_from_compiled(compiled, TPU_V5E)
    assert terms.flops > terms.xla_flops * 1.5
