"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting shapes + finite outputs (assignment req)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.training.train_step import TrainConfig, make_train_state, train_step
from repro.optim.adamw import AdamWConfig


def _batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.encoder_frames, cfg.d_model), jnp.float32) * 0.1
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            ks[3], (b, cfg.n_patches, cfg.d_model), jnp.float32) * 0.1
    return batch


# heavy smoke archs (recurrent scans / MoE dispatch / encoder stacks) run in
# the slow lane; tier-1 keeps one representative per family
_HEAVY = {"hymba-1.5b", "rwkv6-3b", "whisper-tiny", "deepseek-moe-16b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
               for a in ARCH_IDS]


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, aux = T.forward(params, cfg, batch["tokens"],
                               frames=batch.get("frames"),
                               patches=batch.get("patches"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    tcfg = TrainConfig(microbatches=2, remat=True,
                       opt=AdamWConfig(warmup_steps=2, decay_steps=10))
    state = make_train_state(params, tcfg)
    batch = _batch(cfg, key, b=4, s=32)
    state, metrics = jax.jit(
        lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize(
    "arch", ["granite-3-8b"] + [pytest.param(a, marks=pytest.mark.slow)
                                for a in ("rwkv6-3b", "hymba-1.5b",
                                          "whisper-tiny",
                                          "deepseek-moe-16b")])
def test_loss_decreases_overfit(arch):
    """A few steps on one repeated batch must reduce the loss."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    tcfg = TrainConfig(
        microbatches=1, remat=False,
        opt=AdamWConfig(lr_peak=3e-3, warmup_steps=1, decay_steps=100,
                        weight_decay=0.0))
    state = make_train_state(params, tcfg)
    batch = _batch(cfg, key, b=2, s=16)
    step = jax.jit(lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (arch, losses)
