"""Static kernel auditor: registry matrix + planted-defect fixtures.

Two kinds of coverage:

  * the *clean* direction — the live registry's cells audit without
    findings.  Tier-1 parametrizes ``analysis.audit_pairs(smoke=True)``
    (derived, never hand-written); the ``slow`` lane runs the CLI end to
    end, which re-execs under 8 forced host devices so the sharded cells
    trace for real and the report must come back with zero skips;
  * the *dirty* direction — a planted bad kernel per pass proves each
    analysis actually fires: an undeclared Pallas write race, a coverage
    hole, an out-of-bounds index map, a weak-scalar f64 promotion, a bf16
    accumulation downgrade, an undeclared all_gather, and a scalar-keyed
    ``lru_cache`` builder (with and without its waiver comment).  A
    detector nobody has seen fail is just a comment.
"""

import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import analysis, conformance
from repro.core.analysis import (collectives_audit, dtypes, grid,
                                 jaxpr_utils as JU, recompile)
from repro.core.portable import (Backend, BackendUnavailableError,
                                 PortableKernel, registry)

SMOKE_PAIRS = analysis.audit_pairs(smoke=True)


# ---------------------------------------------------------------------------
# clean direction: the live registry audits without findings
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kernel,backend", SMOKE_PAIRS,
    ids=[f"{k}-{b}" for k, b in SMOKE_PAIRS])
def test_registry_cell_audits_clean(kernel, backend):
    """Every smoke cell: no non-waived findings; cells this 1-device host
    cannot trace surface as explicit SkipRecords, never silent passes."""
    res = analysis.audit_cell(kernel, backend, smoke=True)
    assert res.errors == [], [f.to_json() for f in res.errors]
    assert "recompile" in res.passes_run
    for s in res.skips:
        assert s.reason  # a skip always says why


def test_non_jaxpr_traceable_kernels_excluded_from_audit():
    """Host-side driver-loop kernels (serving.engine) stay in conformance
    but have no jaxpr for the static passes — the audit matrix skips them."""
    from repro.core import conformance
    assert registry.get("serving.engine").jaxpr_traceable is False
    assert any(k == "serving.engine"
               for k, _ in conformance.conformance_pairs())
    assert not any(k == "serving.engine" for k, _ in analysis.audit_pairs())


def test_audit_matrix_derives_from_live_registry():
    """Registering a backend adds its audit cell with no suite edit."""
    k = registry.get("stencil7")
    assert ("stencil7", "tmp_audit_backend") not in analysis.audit_pairs()
    k.add_backend("tmp_audit_backend", k.backends["xla"].fn)
    try:
        assert ("stencil7", "tmp_audit_backend") in analysis.audit_pairs()
        res = analysis.audit_cell("stencil7", "tmp_audit_backend",
                                  smoke=True)
        assert res.errors == []
    finally:
        del k.backends["tmp_audit_backend"]


@pytest.mark.slow
def test_full_audit_cli_is_clean():
    """End to end: the CLI re-execs under forced host devices, audits the
    whole matrix, and reports zero findings and zero skips."""
    out = os.path.abspath("ANALYSIS_report_test.json")
    env = dict(os.environ)
    # importing repro.launch.dryrun (test_dryrun_integration) plants a
    # 512-device XLA_FLAGS in this process's environ; the child must see
    # the documented lane (re-exec to 8 forced devices), not that leak
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_ANALYSIS_CHILD", None)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.core.analysis", "--json", out],
            env=env, capture_output=True, text=True, timeout=1200)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        with open(out) as f:
            report = json.load(f)
    finally:
        if os.path.exists(out):
            os.remove(out)
    assert report["schema"] == analysis.SCHEMA
    assert report["summary"]["findings"] == 0
    assert report["summary"]["skips"] == 0
    assert report["summary"]["audited"] == report["summary"]["cells"]
    assert report["device_count"] >= 2


# ---------------------------------------------------------------------------
# planted fixtures: each pass proven to fire
# ---------------------------------------------------------------------------
def _racy_sum(x):
    """Planted grid defect: every grid step writes output block (0,) but
    the output is NOT a declared accumulator — a write race."""
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        body, grid=(4,),
        in_specs=[pl.BlockSpec((32,), lambda i: (i,))],
        out_specs=pl.BlockSpec((32,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
        interpret=True)(x)


def _holey_copy(x):
    """Planted grid defect: 4 output blocks, 2 grid steps — blocks 2, 3
    are never written."""
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        body, grid=(2,),
        in_specs=[pl.BlockSpec((32,), lambda i: (i,))],
        out_specs=pl.BlockSpec((32,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((128,), jnp.float32),
        interpret=True)(x)


def _oob_copy(x):
    """Planted grid defect: index map addresses block i+1 of a 4-block
    space at grid step 3 — out of bounds (and block 0 is a hole)."""
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        body, grid=(4,),
        in_specs=[pl.BlockSpec((32,), lambda i: (i,))],
        out_specs=pl.BlockSpec((32,), lambda i: (i + 1,)),
        out_shape=jax.ShapeDtypeStruct((128,), jnp.float32),
        interpret=True)(x)


def _grid_findings(fn, accumulator_outputs=()):
    closed = JU.trace(fn, (jnp.ones((128,), jnp.float32),), {})
    findings, ncalls = grid.run("planted", "pallas_interpret", closed,
                                accumulator_outputs)
    assert ncalls == 1
    return {f.code for f in findings}, findings


def test_planted_write_race_fires():
    codes, findings = _grid_findings(_racy_sum)
    assert codes == {"write-race"}
    assert findings[0].detail["revisited"] == [[0]]


def test_declared_accumulator_legalizes_revisit():
    """The same planted kernel with its output declared as an accumulator
    audits clean — the dot-partial pattern."""
    codes, _ = _grid_findings(_racy_sum, accumulator_outputs=(0,))
    assert codes == set()


def test_planted_coverage_hole_fires():
    codes, findings = _grid_findings(_holey_copy)
    assert codes == {"coverage-hole"}
    hole = findings[0]
    assert hole.detail["holes"] == [[2], [3]]


def test_planted_oob_tile_fires():
    codes, _ = _grid_findings(_oob_copy)
    assert "out-of-bounds-tile" in codes


def test_planted_f64_promotion_fires():
    """The minibude bug class, distilled: jnp.where over two weak Python
    scalars anchors to float64 under x64."""
    def bad(x):
        return jnp.where(x > 0, 2.0, 4.0) * x

    findings = dtypes.run_f64_lint(
        "planted", "xla", bad, (jnp.ones((8,), jnp.float32),), {})
    assert any(f.code == "f64-promotion" for f in findings)

    def good(x):
        c = x.dtype.type
        return jnp.where(x > 0, c(2.0), c(4.0)) * x

    assert dtypes.run_f64_lint(
        "planted", "xla", good, (jnp.ones((8,), jnp.float32),), {}) == []


def test_planted_accum_downgrade_fires():
    def bf16_dot(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))

    a = jnp.ones((8, 8), jnp.bfloat16)
    closed = JU.trace(bf16_dot, (a, a), {})
    findings = dtypes.run_accum_check("planted", "xla", closed, "float32")
    assert [f.code for f in findings] == ["accum-downgrade"]
    assert findings[0].detail["dtype"] == "bfloat16"

    def f32_dot(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    closed = JU.trace(f32_dot, (a, a), {})
    assert dtypes.run_accum_check("planted", "xla", closed, "float32") == []


def test_planted_undeclared_all_gather_fires():
    """A sharded body that quietly re-materializes the global array.
    check_rep=False mirrors how such a defect ships: replication checking
    would have rejected the spec combination outright."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def gathers(x):
        def body(lx):
            return jnp.sum(jax.lax.all_gather(lx, "x"))
        return shard_map(body, mesh, in_specs=(P("x"),), out_specs=P(),
                        check_rep=False)(x)

    closed = JU.trace(gathers, (jnp.ones((8,), jnp.float32),), {})
    (_, expected), = collectives_audit.normalize_contract(None, ())
    findings = collectives_audit.check_counts(
        "planted", "xla_shard", closed, expected, declared=False)
    assert "undeclared-all-gather" in {f.code for f in findings}


def test_comm_contract_mismatch_fires():
    """A declared contract that disagrees with the trace is a mismatch —
    distinct from the undeclared case."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def summed(x):
        return shard_map(lambda lx: jax.lax.psum(lx, "x"), mesh,
                         in_specs=(P("x"),), out_specs=P())(x)

    closed = JU.trace(summed, (jnp.ones((8,), jnp.float32),), {})
    findings = collectives_audit.check_counts(
        "planted", "xla_shard", closed, {"ppermute": 0, "psum": 0},
        declared=True)
    assert {f.code for f in findings} == {"comm-contract-mismatch"}
    # and the correct declaration audits clean (psum spelled psum2 inside
    # shard_map — the census must see through the renaming)
    assert collectives_audit.check_counts(
        "planted", "xla_shard", closed, {"ppermute": 0, "psum": 1},
        declared=True) == []


_HAZARD_SRC = textwrap.dedent("""
    import functools
    import jax

    @functools.lru_cache(maxsize=None)
    def _build(n, scalar):
        return jax.jit(lambda x: x * scalar + n)

    def entry(x, scalar=0.5):
        return _build(x.shape[0], float(scalar))(x)
""")

_WAIVED_SRC = _HAZARD_SRC.replace(
    "    return jax.jit",
    "    # audit: compile-time-constant(scalar) — baked by design\n"
    "    return jax.jit")


def test_planted_scalar_cache_key_fires():
    hazards = recompile.scan_source(_HAZARD_SRC, "planted_mod")
    assert len(hazards) == 1
    h = hazards[0]
    assert h["builder"] == "_build" and h["waiver"] is None
    # both the float(...) wrapper and the float-default parameter are named
    assert any("float(scalar)" in s for s in h["scalars"])


def test_waiver_comment_downgrades_hazard():
    hazards = recompile.scan_source(_WAIVED_SRC, "planted_mod")
    assert len(hazards) == 1
    assert "compile-time-constant(scalar)" in hazards[0]["waiver"]


def test_shape_keyed_builder_is_not_a_hazard():
    src = _HAZARD_SRC.replace("float(scalar))", "2 * n)")
    assert recompile.scan_source(src, "planted_mod") == []


def test_planted_cell_end_to_end():
    """Full plumbing: a temporarily registered kernel with a racy Pallas
    backend comes back from audit_cell with exactly the planted finding."""
    name = "planted.racy"
    k = PortableKernel(name=name, doc="planted auditor fixture")
    k.add_backend("xla", lambda x: jnp.broadcast_to(x[:32], (32,)))
    k.add_backend("pallas_interpret", _racy_sum)
    registry._kernels[name] = k
    conformance.CASES[name] = lambda: (
        (jnp.ones((128,), jnp.float32),), {})
    try:
        res = analysis.audit_cell(name, "pallas_interpret", smoke=True)
        assert "write-race" in {f.code for f in res.errors}
        # ...and the declared-accumulator escape hatch clears it
        k.declare_grid_contract("pallas_interpret",
                                accumulator_outputs=(0,))
        res = analysis.audit_cell(name, "pallas_interpret", smoke=True)
        assert "write-race" not in {f.code for f in res.errors}
    finally:
        del registry._kernels[name]
        del conformance.CASES[name]


# ---------------------------------------------------------------------------
# satellites: tolerance routing + availability reasons
# ---------------------------------------------------------------------------
def test_validate_routes_through_conformance_tolerance():
    name = "planted.tol"
    k = PortableKernel(name=name)
    k.add_backend("xla", lambda x: x)
    k.add_backend("off_by_eps", lambda x: x + 1e-6)
    registry._kernels[name] = k
    conformance.ORACLE_TOL[name] = (0.0, 1e-3)
    try:
        x = jnp.ones((4,), jnp.float32)
        # default tolerance comes from the conformance table: 1e-6 < 1e-3
        k.validate(x, backend="off_by_eps")
        # a bitwise cell validates at rtol=atol=0 and must reject the drift
        conformance.ORACLE_TOL[name] = "bitwise"
        with pytest.raises(AssertionError):
            k.validate(x, backend="off_by_eps")
        # explicit tolerances still override per call
        k.validate(x, backend="off_by_eps", rtol=0.0, atol=1e-3)
    finally:
        del registry._kernels[name]
        del conformance.ORACLE_TOL[name]


def test_unavailable_reason_from_false_predicate():
    def never(): return False
    b = Backend(name="b", fn=lambda: None, available=never)
    assert b.is_available() is False
    assert "returned False" in b.unavailable_reason
    assert "never" in b.unavailable_reason


def test_unavailable_reason_from_raising_probe():
    def boom(): raise RuntimeError("no TPU runtime linked")
    b = Backend(name="b", fn=lambda: None, available=boom)
    assert b.is_available() is False
    assert "RuntimeError" in b.unavailable_reason
    assert "no TPU runtime linked" in b.unavailable_reason


def test_unavailable_reason_resets_when_available():
    flag = {"ok": False}
    b = Backend(name="b", fn=lambda: None, available=lambda: flag["ok"])
    assert not b.is_available() and b.unavailable_reason
    flag["ok"] = True
    assert b.is_available() and b.unavailable_reason is None


def test_require_available_surfaces_reason():
    k = PortableKernel(name="planted.unavail")
    k.add_backend("xla", lambda x: x)
    k.add_backend("tpu_only", lambda x: x, available=lambda: False)
    with pytest.raises(BackendUnavailableError, match="returned False"):
        k._require_available("tpu_only")


def test_conformance_skip_carries_reason():
    """The conformance suite's skip message now carries the probe's own
    words, not a bare False."""
    name = "planted.skip"
    k = PortableKernel(name=name)
    k.add_backend("xla", lambda x: x)
    k.add_backend("elsewhere", lambda x: x,
                  available=lambda: (_ for _ in ()).throw(
                      RuntimeError("requires libfoo")))
    registry._kernels[name] = k
    conformance.CASES[name] = lambda: ((jnp.ones((4,), jnp.float32),), {})
    conformance.ORACLE_TOL[name] = (0.0, 0.0)
    try:
        with pytest.raises(BackendUnavailableError, match="requires libfoo"):
            conformance.check_backend(name, "elsewhere")
    finally:
        del registry._kernels[name]
        del conformance.CASES[name]
        del conformance.ORACLE_TOL[name]


# ---------------------------------------------------------------------------
# report schema
# ---------------------------------------------------------------------------
def test_report_schema_and_waiver_visibility():
    """Smoke report: schema v2, matrix == derived smoke matrix, and the
    three intentional registry waivers stay visible (never silent)."""
    report = analysis.audit_registry(smoke=True)
    assert report["schema"] == "repro.analysis/v2"
    assert report["passes"] == list(analysis.PASSES)
    assert sorted(map(tuple, report["matrix"])) == sorted(SMOKE_PAIRS)
    assert report["summary"]["findings"] == 0
    waived_codes = {w["code"] for w in report["waived"]}
    assert waived_codes <= {"scalar-cache-key"}
    for w in report["waived"]:
        assert "compile-time-constant" in w["waive_reason"]
