"""PR-6 attention registry dispatch: decode kernel vs ``attend``, backend
resolution (env var / availability fallback), tuned-param injection, and the
per-backend batched↔unbatched serving bit-match.

The decode oracle contract is *bitwise*: ``attention.decode``'s xla backend
is literally the plain-XLA ``attend_xla`` path serving has always run (both
sides jitted — eager-vs-jit FMA contraction differs, so bitwise comparisons
must compile both sides).  Pallas variants run in interpret mode on the host
at the documented fp tolerance.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers attention.* backends)
from repro.configs import get_config
from repro.core import conformance, tuning
from repro.core.portable import registry
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import Request, ServingEngine
from repro.training import serve_step as SS

RTOL = ATOL = 2e-4      # documented pallas-vs-oracle tolerance


def _decode_inputs(seed, *, b=2, h=4, kv=2, t=128, dh=32, wrap=0, holes=None,
                   q_pos=None):
    """Model-native decode call: q (B,1,H,Dh), ring cache k/v (B,T,Kv,Dh)."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((b, 1, h, dh)) * 0.5, jnp.float32)
    k = jnp.asarray(r.standard_normal((b, t, kv, dh)) * 0.5, jnp.float32)
    v = jnp.asarray(r.standard_normal((b, t, kv, dh)) * 0.5, jnp.float32)
    pos = np.tile(np.arange(t, dtype=np.int32), (b, 1))
    if wrap:
        pos[:, :wrap] += t          # ring wrapped: low slots hold new tokens
    if holes is not None:
        pos[:, holes:] = -1         # cache only partially filled
    if q_pos is None:
        q_pos = pos.max(axis=1) + 1
    qp = jnp.asarray(np.asarray(q_pos, np.int32).reshape(b, 1))
    return q, k, v, qp, jnp.asarray(pos)


def _ref(q, k, v, qp, kp, *, kv, window=0):
    fn = jax.jit(lambda *a: A.attend_xla(*a, n_kv_heads=kv, causal=True,
                                         window=window))
    return fn(q, k, v, qp, kp)


# --------------------------------------------------------------------------
# registry surface
# --------------------------------------------------------------------------
def test_attention_kernels_registered_with_tunables():
    for name, params in [("attention.flash", {"bq", "bk"}),
                         ("attention.decode", {"bkv"})]:
        k = registry.get(name)
        assert {"xla", "pallas", "pallas_interpret"} <= set(k.backends)
        assert k.oracle == "xla"
        for b in ("pallas", "pallas_interpret"):
            space = k.tunable_space(b)
            assert space is not None and set(space.params) == params
        # conformance coverage is mandatory: deregistering either kernel,
        # or dropping its case, fails here and in the matrix suite
        assert name in conformance.CASES
        assert name in conformance.ORACLE_TOL
        assert conformance.oracle_tolerance(name, "xla") == "bitwise"


# --------------------------------------------------------------------------
# decode kernel vs attend
# --------------------------------------------------------------------------
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("window", [0, 8])
def test_decode_xla_bitwise_vs_attend(h, kv, window):
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(10 + h + kv, h=h, kv=kv, t=64)
    want = _ref(q, kc, vc, qp, kp, kv=kv, window=window)
    got = k(q, kc, vc, qp, kp, backend="xla", window=window)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1)])
def test_decode_interpret_gqa_ratios(h, kv):
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(20 + h + kv, h=h, kv=kv)
    want = _ref(q, kc, vc, qp, kp, kv=kv)
    got = k(q, kc, vc, qp, kp, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_decode_interpret_ring_wraparound_and_window():
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(3, wrap=5)
    for window in (0, 16):
        want = _ref(q, kc, vc, qp, kp, kv=2, window=window)
        got = k(q, kc, vc, qp, kp, backend="pallas_interpret", window=window,
                bkv=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=RTOL, atol=ATOL)


def test_decode_interpret_leftpad_holes():
    """Empty slots (pos -1, the leftpad drop semantics) never attended."""
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(4, holes=41, q_pos=[41, 41])
    want = _ref(q, kc, vc, qp, kp, kv=2)
    got = k(q, kc, vc, qp, kp, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)
    # and the garbage in the holes genuinely doesn't leak: poisoning the
    # masked slots changes nothing
    kc2 = kc.at[:, 41:].set(1e4)
    vc2 = vc.at[:, 41:].set(-1e4)
    got2 = k(q, kc2, vc2, qp, kp, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_decode_cache_len_one():
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(5, t=1, q_pos=[0, 0])
    want = _ref(q, kc, vc, qp, kp, kv=2)
    got = k(q, kc, vc, qp, kp, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_flash_interpret_leftpad_positions():
    """Prefill kernel in position mode: leftpad -1 rows masked out."""
    k = registry.get("attention.flash")
    r = np.random.default_rng(6)
    b, h, kv, s, dh, pad = 2, 4, 2, 64, 32, 9
    q = jnp.asarray(r.standard_normal((b, h, s, dh)) * 0.5, jnp.float32)
    kc = jnp.asarray(r.standard_normal((b, kv, s, dh)) * 0.5, jnp.float32)
    vc = jnp.asarray(r.standard_normal((b, kv, s, dh)) * 0.5, jnp.float32)
    pos = np.tile(np.arange(s, dtype=np.int32) - pad, (b, 1))
    pos[pos < 0] = -1
    pos = jnp.asarray(pos)
    want = k(q, kc, vc, pos, pos, backend="xla", causal=True, window=0)
    got = k(q, kc, vc, pos, pos, backend="pallas_interpret", causal=True,
            window=0, bq=32, bk=32)
    # pad-query rows are garbage by contract on both sides; compare real rows
    np.testing.assert_allclose(np.asarray(got)[:, :, pad:],
                               np.asarray(want)[:, :, pad:],
                               rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# backend resolution + dispatch
# --------------------------------------------------------------------------
def test_resolve_precedence_and_fallback(monkeypatch):
    monkeypatch.delenv(A.ATTN_BACKEND_ENV, raising=False)
    assert A.resolve_attention_backend("decode", None) == "xla"
    assert A.resolve_attention_backend("decode", "auto") == "xla"
    assert A.resolve_attention_backend("prefill", "xla") == "xla"
    ik = registry.get("attention.decode")
    if ik.backends["pallas_interpret"].is_available():
        assert A.resolve_attention_backend(
            "decode", "pallas_interpret") == "pallas_interpret"
    if not ik.backends["pallas"].is_available():
        # requested-but-unavailable falls back past pallas to the oracle
        assert A.resolve_attention_backend("decode", "pallas") == "xla"
    # env var wins over the argument
    monkeypatch.setenv(A.ATTN_BACKEND_ENV, "xla")
    assert A.resolve_attention_backend("decode", "pallas_interpret") == "xla"
    monkeypatch.delenv(A.ATTN_BACKEND_ENV)
    with pytest.raises(KeyError):
        A.resolve_attention_backend("decode", "no_such_backend")
    with pytest.raises(KeyError):
        A.resolve_attention_backend("no_such_kind", "xla")


def test_attend_dispatch_routes_and_falls_back(monkeypatch):
    monkeypatch.delenv(A.ATTN_BACKEND_ENV, raising=False)
    q, kc, vc, qp, kp = _decode_inputs(7, t=64)
    want = _ref(q, kc, vc, qp, kp, kv=2)

    A.reset_dispatch_log()
    got = A.attend(q, kc, vc, qp, kp, n_kv_heads=2, causal=True,
                   backend="pallas_interpret")
    log = A.dispatch_log()["decode"]
    assert log["backend"] == "pallas_interpret"
    assert log["tuning"] == "miss-default"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)

    # default request: status-quo XLA path, bitwise
    A.reset_dispatch_log()
    got = jax.jit(lambda *a: A.attend(*a, n_kv_heads=2, causal=True)
                  )(q, kc, vc, qp, kp)
    assert A.dispatch_log()["decode"]["backend"] == "xla"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # a cache length no block size divides falls back to XLA with a reason
    q2, kc2, vc2, qp2, kp2 = _decode_inputs(8, t=300)
    A.reset_dispatch_log()
    got2 = A.attend(q2, kc2, vc2, qp2, kp2, n_kv_heads=2, causal=True,
                    backend="pallas_interpret")
    log = A.dispatch_log()["decode"]
    assert log["backend"] == "xla" and "fallback" in log
    np.testing.assert_array_equal(
        np.asarray(got2), np.asarray(_ref(q2, kc2, vc2, qp2, kp2, kv=2)))

    # ring-wrapped causal prefill (k_index_aligned=False) stays on XLA
    r = np.random.default_rng(9)
    qq = jnp.asarray(r.standard_normal((1, 32, 4, 16)), jnp.float32)
    kk = jnp.asarray(r.standard_normal((1, 32, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None], (1, 32))
    A.reset_dispatch_log()
    A.attend(qq, kk, kk, pos, pos, n_kv_heads=2, causal=True,
             backend="pallas_interpret", k_index_aligned=False)
    log = A.dispatch_log()["prefill"]
    assert log["backend"] == "xla" and "fallback" in log


def test_tuned_param_injection(monkeypatch, tmp_path):
    """A planted cache entry is injected at dispatch and reported with its
    search provenance."""
    monkeypatch.delenv(A.ATTN_BACKEND_ENV, raising=False)
    monkeypatch.setenv(tuning.CACHE_ENV, str(tmp_path / "tuning.json"))
    k = registry.get("attention.decode")
    q, kc, vc, qp, kp = _decode_inputs(11, t=128)
    key = tuning.make_key(k, q, kc, vc, qp, kp,
                          backend="pallas_interpret", window=0)
    tuning.TuningCache().put(key, {"bkv": 64}, 1.0, search="exhaustive")

    A.reset_dispatch_log()
    got = A.attend(q, kc, vc, qp, kp, n_kv_heads=2, causal=True,
                   backend="pallas_interpret")
    log = A.dispatch_log()["decode"]
    assert log["tuning"] == "exhaustive"
    assert log["params"] == {"bkv": 64}
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref(q, kc, vc, qp, kp, kv=2)),
                               rtol=RTOL, atol=ATOL)

    # a different shape misses the cache -> declared defaults
    q2, kc2, vc2, qp2, kp2 = _decode_inputs(12, t=64)
    A.reset_dispatch_log()
    A.attend(q2, kc2, vc2, qp2, kp2, n_kv_heads=2, causal=True,
             backend="pallas_interpret")
    assert A.dispatch_log()["decode"]["tuning"] == "miss-default"


# --------------------------------------------------------------------------
# per-backend serving bit-match (batched engine vs unbatched generate)
# --------------------------------------------------------------------------
CFG = get_config("granite-3-8b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return T.init_params(CFG, jax.random.PRNGKey(0))


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_engine_greedy_bitmatch_per_backend(params, backend, monkeypatch):
    monkeypatch.delenv(A.ATTN_BACKEND_ENV, raising=False)
    rng = np.random.default_rng(2)
    lens = [3, 9, 12, 5]
    prompts = [rng.integers(2, CFG.vocab_size, L).astype(np.int32)
               for L in lens]
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=16, attn_backend=backend)
    assert eng.attn_backends == {"prefill": backend, "decode": backend}
    done = eng.run([Request(uid=i, prompt=p, max_new_tokens=5,
                            arrival_time=0.0)
                    for i, p in enumerate(prompts)])
    assert len(done) == len(prompts)
    for r in sorted(done, key=lambda r: r.uid):
        want = SS.generate(params, CFG, jnp.asarray(prompts[r.uid][None]),
                           max_new_tokens=5, cache_len=32,
                           attn_backend=backend)
        assert r.generated == list(np.asarray(want[0])), \
            f"slot-batched decode diverged from unbatched under {backend}"
