"""Edge cases of the compiled-HLO cost parsers (PR 9 satellite).

``test_core.py`` covers the happy paths (trip counts, XLA cross-check);
this file pins the parser corners the registry walk depends on: typed
operand lists, tuple-output fusions, modules without collectives, async
``-start``/``-done`` collective pairs, and the public
``arithmetic_intensity`` helper."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import parse_collective_bytes
from repro.core.hlo_cost import analyze_hlo, arithmetic_intensity

# hand-written HLO in the two operand styles XLA emits: typed
# (`f32[64]{0} %a`) and bare (`%a`) — both must parse identically
_TYPED_OPERANDS = """
HloModule m

ENTRY %main (a: f32[64,32], b: f32[32,16]) -> f32[64,16] {
  %a = f32[64,32]{1,0} parameter(0)
  %b = f32[32,16]{1,0} parameter(1)
  ROOT %d = f32[64,16]{1,0} dot(f32[64,32]{1,0} %a, f32[32,16]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_BARE_OPERANDS = """
HloModule m

ENTRY %main (a: f32[64,32], b: f32[32,16]) -> f32[64,16] {
  %a = f32[64,32]{1,0} parameter(0)
  %b = f32[32,16]{1,0} parameter(1)
  ROOT %d = f32[64,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_TUPLE_FUSION = """
HloModule m

%fused (p0: f32[128]) -> (f32[128], f32[128]) {
  %p0 = f32[128]{0} parameter(0)
  %e = f32[128]{0} exponential(%p0)
  %t = f32[128]{0} tanh(%p0)
  ROOT %tup = (f32[128]{0}, f32[128]{0}) tuple(%e, %t)
}

ENTRY %main (a: f32[128]) -> (f32[128], f32[128]) {
  %a = f32[128]{0} parameter(0)
  ROOT %f = (f32[128]{0}, f32[128]{0}) fusion(%a), kind=kLoop, calls=%fused
}
"""

_ASYNC_COLLECTIVES = """
HloModule m

ENTRY %main (p: f32[128]) -> f32[256] {
  %p = f32[128]{0} parameter(0)
  %ags = f32[256]{0} all-gather-start(%p), replica_groups={}
  %agd = f32[256]{0} all-gather-done(%ags)
  %rs = f32[64]{0} reduce-scatter(%p), replica_groups={}, to_apply=%sum
  ROOT %out = f32[256]{0} copy(%agd)
}
"""


def test_dot_flops_typed_and_bare_operands():
    want = 2.0 * 64 * 16 * 32
    assert analyze_hlo(_TYPED_OPERANDS).flops == want
    assert analyze_hlo(_BARE_OPERANDS).flops == want


def test_tuple_output_fusion():
    cost = analyze_hlo(_TUPLE_FUSION)
    # both fused elementwise ops count, the tuple glue does not
    assert cost.flops == 2 * 128
    assert cost.transcendentals == 2 * 128
    # HBM traffic at the fusion boundary: operand + tuple result
    assert cost.hbm_bytes == (128 + 2 * 128) * 4


def test_zero_collective_module():
    cost = analyze_hlo(_TYPED_OPERANDS)
    assert cost.collective_bytes == 0.0
    assert cost.collective_bytes_by_kind == {}
    assert cost.collective_count_by_kind == {}
    stats = parse_collective_bytes(_TYPED_OPERANDS)
    assert stats.bytes_by_kind == {}
    assert stats.count_by_kind == {}


def test_async_start_done_counted_once():
    stats = parse_collective_bytes(_ASYNC_COLLECTIVES)
    # -start carries the payload, -done must not double it
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 256 * 4
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 4

    cost = analyze_hlo(_ASYNC_COLLECTIVES)
    assert cost.collective_count_by_kind["all-gather"] == 1
    assert cost.collective_bytes_by_kind["all-gather"] == 256 * 4


def test_no_entry_raises():
    with pytest.raises(ValueError, match="no ENTRY"):
        analyze_hlo("%orphan (p: f32[2]) -> f32[2] {\n}")


def test_arithmetic_intensity_helper():
    cost = analyze_hlo(_TYPED_OPERANDS)
    assert arithmetic_intensity(cost) == pytest.approx(
        cost.flops / cost.hbm_bytes)
    # zero-traffic guard: never divides by zero
    empty = analyze_hlo("ENTRY %main (p: f32[2]) -> f32[2] {\n"
                        "  ROOT %p = f32[2]{0} parameter(0)\n}")
    assert empty.hbm_bytes == 0.0
    assert arithmetic_intensity(empty) == 0.0


def test_compiled_roundtrip_has_positive_ai():
    """A real compiled module flows through both lanes coherently."""
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = jax.jit(lambda x, y: jnp.tanh(x @ y)).lower(a, b).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops >= 2 * 128 * 64 * 256
    assert cost.hbm_bytes > 0
    assert arithmetic_intensity(cost) > 0
