"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device
(only launch/dryrun.py forces the 512-device placeholder topology).

Also installs an optional-import shim for ``hypothesis``: when the real
package is absent (minimal CI hosts), the property tests in test_core /
test_substrate / test_kernels_stencil7 / test_sharding_policy fall back to a
deterministic parametrized-example runner (see _hypothesis_stub.py) instead
of failing collection with ModuleNotFoundError.
"""

import os
import sys
import types

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub as _stub

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _stub.given
    _hyp.settings = _stub.settings
    _strat = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from", "booleans"):
        setattr(_strat, _name, getattr(_stub, _name))
    _hyp.strategies = _strat
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strat


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
