"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device
(only launch/dryrun.py forces the 512-device placeholder topology)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
