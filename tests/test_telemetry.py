"""Telemetry-core tests: span nesting, ring eviction, disabled no-op,
Chrome-trace export, the once-per-call (not once-per-trace) regression, the
bounded attention dispatch stream, the serving SLO percentiles, and the
summarize CLI smoke on a trace emitted by a real engine run."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import telemetry as tel
from repro.core.telemetry import jaxmon
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import Request, ServingEngine
from repro.serving.trace import latency_summary, synthetic_trace

CFG = get_config("granite-3-8b", smoke=True)


@pytest.fixture
def telem():
    """Fresh in-memory recorder for the test; restores the env default
    (off, unless REPRO_TELEMETRY is set) afterwards."""
    rec = tel.configure("on")
    yield rec
    tel.configure(os.environ.get(tel.ENV))


@pytest.fixture(scope="module")
def params():
    return T.init_params(CFG, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# core: spans, ring, no-op
# --------------------------------------------------------------------------
def test_span_nesting_records_parent(telem):
    with tel.span("outer", proc="t") as outer:
        with tel.span("inner", proc="t"):
            with tel.span("leaf", proc="t"):
                pass
    spans = {e["name"]: e for e in telem.event_list()
             if e["kind"] == "span"}
    assert set(spans) == {"outer", "inner", "leaf"}
    assert spans["outer"]["parent"] is None
    assert spans["inner"]["parent"] == spans["outer"]["sid"] == outer.sid
    assert spans["leaf"]["parent"] == spans["inner"]["sid"]
    # children close before parents: dur nests
    assert spans["outer"]["dur"] >= spans["inner"]["dur"] >= \
        spans["leaf"]["dur"] >= 0.0
    # instants inherit the enclosing span as parent
    with tel.span("p") as p:
        tel.instant("mark")
    mark = [e for e in telem.event_list() if e["name"] == "mark"][0]
    assert mark["parent"] == p.sid


def test_ring_buffer_cap_evicts_oldest():
    rec = tel.configure("on", capacity=5)
    try:
        for i in range(12):
            tel.instant(f"e{i}")
        events = rec.event_list()
        assert len(events) == 5
        assert [e["name"] for e in events] == [f"e{i}" for i in range(7, 12)]
        assert rec.dropped == 7
        snap = rec.snapshot()
        assert snap["events_dropped"] == 7
        # aggregates never evict: counters survive ring churn
        tel.counter("c")
        for i in range(10):
            tel.instant("spam")
        assert rec.snapshot()["counters"]["c"] == 1.0
    finally:
        tel.configure(os.environ.get(tel.ENV))


def test_disabled_mode_is_noop():
    tel.configure("off")
    assert not tel.enabled() and tel.recorder() is None
    # shared stateless context manager — no per-call allocation
    assert tel.span("a", proc="x", k=1) is tel.span("b")
    with tel.span("a"):
        tel.instant("i")
        tel.counter("c")
        tel.gauge("g", 1.0)
    assert tel.events() == [] and tel.snapshot() == {}
    rec = tel.configure("on")
    tel.instant("now-recording")
    assert len(rec.event_list()) == 1
    tel.configure(os.environ.get(tel.ENV))


def test_configure_rejects_bad_mode():
    tel.configure("off")
    with pytest.raises(ValueError):
        tel.configure("yes-please")
    with pytest.raises(ValueError):
        tel.configure("jsonl:")
    assert not tel.enabled()


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------
def test_chrome_trace_round_trips(telem, tmp_path):
    with tel.span("work", proc="engine", kernel="stencil7"):
        with tel.span("child", proc="engine"):
            pass
    tel.gauge("depth", 3.0, proc="engine")
    tel.instant("mark", proc="worker", uid=1)
    path = tmp_path / "trace.json"
    tel.write_chrome_trace(str(path), telem)
    doc = json.loads(path.read_text())          # well-formed JSON
    tes = doc["traceEvents"]
    xs = [t for t in tes if t["ph"] == "X"]
    assert {t["name"] for t in xs} == {"work", "child"}
    for t in xs:
        assert isinstance(t["ts"], float) and isinstance(t["dur"], float)
        assert t["dur"] >= 0.0 and isinstance(t["pid"], int)
    cs = [t for t in tes if t["ph"] == "C"]
    assert cs and cs[0]["args"] == {"depth": 3.0}
    assert any(t["ph"] == "i" and t["name"] == "mark" for t in tes)
    # proc labels become named processes via metadata events
    procs = {t["args"]["name"] for t in tes
             if t["ph"] == "M" and t["name"] == "process_name"}
    assert {"engine", "worker"} <= procs
    # and the summarize CLI reads the chrome form too
    summary = tel.summarize_file(str(path))
    assert summary["spans"]["work"]["count"] == 1


def test_jsonl_round_trip_and_summary(telem, tmp_path):
    for i in range(10):
        with tel.span("op", proc="t", i=i):
            pass
    tel.counter("hits", 3)
    path = tmp_path / "trace.jsonl"
    n = tel.write_jsonl(str(path), telem, meta={"note": "test"})
    assert n == len(telem.event_list())
    doc = tel.read_events(str(path))
    assert doc["header"]["schema"] == tel.SCHEMA
    assert doc["header"]["note"] == "test"
    assert doc["footer"]["counters"] == {"hits": 3.0}
    summary = tel.summarize_file(str(path))
    s = summary["spans"]["op"]
    assert s["count"] == 10
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    assert s["total_ms"] >= s["p99_ms"]


def test_percentile_matches_numpy():
    vals = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    for q in (0, 25, 50, 90, 95, 99, 100):
        assert tel.percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)))
    assert tel.percentile([7.0], 95) == 7.0
    with pytest.raises(ValueError):
        tel.percentile([], 50)


# --------------------------------------------------------------------------
# trace-time safety: execution events per call, compile events per trace
# --------------------------------------------------------------------------
def test_instrumented_jit_emits_once_per_call_not_per_trace():
    # input built (and synced) BEFORE counting starts, so only f's own
    # compilation can land in the compile counter
    x = jnp.arange(8, dtype=jnp.float32)
    jax.block_until_ready(x)

    @jax.jit
    def f(v):
        return v * 2.0 + 1.0

    rec = tel.configure("on")
    try:
        for _ in range(3):
            with tel.span("exec", proc="t"):
                jax.block_until_ready(f(x))
        events = rec.event_list()
        counters = rec.snapshot()["counters"]
    finally:
        tel.configure(os.environ.get(tel.ENV))
    execs = [e for e in events
             if e["kind"] == "span" and e["name"] == "exec"]
    assert len(execs) == 3                    # once per CALL
    # ... while jax compiled (and traced) the function exactly once
    assert counters[jaxmon.COMPILE_COUNTER] == 1
    compile_spans = [e for e in events
                     if e["kind"] == "span" and e["name"] == "jax.compile"]
    assert len(compile_spans) == 1


# --------------------------------------------------------------------------
# attention dispatch stream (the _DISPATCH_LOG lossiness fix)
# --------------------------------------------------------------------------
def test_dispatch_stream_keeps_concurrent_records():
    A.reset_dispatch_log()
    # two "engines" (or two benchmark rows) tracing back to back — the old
    # dict-keyed-by-kind log kept only the last writer per kind
    A._log("decode", backend="xla", tuning="n/a", params={})
    A._log("prefill", backend="xla", tuning="n/a", params={})
    A._log("decode", backend="pallas_interpret", tuning="miss-default",
           params={"bkv": 64})
    recs = A.dispatch_records()
    assert [r["kind"] for r in recs] == ["decode", "prefill", "decode"]
    assert [r["backend"] for r in recs if r["kind"] == "decode"] == \
        ["xla", "pallas_interpret"]
    # the last-per-kind view is API-compatible with the old log
    log = A.dispatch_log()
    assert log["decode"]["backend"] == "pallas_interpret"
    assert log["decode"]["params"] == {"bkv": 64}
    assert log["prefill"]["backend"] == "xla"
    assert "kind" not in log["decode"]
    A.reset_dispatch_log()
    assert A.dispatch_log() == {} and A.dispatch_records() == []


def test_dispatch_stream_is_bounded():
    A.reset_dispatch_log()
    for i in range(A.DISPATCH_LOG_CAP + 10):
        A._log("decode", backend="xla", tuning="n/a", params={}, seq=i)
    recs = A.dispatch_records()
    assert len(recs) == A.DISPATCH_LOG_CAP
    assert recs[-1]["seq"] == A.DISPATCH_LOG_CAP + 9   # newest kept
    A.reset_dispatch_log()


def test_dispatch_flows_into_telemetry(telem):
    A.reset_dispatch_log()
    A._log("decode", backend="pallas_interpret", tuning="miss-default",
           params={}, fallback="why not")
    names = [e["name"] for e in telem.event_list()]
    assert "attn.dispatch" in names
    counters = telem.snapshot()["counters"]
    assert counters["attn.dispatch.decode.pallas_interpret"] == 1.0
    assert counters["attn.dispatch.fallback"] == 1.0
    A.reset_dispatch_log()


# --------------------------------------------------------------------------
# serving SLO percentiles (trace.py satellite)
# --------------------------------------------------------------------------
def test_latency_summary_empty_trace_is_explicit():
    assert latency_summary([]) == {"requests": 0, "submitted": 0,
                                   "unfinished": 0}
    # submitted-but-never-finished requests are counted, never hidden
    reqs = synthetic_trace(3, vocab_size=32)
    assert latency_summary(reqs) == {"requests": 0, "submitted": 3,
                                     "unfinished": 3}


def test_latency_summary_p99_and_itl():
    reqs = synthetic_trace(10, vocab_size=64, rate=100.0, seed=3)
    for i, r in enumerate(reqs):
        r.t_first_token = r.arrival_time + 0.01
        r.t_done = r.arrival_time + 0.1 + 0.01 * i
        r.t_tokens = [r.t_first_token + 0.005 * k for k in range(4)]
    lat = latency_summary(reqs)
    assert lat["requests"] == 10
    for metric in ("latency", "ttft", "itl"):
        p50, p95, p99 = (lat[f"p{q}_{metric}_s"] for q in (50, 95, 99))
        assert p50 <= p95 <= p99
    assert lat["p50_itl_s"] == pytest.approx(0.005)
    # gaps are per-request consecutive diffs
    assert reqs[0].inter_token_gaps() == pytest.approx([0.005] * 3)
    # without per-token stamps the itl keys are absent, not wrong
    for r in reqs:
        r.t_tokens = []
    lat = latency_summary(reqs)
    assert "p99_itl_s" not in lat and lat["p99_latency_s"] > 0


# --------------------------------------------------------------------------
# engine lifecycle + CLI smoke (tier-1: tiny synthetic engine run)
# --------------------------------------------------------------------------
def _run_engine(params, n=3):
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    reqs = [Request(uid=i,
                    prompt=np.arange(2 + i, 6 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(n)]
    done = eng.run(reqs)
    return {r.uid: list(r.generated) for r in done}


def test_engine_lifecycle_events_and_cli_smoke(params, tmp_path):
    rec = tel.configure("on")
    try:
        toks_on = _run_engine(params)
        events = rec.event_list()
        path = tmp_path / "engine_trace.jsonl"
        tel.write_jsonl(str(path), rec)
    finally:
        tel.configure(os.environ.get(tel.ENV))

    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    # full lifecycle, one per request
    for name in ("serving.enqueue", "serving.slot_assign",
                 "serving.first_token", "serving.finish"):
        assert len(by_name[name]) == 3, name
    assert len(by_name["serving.prefill"]) == 3
    assert by_name["serving.decode_step"], "no decode-step spans"
    # decode steps nest under the serving.run span
    run_sid = by_name["serving.run"][0]["sid"]
    assert all(e["parent"] == run_sid
               for e in by_name["serving.decode_step"])
    # gauges sampled per step
    assert len(by_name["serving.queue_depth"]) == \
        len(by_name["serving.decode_step"])
    assert all(0 < e["value"] <= 1.0
               for e in by_name["serving.slot_occupancy"])
    # lifecycle ordering per request uid
    for uid in range(3):
        ts = {n: [e["ts"] for e in by_name[n]
                  if e.get("attrs", {}).get("uid") == uid]
              for n in ("serving.enqueue", "serving.slot_assign",
                        "serving.first_token", "serving.finish")}
        assert ts["serving.enqueue"][0] <= ts["serving.slot_assign"][0] \
            <= ts["serving.first_token"][0] <= ts["serving.finish"][0]

    # telemetry must not change sampled tokens: bitwise vs the off run
    assert not tel.enabled()
    toks_off = _run_engine(params)
    assert toks_on == toks_off

    # the CLI end of the pipeline: summarize the emitted trace
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.telemetry", "summarize",
         str(path)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert "serving.decode_step" in out.stdout
    assert "p99_ms" in out.stdout or "p99" in out.stdout
    assert "serving.requests_finished = 3" in out.stdout


# --------------------------------------------------------------------------
# serving benchmark v4 drift check (slow lane; the --smoke CLI also covers)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_serving_benchmark_smoke_writes_v4_artifact(tmp_path, monkeypatch):
    from benchmarks import serving as bench

    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tuning.json"))
    json_path = str(tmp_path / "BENCH_serving.json")
    artifact = bench.run(smoke=True, json_path=json_path)
    on_disk = json.loads((tmp_path / "BENCH_serving.json").read_text())

    assert on_disk["schema"] == "repro.serving/v4"
    assert on_disk["jax_compile_events"] > 0      # the recompile counter
    assert on_disk["telemetry"]["counters"]
    # the sweep: 2 backends x both cache layouts x a >=3-point rate ladder
    assert len(on_disk["rates_rps"]) >= 3
    backends = sorted({r["backend"] for r in on_disk["rows"]})
    assert len(backends) == 2 and "xla" in backends
    assert ({r["cache_layout"] for r in on_disk["rows"]}
            == {"contiguous", "paged"})
    cells = {(r["backend"], r["cache_layout"], r["rate_rps"])
             for r in on_disk["rows"]}
    assert len(cells) == 2 * 2 * len(on_disk["rates_rps"])
    for row in on_disk["rows"]:
        assert not row["retraced"]
        # a row whose trace didn't drain would have raised inside run();
        # the artifact still records the accounting
        assert row["unfinished"] == 0
        assert row["submitted"] == row["requests"]
        for col in ("ttft_p99_ms", "latency_p99_ms", "itl_p50_ms",
                    "itl_p95_ms", "itl_p99_ms", "jax_compile_events"):
            assert row[col] is not None and row[col] >= 0, col
        # warmup walked the whole bucket ladder: timed runs never compile
        assert row["telemetry"]["jax_compile_events_timed"] == 0
        assert row["telemetry"]["spans"]["serving.decode_step"]["count"] > 0
    # bounded-compile contract per engine: one prefill program per ladder
    # rung at most, exactly one decode program
    assert len(on_disk["engines"]) == 4
    for e in on_disk["engines"]:
        assert e["prefill_traces"] <= len(on_disk["prefill_buckets"])
        assert e["decode_traces"] == 1
    # the pallas rows must dispatch through the registry, not fall back
    for row in on_disk["rows"]:
        if row["backend"] != "xla":
            assert row["dispatch"]["decode"]["backend"] != "xla"

    # trace artifacts: JSONL summarizes, chrome form loads
    summary = tel.summarize_file(artifact["trace_jsonl"])
    assert summary["spans"]["serving.decode_step"]["count"] > 0
    assert summary["counters"][jaxmon.COMPILE_COUNTER] == \
        on_disk["jax_compile_events"]
    doc = json.loads(open(artifact["trace_chrome"]).read())
    assert any(t["ph"] == "X" for t in doc["traceEvents"])
    # telemetry was owned by the benchmark and is off again
    assert not tel.enabled()
