"""Substrate tests: optimizer, compression, losses, data, checkpoint, FT."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import (Heartbeat, PreemptionGuard,
                                               StragglerMonitor,
                                               elastic_mesh_shape)
from repro.optim import adamw, compression
from repro.training.losses import softmax_xent


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_quadratic_converges():
    cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=5, decay_steps=200,
                            weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}      # d/dw (w^2)
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr_peak=1.0, warmup_steps=0, decay_steps=10,
                            grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    newp, _, metrics = adamw.apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(newp["w"]))) < 2.0   # clipped


def test_cosine_schedule_shape():
    cfg = adamw.AdamWConfig(lr_peak=1.0, warmup_steps=10, decay_steps=100,
                            lr_min_ratio=0.1)
    lrs = [float(adamw.cosine_lr(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 1000)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6            # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6            # peak
    assert 0.1 < lrs[3] < 1.0                  # decaying
    assert abs(lrs[4] - 0.1) < 1e-6            # floor
    assert abs(lrs[5] - 0.1) < 1e-6


# --------------------------------------------------------------------------
# compression (error feedback property)
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_error_feedback_accumulates_true_gradient(seed):
    """sum_t compressed_t -> sum_t g_t within one final quantization step."""
    rng = np.random.default_rng(seed)
    g_seq = [jnp.asarray(rng.standard_normal(32) * 0.1, jnp.float32)
             for _ in range(20)]
    residual = {"g": jnp.zeros(32)}
    total_sent = jnp.zeros(32)
    for g in g_seq:
        sent, residual = compression.ef_compress_tree(
            {"g": g}, residual)
        total_sent = total_sent + sent["g"]
    true_total = sum(g_seq)
    # remaining error is exactly the residual (bounded by one quant step)
    np.testing.assert_allclose(np.asarray(total_sent + residual["g"]),
                               np.asarray(true_total), rtol=1e-5, atol=1e-5)


def test_int8_quantization_bounds():
    x = jnp.asarray([-3.0, 0.0, 1.5, 3.0])
    q, s = compression.quantize_int8(x)
    deq = compression.dequantize_int8(q, s)
    assert np.max(np.abs(np.asarray(deq - x))) <= float(s) * 0.5 + 1e-7


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def test_xent_uniform_logits():
    v = 32
    logits = jnp.zeros((2, 3, v))
    targets = jnp.zeros((2, 3), jnp.int32)
    loss, m = softmax_xent(logits, targets, z_loss=0.0)
    np.testing.assert_allclose(float(loss), np.log(v), rtol=1e-5)


def test_xent_mask_excludes_tokens():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((1, 4, 8)),
                         jnp.float32)
    targets = jnp.zeros((1, 4), jnp.int32)
    full, _ = softmax_xent(logits, targets, z_loss=0.0)
    m = jnp.asarray([[1, 1, 0, 0]], jnp.float32)
    masked, _ = softmax_xent(logits, targets, mask=m, z_loss=0.0)
    ref2, _ = softmax_xent(logits[:, :2], targets[:, :2], z_loss=0.0)
    np.testing.assert_allclose(float(masked), float(ref2), rtol=1e-5)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    d2.seek(3)
    b1 = [d1.batch_at(i) for i in range(5)]
    np.testing.assert_array_equal(b1[3]["tokens"], next(iter(d2))["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1[0]["tokens"][:, 1:],
                                  b1[0]["targets"][:, :-1])


def test_data_host_sharding_disjoint():
    k = dict(vocab_size=1000, seq_len=16, global_batch=8, n_hosts=2)
    h0 = SyntheticLM(DataConfig(host_id=0, **k)).batch_at(0)
    h1 = SyntheticLM(DataConfig(host_id=1, **k)).batch_at(0)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_yields_in_order():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    src = SyntheticLM(cfg)
    want = [src.batch_at(i)["tokens"] for i in range(3)]
    pf = Prefetcher(SyntheticLM(cfg), depth=2)
    got = [next(pf)["tokens"] for _ in range(3)]
    pf.close()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7, jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, state, metadata={"arch": "test"})
    template = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    restored, manifest = mgr.restore(template)
    assert manifest["step"] == 7 and manifest["arch"] == "test"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored, state)


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones(4)}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((3, 3))})


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------
def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(alpha=0.5, factor=2.0, warmup=3)
    for i in range(5):
        assert not mon.observe(i, 1.0)
    assert mon.observe(5, 5.0)            # 5x EMA -> straggler
    assert mon.events and mon.events[0]["step"] == 5
    assert not mon.observe(6, 1.0)        # EMA not poisoned


def test_preemption_guard_flag():
    g = PreemptionGuard().install()
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop


def test_heartbeat_writes(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb"), interval_s=0.0)
    hb.beat(12)
    assert (tmp_path / "hb").read_text().startswith("12 ")


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 4096))
def test_elastic_mesh_shape_factors(n):
    shape = elastic_mesh_shape(n)
    assert shape["data"] * shape["model"] == n
