"""Beyond-paper performance levers must be numerics-safe (EXPERIMENTS §Perf)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import apply_norm, norm_init
from repro.models.chunked_attention import attend_chunked
from repro.training.train_step import TrainConfig, make_train_state, train_step


def _batch(cfg, key, b=4, s=32):
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }


def test_zero1_matches_baseline_loss():
    cfg0 = get_config("granite-3-8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg0, key)
    tcfg = TrainConfig(microbatches=2)
    batch = _batch(cfg0, key)
    losses = {}
    for name, over in [("base", {}), ("zero1", {"zero1_weights": True})]:
        cfg = dataclasses.replace(cfg0, **over)
        state = make_train_state(params, tcfg)
        state, m = jax.jit(
            lambda s, b: train_step(s, b, cfg=cfg, tcfg=tcfg))(state, batch)
        losses[name] = float(m["loss"])
    assert abs(losses["base"] - losses["zero1"]) < 1e-2


@pytest.mark.slow
def test_moe_stopgrad_matches_baseline_loss_and_router_grads():
    cfg0 = get_config("deepseek-moe-16b", smoke=True)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg0, key)
    tcfg = TrainConfig(microbatches=1, remat=False)
    batch = _batch(cfg0, key, b=2, s=16)
    outs = {}
    for name, over in [("base", {}), ("sg", {"moe_stopgrad_dispatch": True})]:
        cfg = dataclasses.replace(cfg0, **over)
        state = make_train_state(params, tcfg)
        new_state, m = jax.jit(
            lambda s, b: train_step(s, b, cfg=cfg, tcfg=tcfg))(state, batch)
        outs[name] = (float(m["loss"]), new_state["params"])
    # identical forward loss
    assert abs(outs["base"][0] - outs["sg"][0]) < 1e-4
    # router still learns (gradient flows via combine gates)
    r0 = params["segments"][0]["moe"]["router"]
    r1 = outs["sg"][1]["segments"][0]["moe"]["router"]
    assert float(jnp.max(jnp.abs(r1 - r0))) > 0


def test_bf16_norm_close_to_f32_norm():
    p = norm_init(64, "rmsnorm", jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16, 64)),
                    jnp.bfloat16)
    a = apply_norm(p, x, "rmsnorm", bf16_mul=False).astype(jnp.float32)
    b = apply_norm(p, x, "rmsnorm", bf16_mul=True).astype(jnp.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)
    pl = norm_init(64, "layernorm", jnp.float32)
    a = apply_norm(pl, x, "layernorm", bf16_mul=False).astype(jnp.float32)
    b = apply_norm(pl, x, "layernorm", bf16_mul=True).astype(jnp.float32)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)


def test_attn_bf16_intermediates_tolerance():
    rng = np.random.default_rng(0)
    B, S, H, Kv, Dh = 1, 2048, 4, 2, 32
    f = lambda *sh: jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k, v = f(B, S, H, Dh), f(B, S, Kv, Dh), f(B, S, Kv, Dh)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    a = attend_chunked(q, k, v, pos, pos, n_kv_heads=Kv, causal=True)
    b = attend_chunked(q, k, v, pos, pos, n_kv_heads=Kv, causal=True,
                       bf16_intermediates=True)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)


def test_vocab_padding_masks_invalid_logits():
    cfg = dataclasses.replace(get_config("granite-3-8b", smoke=True),
                              vocab_size=250, vocab_pad_multiple=64)
    assert cfg.padded_vocab == 256
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    assert params["embed"].shape[0] == 256
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 250)
    logits, _, _ = T.forward(params, cfg, tokens)
    assert logits.shape[-1] == 256
    pad_logits = np.asarray(logits[..., 250:], np.float32)
    assert (pad_logits < -1e8).all()


def test_last_only_matches_full_forward():
    cfg = get_config("stablelm-1.6b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, tokens)
    last, _, _ = T.forward(params, cfg, tokens, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-3, atol=1e-3)
