"""Autotuning subsystem: cache determinism, availability skip, warmup=0,
and the registry-driven Eq.-4 sweep at smoke shapes."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tuning
from repro.core.portable import (BackendUnavailableError, KernelRegistry,
                                 PortableKernel)


def _toy_kernel(calls):
    """A kernel whose 'fast' backend counts invocations (to prove cache hits
    skip re-timing) and exposes a 3-point tunable grid."""
    k = PortableKernel(name="toy")
    k.add_backend("xla", lambda x: x * 2.0)

    def fast(x, *, block=8):
        calls["n"] += 1
        return x + x

    k.add_backend("fast", fast)
    k.declare_tunables("fast", block=(4, 8, 16))
    return k


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------
def test_time_backend_warmup_zero_does_not_raise():
    k = PortableKernel(name="w0")
    k.add_backend("xla", lambda x: x + 1.0)
    t = k.time_backend(jnp.ones(8), backend="xla", warmup=0, iters=3)
    assert t > 0.0


def test_unavailable_backend_is_skipped_not_crashed():
    k = PortableKernel(name="avail")
    k.add_backend("xla", lambda x: x * 2.0)
    k.add_backend("pallas", lambda x: (_ for _ in ()).throw(
        RuntimeError("must never run")), available=lambda: False)

    # default selection never lands on the unavailable backend
    assert k.default_backend() == "xla"
    assert k.available_backends() == ["xla"]

    # timing / validation refuse with the typed error, not a crash inside
    with pytest.raises(BackendUnavailableError):
        k.time_backend(jnp.ones(4), backend="pallas", iters=1, warmup=0)
    with pytest.raises(BackendUnavailableError):
        k.validate(jnp.ones(4), backend="pallas")

    # the sweep records a reason instead of raising
    r = tuning.tune(k, jnp.ones(4), backend="pallas")
    assert r.skipped is not None and "unavailable" in r.skipped
    assert r.swept == []


def test_default_backend_falls_back_past_unavailable_oracle():
    k = PortableKernel(name="noora")
    k.add_backend("xla", lambda x: x, available=lambda: False)
    k.add_backend("alt", lambda x: x)
    assert k.default_backend() == "alt"
    k2 = PortableKernel(name="nothing")
    k2.add_backend("xla", lambda x: x, available=lambda: False)
    with pytest.raises(BackendUnavailableError):
        k2.default_backend()


def test_registry_get_keyerror_lists_registered_names():
    r = KernelRegistry()
    r.register(PortableKernel(name="alpha"))
    r.register(PortableKernel(name="beta"))
    with pytest.raises(KeyError, match="alpha.*beta"):
        r.get("nope")


# --------------------------------------------------------------------------
# tuning sweep + cache
# --------------------------------------------------------------------------
def test_tune_is_deterministic_and_cache_hit_skips_retiming(tmp_path):
    calls = {"n": 0}
    k = _toy_kernel(calls)
    cache = tuning.TuningCache(path=tmp_path / "tuning.json")
    x = jnp.ones(16)

    r1 = tuning.tune(k, x, backend="fast", cache=cache, iters=2, warmup=1)
    assert not r1.cached
    assert r1.params["block"] in (4, 8, 16)
    assert len(r1.swept) == 3
    n_after_first = calls["n"]
    assert n_after_first > 0

    # same key -> served from cache, the backend is never invoked again
    r2 = tuning.tune(k, x, backend="fast", cache=cache, iters=2, warmup=1)
    assert r2.cached
    assert r2.params == r1.params
    assert r2.seconds == r1.seconds
    assert calls["n"] == n_after_first

    # a fresh cache object re-reads the persisted file (not process state)
    r3 = tuning.tune(k, x, backend="fast",
                     cache=tuning.TuningCache(path=tmp_path / "tuning.json"),
                     iters=2, warmup=1)
    assert r3.cached and r3.params == r1.params

    # a different shape is a different key -> re-tunes
    r4 = tuning.tune(k, jnp.ones(32), backend="fast", cache=cache, iters=2,
                     warmup=1)
    assert not r4.cached


def test_truncated_sweep_never_poisons_the_cache(tmp_path):
    """A smoke-lane sweep (max_points) shares its key with the full run and
    must therefore not persist its partial search result."""
    calls = {"n": 0}
    k = _toy_kernel(calls)
    cache = tuning.TuningCache(path=tmp_path / "tuning.json")
    x = jnp.ones(16)

    r1 = tuning.tune(k, x, backend="fast", cache=cache, iters=1, warmup=0,
                     max_points=2)
    assert not r1.cached and len(r1.swept) == 2
    assert len(cache) == 0

    # the full sweep then runs (no stale hit) and is the one that persists
    r2 = tuning.tune(k, x, backend="fast", cache=cache, iters=1, warmup=0)
    assert not r2.cached and len(r2.swept) == 3
    assert len(cache) == 1


def test_cache_put_merges_on_disk_entries(tmp_path):
    """Two cache objects on the same file (concurrent processes) must not
    erase each other's entries on write."""
    k = _toy_kernel({"n": 0})
    path = tmp_path / "tuning.json"
    a, b = tuning.TuningCache(path=path), tuning.TuningCache(path=path)
    key_a = tuning.make_key(k, jnp.ones(16), backend="fast")
    key_b = tuning.make_key(k, jnp.ones(32), backend="fast")
    a.get(key_a)  # force both to load the (empty) file now
    b.get(key_b)
    a.put(key_a, {"block": 4}, 1e-6)
    b.put(key_b, {"block": 8}, 2e-6)
    fresh = tuning.TuningCache(path=path)
    assert fresh.get(key_a) == {"params": {"block": 4}, "seconds": 1e-6,
                                "search": "exhaustive"}
    assert fresh.get(key_b) == {"params": {"block": 8}, "seconds": 2e-6,
                                "search": "exhaustive"}


def test_tuning_key_separates_shape_dtype_backend():
    k = _toy_kernel({"n": 0})
    k1 = tuning.make_key(k, jnp.ones(16), backend="fast")
    k2 = tuning.make_key(k, jnp.ones(32), backend="fast")
    k3 = tuning.make_key(k, jnp.ones(16, jnp.bfloat16), backend="fast")
    k4 = tuning.make_key(k, jnp.ones(16), backend="xla")
    assert len({k1.as_str(), k2.as_str(), k3.as_str(), k4.as_str()}) == 4


def test_tuning_key_separates_device_count(monkeypatch):
    """num_shards tuned under 8 devices must not be replayed on a 2-device
    host — the key carries the device count."""
    k = _toy_kernel({"n": 0})
    x = jnp.ones(16)
    k1 = tuning.make_key(k, x, backend="fast")
    forced = k1.devices + 7
    monkeypatch.setattr(tuning.jax, "device_count", lambda: forced,
                        raising=True)
    k2 = tuning.make_key(k, x, backend="fast")
    assert k1.devices != k2.devices
    assert k1.as_str() != k2.as_str()


def test_constraint_filters_sweep_points():
    k = PortableKernel(name="constrained")
    k.add_backend("xla", lambda x: x)
    k.add_backend("fast", lambda x, *, block=4: x + x)
    k.declare_tunables(
        "fast", block=(4, 8, 16),
        constraint=lambda p, x, **kw: x.shape[0] % p["block"] == 0)
    r = tuning.tune(k, jnp.ones(8), backend="fast", iters=1, warmup=0)
    assert [p["block"] for p, _ in r.swept] == [4, 8]


def test_call_tuned_uses_cached_params(tmp_path):
    seen = []
    k = PortableKernel(name="tunedcall")
    k.add_backend("xla", lambda x: x)

    def fast(x, *, block=8):
        seen.append(block)
        return x + x

    k.add_backend("fast", fast)
    k.declare_tunables("fast", block=(4, 8, 16))
    cache = tuning.TuningCache(path=tmp_path / "t.json")
    x = jnp.ones(16)

    # miss -> declared default
    k(x, backend="fast", tuned=True, tuning_cache=cache)
    assert seen[-1] == 8

    key = tuning.make_key(k, x, backend="fast")
    cache.put(key, {"block": 16}, 1e-6)
    k(x, backend="fast", tuned=True, tuning_cache=cache)
    assert seen[-1] == 16

    # explicit kwargs always win over the cache
    k(x, backend="fast", tuned=True, tuning_cache=cache, block=4)
    assert seen[-1] == 4


# --------------------------------------------------------------------------
# tuple-valued tunables (the stencil's shard_grid=(sz, sy) axis)
# --------------------------------------------------------------------------
def _grid_valued_kernel(seen):
    """A kernel whose tunable is a *tuple* (like the stencil's 2-D shard
    grid) with a divisibility constraint over the concrete input."""
    k = PortableKernel(name="tuplegrid")
    k.add_backend("xla", lambda x: x)

    def fast(x, *, grid=(2, 1)):
        seen.append(tuple(grid))
        return x + x

    k.add_backend("fast", fast)
    k.declare_tunables(
        "fast", grid=((2, 1), (4, 1), (2, 2), (3, 2)),
        constraint=lambda p, x, **kw: x.shape[0] % p["grid"][0] == 0)
    return k


def test_tuple_valued_tunables_sweep_and_constrain():
    seen = []
    k = _grid_valued_kernel(seen)
    r = tuning.tune(k, jnp.ones(8), backend="fast", iters=1, warmup=0)
    # (3, 2) violates the divisibility constraint and is never timed
    assert [p["grid"] for p, _ in r.swept] == [(2, 1), (4, 1), (2, 2)]
    assert r.params["grid"] in ((2, 1), (4, 1), (2, 2))
    assert isinstance(r.params["grid"], tuple)


def test_tuple_valued_params_round_trip_the_json_cache(tmp_path):
    """JSON has no tuples: cached grid params come back as lists and must
    be re-tupled before they are compared, hashed, or re-injected."""
    seen = []
    k = _grid_valued_kernel(seen)
    cache = tuning.TuningCache(path=tmp_path / "t.json")
    x = jnp.ones(8)

    r1 = tuning.tune(k, x, backend="fast", cache=cache, iters=1, warmup=0)
    assert not r1.cached

    # a fresh cache object re-reads the persisted JSON (lists on disk)
    fresh = tuning.TuningCache(path=tmp_path / "t.json")
    r2 = tuning.tune(k, x, backend="fast", cache=fresh, iters=1, warmup=0)
    assert r2.cached
    assert r2.params == r1.params
    assert isinstance(r2.params["grid"], tuple)

    # the tuned-call path re-injects a tuple too
    best = tuning.cached_best_params(k, x, backend="fast", cache=fresh)
    assert best == r1.params and isinstance(best["grid"], tuple)
    k(x, backend="fast", tuned=True, tuning_cache=fresh)
    assert seen[-1] == r1.params["grid"]


def test_params_from_cache_is_shallow_and_typed():
    assert tuning.params_from_cache(
        {"grid": [2, 4], "by": 8, "decomp": "pencil", "overlap": True}) == {
            "grid": (2, 4), "by": 8, "decomp": "pencil", "overlap": True}


# --------------------------------------------------------------------------
# cache invalidation on kernel-code change (schema v2)
# --------------------------------------------------------------------------
def test_cache_key_embeds_backend_code_hash():
    """Editing a backend's body must change its tuning key — stale tuned
    params must not survive kernel edits."""
    k1 = PortableKernel(name="codehash")
    k1.add_backend("fast", lambda x, *, block=8: x + x)
    k2 = PortableKernel(name="codehash")
    k2.add_backend("fast", lambda x, *, block=8: x * 2.0 + 0.0)
    x = jnp.ones(16)
    key1 = tuning.make_key(k1, x, backend="fast")
    key2 = tuning.make_key(k2, x, backend="fast")
    assert key1.code != key2.code
    assert key1.as_str() != key2.as_str()
    # identical code -> identical key (stable across calls)
    assert tuning.make_key(k1, x, backend="fast").as_str() == key1.as_str()


def test_code_hash_unwraps_jit_and_partial():
    import functools as ft

    import jax

    def body(x, *, block=8):
        return x + x

    h = tuning.backend_code_hash(body)
    assert tuning.backend_code_hash(jax.jit(body)) == h
    assert tuning.backend_code_hash(
        ft.partial(jax.jit(body), block=4)) == h


def test_code_hash_sees_through_thin_wrappers(tmp_path, monkeypatch):
    """Registered backends are mostly 3-line wrappers around a kernel
    module; editing the *kernel body* must still change the hash."""
    import importlib
    import sys
    import textwrap

    # a /repro/-pathed package (the hash only follows repro source files)
    # importable *beside* the real one: top-level name `fakekern`
    pkg = tmp_path / "repro" / "fakekern"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")

    def write_kernel(body):
        (pkg / "kernel.py").write_text(textwrap.dedent(f"""
            def laplacian(u):
                return {body}
        """))
        (pkg / "ops.py").write_text(textwrap.dedent("""
            from fakekern import kernel as K

            def wrapper(u):
                return K.laplacian(u)
        """))

    write_kernel("u + u")
    monkeypatch.syspath_prepend(str(tmp_path / "repro"))
    for mod in [m for m in sys.modules if m.startswith("fakekern")]:
        del sys.modules[mod]
    import fakekern.ops as ops
    h1 = tuning.backend_code_hash(ops.wrapper)

    write_kernel("u * 2.0")  # kernel edit; wrapper text unchanged
    importlib.reload(sys.modules["fakekern.kernel"])
    ops = importlib.reload(ops)
    assert tuning.backend_code_hash(ops.wrapper) != h1
    del sys.modules["fakekern"], sys.modules["fakekern.kernel"]
    del sys.modules["fakekern.ops"]


def test_code_hash_sees_through_lru_cache_dispatch(tmp_path, monkeypatch):
    """The sharded backends dispatch through lru_cache-wrapped shard_map
    builders; a kernel-body edit must still reach the hash through that
    wrapper (the regression: lru_cache wrappers are not isfunction and the
    walk stopped dead at them)."""
    import importlib
    import sys
    import textwrap

    pkg = tmp_path / "repro" / "fakecached"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")

    def write_kernel(body):
        (pkg / "kernel.py").write_text(textwrap.dedent(f"""
            def laplacian(u):
                return {body}
        """))
        (pkg / "ops.py").write_text(textwrap.dedent("""
            import functools

            from fakecached import kernel as K

            @functools.lru_cache(maxsize=None)
            def _build():
                return K.laplacian

            def wrapper(u):
                return _build()(u)
        """))

    write_kernel("u + u")
    monkeypatch.syspath_prepend(str(tmp_path / "repro"))
    for mod in [m for m in sys.modules if m.startswith("fakecached")]:
        del sys.modules[mod]
    import fakecached.ops as ops
    h1 = tuning.backend_code_hash(ops.wrapper)

    write_kernel("u * 2.0")  # kernel edit; ops.py text unchanged
    importlib.reload(sys.modules["fakecached.kernel"])
    ops = importlib.reload(ops)
    assert tuning.backend_code_hash(ops.wrapper) != h1
    del sys.modules["fakecached"], sys.modules["fakecached.kernel"]
    del sys.modules["fakecached.ops"]


def test_code_hash_reaches_kernel_refs_from_sharded_backends():
    """The registered xla_shard wrappers must hash the kernel ref files
    they ultimately dispatch into (through lru_cache builders and the
    _STREAM_LOCAL dispatch table), or editing a kernel would silently keep
    serving its stale tuned shard params."""
    import repro.kernels  # noqa: F401
    from repro.distributed import domain

    parts = tuning._referenced_file_hashes(domain.laplacian_shard)
    assert any("stencil7" in p and "ref.py" in p for p in parts), parts
    fns = domain.stream_shard_fns()
    parts = tuning._referenced_file_hashes(fns["copy"])
    assert any("babelstream" in p and "ref.py" in p for p in parts), parts
    # keys are repro-relative, never absolute: hosts sharing a cache via
    # $REPRO_TUNING_CACHE must agree on the hash for byte-identical code
    assert all(p.startswith("repro/") for p in parts), parts


def test_code_hash_distinguishes_factory_closures():
    """Factory-made wrappers share source; their closed-over constants
    (which op they dispatch) must still separate the hashes."""
    def make(op):
        def run(x):
            return x + 1 if op == "inc" else x - 1
        return run

    assert (tuning.backend_code_hash(make("inc"))
            != tuning.backend_code_hash(make("dec")))
    # the registered stream shards are exactly this shape
    from repro.distributed.domain import stream_shard_fns
    fns = stream_shard_fns()
    assert (tuning.backend_code_hash(fns["copy"])
            != tuning.backend_code_hash(fns["add"]))


def test_edited_kernel_invalidates_cache_entry(tmp_path):
    calls = {"n": 0}
    cache = tuning.TuningCache(path=tmp_path / "tuning.json")
    x = jnp.ones(16)

    r1 = tuning.tune(_toy_kernel(calls), x, backend="fast", cache=cache,
                     iters=1, warmup=0)
    assert not r1.cached

    edited = PortableKernel(name="toy")
    edited.add_backend("xla", lambda x: x * 2.0)

    def fast(x, *, block=8):
        calls["n"] += 1
        return x + x + 0.0  # the "edit"

    edited.add_backend("fast", fast)
    edited.declare_tunables("fast", block=(4, 8, 16))
    r2 = tuning.tune(edited, x, backend="fast", cache=cache, iters=1,
                     warmup=0)
    assert not r2.cached  # code changed -> new key -> fresh sweep
    assert len(cache) == 2


def test_cache_v1_files_are_discarded(tmp_path):
    """Pre-v2 cache files lack code-hash keys: loading must treat them as
    empty (that IS the invalidation), and the next put writes v2."""
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps({"old|key": {"params": {"block": 4},
                                            "seconds": 1e-6}}))
    k = _toy_kernel({"n": 0})
    cache = tuning.TuningCache(path=path)
    assert len(cache) == 0
    key = tuning.make_key(k, jnp.ones(16), backend="fast")
    assert cache.get(key) is None
    cache.put(key, {"block": 8}, 2e-6)
    raw = json.loads(path.read_text())
    assert raw["schema"] == tuning.CACHE_SCHEMA
    assert "old|key" not in raw["entries"]


# --------------------------------------------------------------------------
# budgeted coordinate descent (large grids)
# --------------------------------------------------------------------------
def _grid_kernel(cost):
    """5x5 grid (> COORD_THRESHOLD) with a deterministic fake timer:
    ``cost(point) -> seconds``.  Timing nondeterminism would make search-
    behavior assertions flaky, so time_backend is replaced wholesale."""
    k = PortableKernel(name="grid")
    k.add_backend("xla", lambda x: x)
    k.add_backend("fast", lambda x, *, block=4, rows=1: x + x)
    k.declare_tunables("fast", block=(4, 8, 16, 32, 64),
                       rows=(1, 2, 4, 8, 16))
    timed = []
    k.time_backend = lambda *a, backend, iters=3, warmup=1, **kw: (
        timed.append((kw["block"], kw["rows"])),
        cost(kw["block"], kw["rows"]))[1]
    return k, timed


def test_auto_switches_to_coordinate_descent_above_threshold():
    assert 25 > tuning.COORD_THRESHOLD
    # separable bowl with minimum at (16, 4): coordinate descent finds it
    k, timed = _grid_kernel(lambda b, r: abs(b - 16) + 10 * abs(r - 4) + 1.0)
    r = tuning.tune(k, jnp.ones(16), backend="fast")
    assert r.search == "coordinate"
    assert r.params == {"block": 16, "rows": 4}
    budget = 2 * (5 + 5)
    assert len(set(timed)) <= budget < 25  # never the exhaustive sweep
    assert len(r.swept) == len(set(timed))


def test_small_grids_stay_exhaustive_and_budget_is_honored():
    k, timed = _grid_kernel(lambda b, r: 1.0)
    r = tuning.tune(k, jnp.ones(16), backend="fast", search="exhaustive")
    assert r.search == "exhaustive" and len(r.swept) == 25

    k2, timed2 = _grid_kernel(lambda b, r: 1.0 / (b * r))
    r2 = tuning.tune(k2, jnp.ones(16), backend="fast", search="coordinate",
                     budget=3)
    assert r2.search == "coordinate" and len(set(timed2)) <= 3

    with pytest.raises(ValueError, match="search mode"):
        tuning.tune(k, jnp.ones(16), backend="fast", search="bogus")


def test_max_points_bounds_and_unpersists_coordinate_descent(tmp_path):
    """The smoke lane's max_points must cap coordinate descent too, and a
    max_points-bounded result must never reach the cache (same contract as
    truncated exhaustive sweeps)."""
    cache = tuning.TuningCache(path=tmp_path / "t.json")
    k, timed = _grid_kernel(lambda b, r: 1.0 / (b * r))
    r = tuning.tune(k, jnp.ones(16), backend="fast", cache=cache,
                    max_points=2)  # auto -> coordinate (25 > threshold)
    assert r.search == "coordinate"
    assert len(set(timed)) <= 2
    assert len(cache) == 0


def test_coordinate_results_never_serve_exhaustive_requests(tmp_path):
    """A budgeted search result is cached with provenance and must not
    masquerade as the exhaustive optimum."""
    cache = tuning.TuningCache(path=tmp_path / "t.json")
    x = jnp.ones(16)
    k, timed = _grid_kernel(lambda b, r: abs(b - 16) + abs(r - 4) + 1.0)

    r1 = tuning.tune(k, x, backend="fast", cache=cache)  # auto -> coordinate
    assert r1.search == "coordinate" and not r1.cached

    # same mode -> served from cache
    r2 = tuning.tune(k, x, backend="fast", cache=cache)
    assert r2.cached and r2.search == "coordinate"

    # exhaustive request ignores the budgeted entry, re-sweeps, overwrites
    n_before = len(timed)
    r3 = tuning.tune(k, x, backend="fast", cache=cache, search="exhaustive")
    assert not r3.cached and r3.search == "exhaustive"
    assert len(timed) == n_before + 25

    # ... after which even exhaustive callers hit the cache
    r4 = tuning.tune(k, x, backend="fast", cache=cache, search="exhaustive")
    assert r4.cached and r4.search == "exhaustive"


def test_registered_kernels_declare_tunable_spaces():
    import repro.kernels  # noqa: F401
    from repro.core.portable import registry
    for name, param in [("stencil7", "by"),
                        ("babelstream.triad", "block_rows"),
                        ("minibude.fasten", "pose_tile"),
                        ("hartree_fock.twoel", "i_tile"),
                        ("attention.flash", "bq"),
                        ("rwkv6.wkv", "chunk")]:
        space = registry.get(name).tunable_space("pallas_interpret")
        assert space is not None and param in space.params, name


# --------------------------------------------------------------------------
# registry-driven Eq.-4 sweep (tier-1 smoke)
# --------------------------------------------------------------------------
def test_portability_sweep_smoke(tmp_path):
    from benchmarks import portability

    artifact = portability.run(
        smoke=True,
        json_path=str(tmp_path / "BENCH_portability.json"),
        cache_path=str(tmp_path / "tuning.json"))

    on_disk = json.loads((tmp_path / "BENCH_portability.json").read_text())
    assert on_disk["schema"] == "repro.portability/v1"
    assert on_disk["smoke"] is True
    assert on_disk["phi"] == artifact["phi"]

    measured = [r for r in artifact["kernels"] if r["e_i"] is not None]
    apps = {r["app"] for r in measured}
    assert len(apps) >= 4, apps
    for r in measured:
        assert r["seconds_tuned"] <= r["seconds_default"] * 1.0 + 1e-12
        assert r["backend"] == "pallas_interpret"  # CPU host
        assert np.isfinite(r["e_i"]) and r["e_i"] > 0
    assert artifact["phi"]["overall"] is not None
    assert set(artifact["phi"]["per_app"]) == apps
