"""Registry-wide differential conformance suite.

Parametrizes over ``repro.core.conformance.conformance_pairs()`` — the
(kernel, backend) matrix derived from the *live* registry at collection
time, never a hand-written list — and asserts every backend matches its
oracle at the per-kernel tolerance (bitwise where PR 3/4 promised it, plus
the ``shard_pallas`` composites' bitwise-twin contract against their
single-device Pallas kernels).  Consequences of deriving from the registry:

  * a backend registered tomorrow (``shard_pallas`` today) gets a matrix
    cell for free, and dropping a registered backend from coverage is
    impossible — the parametrization *is* the registry;
  * a kernel registered without a conformance case FAILS its cells
    (coverage is mandatory, never silently absent);
  * backends this host cannot run surface as explicit pytest skips carrying
    the ``BackendUnavailableError`` reason — never silent passes.  The
    multi-device cells run for real in ``repro.distributed.selftest``'s
    ``conformance`` battery under 8 forced host devices.
"""

import jax
import pytest

from repro.core import conformance
from repro.core.portable import (BackendUnavailableError, PortableKernel,
                                 registry)

PAIRS = conformance.conformance_pairs()


@pytest.mark.parametrize(
    "kernel,backend", PAIRS, ids=[f"{k}-{b}" for k, b in PAIRS])
def test_backend_matches_oracle(kernel, backend):
    try:
        conformance.check_backend(kernel, backend)
    except BackendUnavailableError as exc:
        pytest.skip(f"{kernel}[{backend}] unavailable here: {exc}")


def test_every_registered_kernel_has_case_and_tolerance():
    """The coverage guard behind the matrix: a kernel missing from CASES /
    ORACLE_TOL would fail its cells with a pointed message — this test
    makes the gap visible as one line instead of N."""
    for name in registry.names():
        assert name in conformance.CASES, \
            f"kernel {name!r} has no conformance case"
        assert name in conformance.ORACLE_TOL, \
            f"kernel {name!r} has no conformance tolerance"


def test_pairs_derive_from_live_registry():
    """Registering a backend adds its matrix cell with no suite edit."""
    k = registry.get("stencil7")
    assert ("stencil7", "tmp_backend") not in conformance.conformance_pairs()
    k.add_backend("tmp_backend", k.backends["xla"].fn)
    try:
        assert ("stencil7", "tmp_backend") in conformance.conformance_pairs()
        # it is the oracle's own fn, so its cell passes immediately
        conformance.check_backend("stencil7", "tmp_backend")
    finally:
        del k.backends["tmp_backend"]
    assert ("stencil7", "tmp_backend") not in conformance.conformance_pairs()


def test_missing_case_fails_never_passes():
    """A kernel without a case must FAIL conformance, not skip or pass."""
    name = "tmp.caseless"
    k = PortableKernel(name=name)
    k.add_backend("xla", lambda x: x)
    registry._kernels[name] = k
    try:
        assert (name, "xla") in conformance.conformance_pairs()
        with pytest.raises(AssertionError, match="no conformance case"):
            conformance.check_backend(name, "xla")
    finally:
        del registry._kernels[name]


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="asserts the 1-device availability contract")
def test_unavailable_backend_surfaces_reasoned_error():
    """The skip path is an explicit BackendUnavailableError naming the
    backend and the available alternatives — what the parametrized test
    (and the selftest battery) turn into a reasoned skip."""
    for backend in ("xla_shard", "shard_pallas"):
        with pytest.raises(BackendUnavailableError, match=backend):
            conformance.check_backend("stencil7", backend)


def test_bitwise_promises_cover_the_sharded_oracles():
    """The PR-3/4 bitwise promises stay pinned in the tolerance table, and
    every bitwise-twin entry points at a registered backend."""
    for kernel in ("stencil7", "babelstream.copy", "babelstream.mul",
                   "babelstream.add", "babelstream.triad",
                   "minibude.fasten"):
        assert conformance.oracle_tolerance(kernel, "xla_shard") == "bitwise"
        twin = conformance.BITWISE_TWIN[(kernel, "shard_pallas")]
        assert twin in registry.get(kernel).backends
    # reductions are exempt: psum changes their summation order
    assert conformance.oracle_tolerance("babelstream.dot",
                                        "xla_shard") != "bitwise"
    assert ("babelstream.dot", "shard_pallas") not in \
        conformance.BITWISE_TWIN
    assert ("hartree_fock.twoel", "shard_pallas") not in \
        conformance.BITWISE_TWIN
