"""miniBUDE fasten Pallas kernel vs oracle + Eq. 3 FoM model."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import minibude_ops
from repro.kernels.minibude import ops


@pytest.mark.parametrize("natpro,natlig,nposes", [
    (64, 8, 256), (96, 16, 512), (32, 4, 128),
])
def test_matches_oracle(natpro, natlig, nposes):
    deck = ops.make_deck(natpro=natpro, natlig=natlig, nposes=nposes, seed=3)
    want = ops.fasten_xla(*deck)
    got = ops.fasten_pallas(*deck, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_deterministic_deck():
    d1 = ops.make_deck(natpro=16, natlig=4, nposes=128, seed=5)
    d2 = ops.make_deck(natpro=16, natlig=4, nposes=128, seed=5)
    for a, b in zip(d1, d2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_energy_scale_invariance_under_pose_order():
    """Permuting poses permutes energies (no cross-pose coupling)."""
    deck = ops.make_deck(natpro=32, natlig=4, nposes=256, seed=1)
    pp, ppar, lp, lpar, poses = deck
    e = np.asarray(ops.fasten_xla(pp, ppar, lp, lpar, poses))
    perm = np.random.default_rng(0).permutation(256)
    e_perm = np.asarray(ops.fasten_xla(pp, ppar, lp, lpar, poses[:, perm]))
    np.testing.assert_allclose(e_perm, e[perm], rtol=1e-5, atol=1e-5)


def test_eq3_ops_model():
    # paper Eq. 3
    ppwi, nl, np_, poses = 4, 26, 938, 65536
    per_wg = 28 * ppwi + nl * (2 + 18 * ppwi + np_ * (10 + 30 * ppwi))
    assert minibude_ops(ppwi, nl, np_, poses) == per_wg * poses / ppwi
