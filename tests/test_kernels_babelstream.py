"""BabelStream Pallas kernels vs pure-jnp oracle (interpret mode), with
shape/dtype sweeps and the paper's Eq. 2 byte model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import babelstream_bytes
from repro.core.portable import registry
from repro.kernels.babelstream import ops, ref

SIZES = [128 * 512, 128 * 2048]
DTYPES = [jnp.float32]


def _data(rng, n, dtype):
    a = jnp.asarray(rng.standard_normal(n), dtype)
    b = jnp.asarray(rng.standard_normal(n), dtype)
    return a, b


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_elementwise_ops_match_oracle(rng, n, dtype):
    a, b = _data(rng, n, dtype)
    np.testing.assert_allclose(ops.copy_pallas(a, interpret=True),
                               ref.copy(a), rtol=1e-6)
    np.testing.assert_allclose(ops.mul_pallas(a, interpret=True),
                               ref.mul(a), rtol=1e-6)
    np.testing.assert_allclose(ops.add_pallas(a, b, interpret=True),
                               ref.add(a, b), rtol=1e-6)
    np.testing.assert_allclose(ops.triad_pallas(a, b, interpret=True),
                               ref.triad(a, b), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", SIZES)
def test_dot_matches_oracle(rng, n):
    a, b = _data(rng, n, jnp.float32)
    got = ops.dot_pallas(a, b, interpret=True)
    want = ref.dot(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_dot_block_rows_sweep(rng):
    a, b = _data(rng, 128 * 1024, jnp.float32)
    want = ref.dot(a, b)
    for rows in (128, 256, 512):
        got = ops.dot_pallas(a, b, interpret=True, block_rows=rows)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_registry_backends_registered():
    for op in ("copy", "mul", "add", "triad", "dot"):
        k = registry.get(f"babelstream.{op}")
        assert {"xla", "pallas", "pallas_interpret"} <= set(k.backends)


def test_eq2_byte_model():
    # paper Eq. 2: copy/mul move 2 arrays, add/triad 3, dot 2
    n, isz = 1024, 4
    assert babelstream_bytes("copy", n, isz) == 2 * n * isz
    assert babelstream_bytes("add", n, isz) == 3 * n * isz
    assert babelstream_bytes("triad", n, isz) == 3 * n * isz
    assert babelstream_bytes("dot", n, isz) == 2 * n * isz
    with pytest.raises(ValueError):
        babelstream_bytes("nope", n, isz)
