"""Property-based invariants for the decomposition helpers and the
composite tile x shard tunable spaces.

Runs under real ``hypothesis`` when installed; on minimal hosts the
deterministic shim (``tests/_hypothesis_stub.py``, installed by conftest)
replays the strategy edges plus seeded draws, so the properties hold in
both lanes.  Every helper takes an injected ``device_count``, so the
invariants are checked for hypothetical topologies regardless of the
1-device pytest process.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.kernels  # noqa: F401  (registers the sharded backends)
from repro.core.portable import get_kernel
from repro.distributed import shard_pallas as sp
from repro.distributed.domain import (balanced_pencil_grid,
                                      resolve_num_shards,
                                      resolve_shard_grid)

LANES = 128


# --------------------------------------------------------------------------
# decomposition helpers
# --------------------------------------------------------------------------
@settings(max_examples=25)
@given(total=st.integers(min_value=2, max_value=96))
def test_balanced_pencil_grid_product_and_balance(total):
    g = balanced_pencil_grid(total)
    factorizations = [(total // sy, sy) for sy in range(2, total // 2 + 1)
                      if total % sy == 0 and total // sy >= 2]
    if g is None:
        # None exactly when no true 2-D grid exists (both factors >= 2)
        assert not factorizations
        return
    sz, sy = g
    assert sz * sy == total and sz >= 2 and sy >= 2
    # most balanced wins; ties prefer the z-major grid
    best = min(abs(a - b) for a, b in factorizations)
    assert abs(sz - sy) == best
    if any((b, a) == (sz, sy) for a, b in factorizations if a != b):
        assert sz >= sy


@settings(max_examples=25)
@given(total=st.integers(min_value=2, max_value=96),
       nz=st.sampled_from([2, 4, 6, 8, 12, 16, 24, 32]),
       ny=st.sampled_from([2, 3, 4, 8, 9, 16, 32]))
def test_balanced_pencil_grid_divisibility(total, nz, ny):
    g = balanced_pencil_grid(total, nz, ny)
    if g is None:
        # every candidate factorization violates a divisibility bound
        assert all(nz % a or ny % b
                   for b in range(2, total // 2 + 1) if total % b == 0
                   for a in [total // b] if a >= 2)
        return
    sz, sy = g
    assert sz * sy == total and sz >= 2 and sy >= 2
    assert nz % sz == 0 and ny % sy == 0


@settings(max_examples=25)
@given(extent=st.integers(min_value=2, max_value=64),
       dc=st.integers(min_value=2, max_value=16))
def test_resolve_num_shards_picks_largest_valid(extent, dc):
    try:
        s = resolve_num_shards(extent, None, device_count=dc)
    except ValueError:
        assert all(extent % c for c in range(2, min(dc, extent) + 1))
        return
    assert 2 <= s <= dc and extent % s == 0
    # maximal: nothing between s and the device budget divides the extent
    assert all(extent % c for c in range(s + 1, min(dc, extent) + 1))


@settings(max_examples=25)
@given(nz=st.sampled_from([4, 8, 16, 32]), ny=st.sampled_from([4, 8, 16, 32]),
       dc=st.integers(min_value=2, max_value=16),
       decomp=st.sampled_from(["slab", "pencil"]))
def test_resolve_shard_grid_invariants(nz, ny, dc, decomp):
    try:
        sz, sy = resolve_shard_grid(nz, ny, decomp=decomp, device_count=dc)
    except ValueError:
        return  # no valid grid on this hypothetical host
    assert nz % sz == 0 and ny % sy == 0
    assert 2 <= sz * sy <= dc
    if decomp == "slab":
        # slab is the sy == 1 special case of the grid resolution, and its
        # z split is exactly resolve_num_shards
        assert sy == 1
        assert sz == resolve_num_shards(nz, None, device_count=dc)
    else:
        assert sz >= 2 and sy >= 2


# --------------------------------------------------------------------------
# composite tile x shard spaces: every emitted point satisfies every
# cross-constraint, and the filter is EXACTLY the declared predicate
# --------------------------------------------------------------------------
@settings(max_examples=15)
@given(nz=st.sampled_from([4, 8, 16]), ny=st.sampled_from([8, 16, 32, 64]),
       dc=st.integers(min_value=2, max_value=12))
def test_stencil_composite_space_cross_constraints(nz, ny, dc):
    u = np.zeros((nz, ny, LANES), np.float32)
    space = get_kernel("stencil7").tunable_space("shard_pallas")
    pts = space.valid_points(u, device_count=dc)
    for p in pts:
        sz, sy = p["shard_grid"]
        assert 2 <= sz * sy <= dc
        assert nz % sz == 0 and ny % sy == 0
        # the tile tunable binds against the LOCAL (post-shard) block:
        # oversized tiles can never divide it
        assert p["by"] <= ny // sy
        assert (ny // sy) % p["by"] == 0
        if p["decomp"] == "pencil":
            assert sz >= 2 and sy >= 2
        else:
            assert sy == 1
    expect = [p for p in space.points()
              if sp.stencil_pallas_point_ok(p, nz, ny, dc)]
    assert pts == expect


@settings(max_examples=15)
@given(n=st.sampled_from([1 << 14, 1 << 15, 1 << 16, 1 << 17,
                          3 * (1 << 14)]),
       dc=st.integers(min_value=2, max_value=12))
def test_stream_composite_space_cross_constraints(n, dc):
    a = np.zeros((n,), np.float32)
    space = get_kernel("babelstream.triad").tunable_space("shard_pallas")
    pts = space.valid_points(a, device_count=dc)
    for p in pts:
        s, br = p["num_shards"], p["block_rows"]
        assert 2 <= s <= dc and n % s == 0
        assert (n // s) % (br * LANES) == 0
    expect = [p for p in space.points()
              if sp.stream_pallas_point_ok(p, n, dc)]
    assert pts == expect


@settings(max_examples=15)
@given(nposes=st.sampled_from([128, 256, 512, 1024]),
       dc=st.integers(min_value=2, max_value=12))
def test_bude_composite_space_cross_constraints(nposes, dc):
    deck = [None] * 4 + [np.zeros((6, nposes), np.float32)]
    space = get_kernel("minibude.fasten").tunable_space("shard_pallas")
    pts = space.valid_points(*deck, device_count=dc)
    for p in pts:
        s, pt = p["num_shards"], p["pose_tile"]
        assert 2 <= s <= dc and nposes % s == 0
        assert pt <= nposes // s and (nposes // s) % pt == 0
    expect = [p for p in space.points()
              if sp.bude_pallas_point_ok(p, nposes, dc)]
    assert pts == expect


@settings(max_examples=15)
@given(natoms=st.sampled_from([4, 8, 12, 16]),
       dc=st.integers(min_value=2, max_value=12))
def test_hf_composite_space_cross_constraints(natoms, dc):
    pos = np.zeros((natoms, 3), np.float32)
    space = get_kernel("hartree_fock.twoel").tunable_space("shard_pallas")
    pts = space.valid_points(pos, device_count=dc)
    for p in pts:
        s, it = p["num_shards"], p["i_tile"]
        assert 2 <= s <= dc and natoms % s == 0
        # Fock rows stay whole under the l-slab split, so i_tile binds
        # against the full atom count — and never exceeds it
        assert it <= natoms and natoms % it == 0
    expect = [p for p in space.points()
              if sp.hf_pallas_point_ok(p, natoms, dc)]
    assert pts == expect
