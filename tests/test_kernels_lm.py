"""LM Pallas kernels (flash attention, RWKV6 WKV) vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.rwkv6 import ops as wkv_ops


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 128)])
def test_flash_matches_oracle(rng, causal, window):
    B, H, Kv, S, Dh = 2, 4, 2, 512, 64
    q = _rand(rng, B, H, S, Dh)
    k = _rand(rng, B, Kv, S, Dh)
    v = _rand(rng, B, Kv, S, Dh)
    want = fa_ops.flash_xla(q, k, v, causal=causal, window=window)
    got = fa_ops.flash_pallas(q, k, v, causal=causal, window=window,
                              bq=128, bk=128, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 256), (256, 128)])
def test_flash_block_shape_sweep(rng, bq, bk):
    B, H, Kv, S, Dh = 1, 2, 1, 256, 32
    q = _rand(rng, B, H, S, Dh)
    k = _rand(rng, B, Kv, S, Dh)
    v = _rand(rng, B, Kv, S, Dh)
    want = fa_ops.flash_xla(q, k, v, causal=True)
    got = fa_ops.flash_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    B, H, Kv, S, Dh = 1, 2, 2, 256, 64
    q = _rand(rng, B, H, S, Dh).astype(jnp.bfloat16)
    k = _rand(rng, B, Kv, S, Dh).astype(jnp.bfloat16)
    v = _rand(rng, B, Kv, S, Dh).astype(jnp.bfloat16)
    want = fa_ops.flash_xla(q, k, v, causal=True).astype(jnp.float32)
    got = fa_ops.flash_pallas(q, k, v, causal=True, bq=128, bk=128,
                              interpret=True).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("chunk", [32, 64])
def test_wkv_matches_serial_oracle(rng, chunk):
    B, H, S, Dh = 2, 3, 128, 32
    r = _rand(rng, B, H, S, Dh, scale=0.5)
    k = _rand(rng, B, H, S, Dh, scale=0.5)
    v = _rand(rng, B, H, S, Dh, scale=0.5)
    lw = -jnp.exp(jnp.clip(_rand(rng, B, H, S, Dh), -8, 1))
    u = _rand(rng, H, Dh, scale=0.5)
    want = wkv_ops.wkv_xla(r, k, v, lw, u)
    got = wkv_ops.wkv_pallas(r, k, v, lw, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_wkv_state_carried_across_chunks(rng):
    """First-chunk output can't depend on later tokens; later chunks must.
    Slow decay (-0.01/step) so cross-chunk state influence is measurable."""
    B, H, S, Dh = 1, 1, 64, 16
    r = _rand(rng, B, H, S, Dh, scale=0.5)
    k = _rand(rng, B, H, S, Dh, scale=0.5)
    v = _rand(rng, B, H, S, Dh, scale=0.5)
    lw = jnp.full((B, H, S, Dh), -0.01)
    u = _rand(rng, H, Dh, scale=0.5)
    y1 = wkv_ops.wkv_pallas(r, k, v, lw, u, chunk=32, interpret=True)
    v2 = v.at[:, :, 0].add(10.0)   # perturb an early token's value
    y2 = wkv_ops.wkv_pallas(r, k, v2, lw, u, chunk=32, interpret=True)
    # token 0 output unchanged? (depends only on its own diag term - yes
    # via u bonus it does change). Check instead: later chunk outputs differ
    assert float(jnp.max(jnp.abs(y1[:, :, 40:] - y2[:, :, 40:]))) > 1e-6
    # and causality: perturbing a LATE token leaves early outputs unchanged
    v3 = v.at[:, :, 50].add(10.0)
    y3 = wkv_ops.wkv_pallas(r, k, v3, lw, u, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y3[:, :, :50]),
                               np.asarray(y1[:, :, :50]), rtol=1e-5,
                               atol=1e-5)
