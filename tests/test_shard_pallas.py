"""shard_pallas composite backends: registry wiring, local-tile helpers,
and the tunable-space validity audit (tile points larger than the local
post-shard block must be rejected, pinned at the one-plane-per-shard and
smallest-``by`` edges).

The multi-device *execution* checks — bitwise equality to the single-device
Pallas backends under 8 forced host devices — live in
``repro.distributed.selftest`` (``shard_pallas_*`` batteries) because this
pytest process is pinned to the 1-device topology.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers the sharded backends)
from repro.core import tuning
from repro.core.portable import BackendUnavailableError, get_kernel
from repro.distributed import shard_pallas as sp
from repro.distributed.domain import SHARD_GRID, STENCIL_SHARD_GRIDS
from repro.kernels.babelstream import kernel as stream_K
from repro.kernels.hartree_fock import kernel as hf_K
from repro.kernels.minibude import kernel as mb_K
from repro.kernels.stencil7 import kernel as s7_K

SHARDED_KERNELS = ["stencil7", "babelstream.copy", "babelstream.mul",
                   "babelstream.add", "babelstream.triad", "babelstream.dot",
                   "minibude.fasten", "hartree_fock.twoel"]

#: family -> the tile axis its composite space shares with the
#: single-device pallas space
TILE_AXES = {
    "stencil7": ("by", s7_K.BY_GRID),
    "babelstream": ("block_rows", stream_K.BLOCK_ROWS_GRID),
    "minibude.fasten": ("pose_tile", mb_K.POSE_TILE_GRID),
    "hartree_fock.twoel": ("i_tile", hf_K.I_TILE_GRID),
}


# --------------------------------------------------------------------------
# registry wiring
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", SHARDED_KERNELS)
def test_registered_with_composite_tile_x_shard_space(name):
    k = get_kernel(name)
    assert sp.PALLAS_SHARD_BACKEND in k.backends, name
    space = k.tunable_space(sp.PALLAS_SHARD_BACKEND)
    assert space is not None
    family = name.split(".")[0] if name.startswith("babelstream") else name
    tile, grid = TILE_AXES[family]
    if name == "stencil7":
        # decomposition axes compose with the y-tile in ONE space
        assert set(space.params) == {"decomp", "shard_grid", "by"}
        assert tuple(space.params["shard_grid"]) == STENCIL_SHARD_GRIDS
    else:
        assert set(space.params) == {"num_shards", tile}
        assert tuple(space.params["num_shards"]) == SHARD_GRID
    # the tile axis IS the single-device pallas grid — same kernel source,
    # same tunables, now composed with the shard axes
    assert tuple(space.params[tile]) == tuple(grid)
    assert tuple(space.params[tile]) == \
        tuple(k.tunable_space("pallas_interpret").params[tile])


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="asserts the 1-device availability contract")
def test_unavailable_on_single_device():
    k = get_kernel("stencil7")
    assert not k.backends[sp.PALLAS_SHARD_BACKEND].is_available()
    assert sp.PALLAS_SHARD_BACKEND not in k.available_backends()
    assert k.default_backend() != sp.PALLAS_SHARD_BACKEND
    with pytest.raises(BackendUnavailableError):
        k.time_backend(jnp.ones((4, 8, 128)),
                       backend=sp.PALLAS_SHARD_BACKEND, iters=1, warmup=0)
    r = tuning.tune(k, jnp.ones((4, 8, 128)),
                    backend=sp.PALLAS_SHARD_BACKEND)
    assert r.skipped is not None and "unavailable" in r.skipped


def test_availability_composes_multi_device_and_execution_tier():
    # multi_device() is False here, so the conjunction is False regardless
    # of the execution tier; the tier predicate itself is True (interpret
    # mode runs on any live jax backend)
    assert sp._interpret_capable()
    assert sp.default_interpret() == (not jax.devices()[0].platform == "tpu")
    if jax.device_count() == 1:
        assert not sp.shard_pallas_available()


# --------------------------------------------------------------------------
# local-tile helpers (the kernel-layer local-block entry points)
# --------------------------------------------------------------------------
def test_local_block_by_picks_and_validates():
    assert s7_K.local_block_by(64) == 64
    assert s7_K.local_block_by(32) == 32
    assert s7_K.local_block_by(24) == 8
    assert s7_K.local_block_by(64, 16) == 16
    with pytest.raises(ValueError, match="does not divide"):
        s7_K.local_block_by(32, 64)  # tile larger than the local block
    with pytest.raises(ValueError, match="no declared y-tile"):
        s7_K.local_block_by(4)


def test_local_block_rows_picks_and_validates():
    assert stream_K.local_block_rows(1024 * 128) == 1024
    assert stream_K.local_block_rows(128 * 128) == 128
    assert stream_K.local_block_rows(1024 * 128, 256) == 256
    with pytest.raises(ValueError, match="does not tile"):
        stream_K.local_block_rows(128 * 128, 256)
    with pytest.raises(ValueError, match="no declared row tile"):
        stream_K.local_block_rows(64 * 128)


def test_local_pose_tile_and_i_tile():
    assert mb_K.local_pose_tile(256) == 256
    assert mb_K.local_pose_tile(192) == 64
    assert mb_K.local_pose_tile(256, 64) == 64
    with pytest.raises(ValueError):
        mb_K.local_pose_tile(32)
    assert hf_K.local_i_tile(16) == 16
    assert hf_K.local_i_tile(8) == 8
    assert hf_K.local_i_tile(8, 4) == 4
    with pytest.raises(ValueError):
        hf_K.local_i_tile(8, 16)  # tile larger than the row count


# --------------------------------------------------------------------------
# validity audit: tiles never exceed the local (post-shard) block extent
# --------------------------------------------------------------------------
def _stencil_points(u, dc=8):
    space = get_kernel("stencil7").tunable_space(sp.PALLAS_SHARD_BACKEND)
    return space.valid_points(u, device_count=dc)


def test_stencil_space_rejects_tiles_larger_than_local_block():
    u = np.zeros((8, 16, 128), np.float32)
    pts = _stencil_points(u)
    assert pts
    for p in pts:
        assert p["by"] <= 16 // p["shard_grid"][1]
    # pencil (2,4) leaves a 4-wide local block: below every declared tile,
    # so that grid vanishes from the space entirely
    assert all(p["shard_grid"] != (2, 4) for p in pts)
    # (2,2)/(4,2) leave 8: only the smallest tile survives
    assert {p["by"] for p in pts if p["shard_grid"] == (2, 2)} == {8}
    # slab keeps the full ny=16
    assert {p["by"] for p in pts if p["decomp"] == "slab"} == {8, 16}


def test_stencil_space_one_plane_per_shard_edge():
    """nz == total shards leaves one z plane per shard — a legal block for
    the padded-slab composite, so the point must survive the audit."""
    u = np.zeros((8, 64, 128), np.float32)
    pts = _stencil_points(u)
    assert {"decomp": "slab", "shard_grid": (8, 1), "by": 64} in pts
    assert {p["by"] for p in pts if p["shard_grid"] == (8, 1)} == \
        {8, 16, 32, 64}


def test_stencil_space_smallest_by_edge():
    """ny == smallest declared tile: exactly one y-tile survives, on slab
    grids only (any pencil split would undercut the smallest tile)."""
    u = np.zeros((8, 8, 128), np.float32)
    pts = _stencil_points(u)
    assert pts and all(p["by"] == 8 for p in pts)
    assert all(p["decomp"] == "slab" for p in pts)


def test_stream_space_rejects_oversized_block_rows():
    # 2^16 elements: 8 shards leave 64 rows per shard — below the smallest
    # declared row tile, so num_shards=8 vanishes; 2 and 4 survive with
    # the tiles that still fit
    n = 1 << 16
    a = np.zeros((n,), np.float32)
    space = get_kernel("babelstream.triad").tunable_space(
        sp.PALLAS_SHARD_BACKEND)
    pts = space.valid_points(a, device_count=8)
    assert pts
    assert all(p["num_shards"] != 8 for p in pts)
    for p in pts:
        assert (n // p["num_shards"]) % (p["block_rows"] * 128) == 0
    assert {p["block_rows"] for p in pts if p["num_shards"] == 4} == {128}


def test_hf_space_rejects_i_tile_larger_than_atoms():
    pos = np.zeros((8, 3), np.float32)
    space = get_kernel("hartree_fock.twoel").tunable_space(
        sp.PALLAS_SHARD_BACKEND)
    pts = space.valid_points(pos, device_count=8)
    assert pts
    assert all(p["i_tile"] <= 8 for p in pts)
    assert {p["num_shards"] for p in pts} == {2, 4, 8}


def test_single_device_pallas_space_still_guards_whole_domain():
    """The audit covers the unsharded spaces too: the single-device pallas
    grid must reject tiles larger than the (whole-domain) extent."""
    u = np.zeros((8, 16, 128), np.float32)
    pts = get_kernel("stencil7").tunable_space("pallas_interpret") \
        .valid_points(u)
    assert pts and all(p["by"] <= 16 for p in pts)
    deck = [None] * 4 + [np.zeros((6, 128), np.float32)]
    pts = get_kernel("minibude.fasten").tunable_space("pallas_interpret") \
        .valid_points(*deck)
    assert pts and all(p["pose_tile"] <= 128 for p in pts)
