"""Core tests: portable registry, Phi metric (Eq. 4), HLO cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hlo_analysis import (parse_collective_bytes,
                                     parse_shape_bytes, xla_cost_analysis)
from repro.core.hlo_cost import analyze_hlo
from repro.core.metrics import Efficiency, phi_bar
from repro.core.portable import PortableKernel


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def test_portable_kernel_backend_selection_and_validation():
    k = PortableKernel(name="t", oracle="xla")
    k.add_backend("xla", lambda x: x * 2.0)
    k.add_backend("fast", lambda x: x + x)
    k.validate(jnp.ones(4), backend="fast")
    assert k.default_backend() == "xla"     # CPU host: no pallas
    with pytest.raises(KeyError):
        k.backend("missing")


def test_portable_kernel_fom():
    k = PortableKernel(name="t2", flops_model=lambda x: 100.0,
                       bytes_model=lambda x: 50.0)
    fom = k.figure_of_merit(1e-6, None)
    assert abs(fom["gflops_per_s"] - 0.1) < 1e-9
    assert abs(fom["gbytes_per_s"] - 0.05) < 1e-9


# --------------------------------------------------------------------------
# Eq. 4 — Phi metric
# --------------------------------------------------------------------------
def test_phi_bar_paper_table5_stencil():
    """Reproduce Table 5: stencil Phi = mean(0.82, 1.00, 0.87, 1.00) = 0.92."""
    terms = [Efficiency("H100", "fp32", 0.82, 1.0),
             Efficiency("MI300A", "fp32", 1.00, 1.0),
             Efficiency("H100", "fp64", 0.87, 1.0),
             Efficiency("MI300A", "fp64", 1.00, 1.0)]
    assert abs(phi_bar(terms) - 0.9225) < 1e-9


@settings(max_examples=100, deadline=None)
@given(effs=st.lists(st.floats(0.01, 10.0), min_size=1, max_size=10))
def test_phi_bar_bounded_by_extremes(effs):
    terms = [Efficiency("p", str(i), e, 1.0) for i, e in enumerate(effs)]
    phi = phi_bar(terms)
    assert min(effs) - 1e-9 <= phi <= max(effs) + 1e-9


def test_phi_bar_empty_raises():
    with pytest.raises(ValueError):
        phi_bar([])


# --------------------------------------------------------------------------
# HLO parsing / cost model
# --------------------------------------------------------------------------
def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert parse_shape_bytes("(f32[8], s32[2])") == 8 * 4 + 2 * 4
    assert parse_shape_bytes("f32[]") == 4


def test_collective_parse_counts_kinds():
    hlo = """
ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %ag = f32[256]{0} all-gather(%p), replica_groups={}
  %ar = f32[128]{0} all-reduce(%p), to_apply=%sum
  ROOT %out = f32[128]{0} copy(%ar)
}
"""
    stats = parse_collective_bytes(hlo)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 256 * 4
    assert stats.bytes_by_kind["all-reduce"] == 128 * 4


def test_hlo_cost_scan_trip_count():
    def g(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(g).lower(x, w).compile()
    got = analyze_hlo(c.as_text())
    expect = 8 * 2 * 64 * 128 * 128
    assert 0.95 < got.flops / expect < 1.3
    assert got.unknown_trip_loops == 0


def test_hlo_cost_matches_xla_on_flat_program():
    def f(a, b):
        return jax.nn.gelu(a @ b) @ b.T
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    got = analyze_hlo(c.as_text())
    xla = xla_cost_analysis(c)["flops"]
    assert abs(got.flops - xla) / xla < 0.2
