"""Domain-decomposition subsystem: registry wiring, shard-count constraints,
XLA_FLAGS hygiene, and the multi-device correctness battery.

pytest's process pins jax to the 1-device topology (conftest contract), so
the multi-device checks — sharded backends bit-matching their single-device
counterparts at 2/4/8 forced host devices, halo-exchange round-trips —
run ``repro.distributed.selftest`` in a subprocess with
``--xla_force_host_platform_device_count=8`` appended.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers xla_shard backends)
from repro.core.portable import BackendUnavailableError, get_kernel
from repro.core import tuning
from repro.distributed import collectives
from repro.distributed.domain import (OVERLAP_GRID, SHARD_BACKEND,
                                      SHARD_GRID, STENCIL_DECOMPS,
                                      STENCIL_SHARD_GRIDS,
                                      resolve_num_shards,
                                      resolve_shard_grid)
from repro.launch import hostsim

SHARDED_KERNELS = ["stencil7", "babelstream.copy", "babelstream.mul",
                   "babelstream.add", "babelstream.triad", "babelstream.dot",
                   "minibude.fasten", "hartree_fock.twoel"]

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _subprocess_env(devices=8):
    env = dict(os.environ)
    # force EXACTLY `devices`: the battery asserts shard counts that depend
    # on the topology, so an inherited device-count flag must not win here
    # (hostsim's respect-user-flags merge is the wrong tool for this env)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if not t.startswith(hostsim.DEVICE_COUNT_FLAG)]
    flags.append(f"{hostsim.DEVICE_COUNT_FLAG}={devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return env


# --------------------------------------------------------------------------
# registry wiring (1-device host: registered but unavailable)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", SHARDED_KERNELS)
def test_xla_shard_registered_with_shard_tunables(name):
    k = get_kernel(name)
    assert SHARD_BACKEND in k.backends, name
    space = k.tunable_space(SHARD_BACKEND)
    assert space is not None
    if name == "stencil7":
        # the decomposition *shape* is the tunable axis: slab vs pencil
        # grids plus halo/compute overlap
        assert set(space.params) == {"decomp", "shard_grid", "overlap"}
        assert tuple(space.params["decomp"]) == STENCIL_DECOMPS
        assert tuple(space.params["shard_grid"]) == STENCIL_SHARD_GRIDS
        assert tuple(space.params["overlap"]) == OVERLAP_GRID
    else:
        assert "num_shards" in space.params
        assert tuple(space.params["num_shards"]) == SHARD_GRID


@pytest.mark.skipif(jax.device_count() != 1,
                    reason="asserts the 1-device availability contract")
def test_xla_shard_unavailable_on_single_device():
    k = get_kernel("stencil7")
    assert not k.backends[SHARD_BACKEND].is_available()
    assert SHARD_BACKEND not in k.available_backends()
    assert k.default_backend() != SHARD_BACKEND
    with pytest.raises(BackendUnavailableError):
        k.time_backend(jnp.ones((4, 4, 8)), backend=SHARD_BACKEND, iters=1,
                       warmup=0)
    # the tuner records the reason instead of crashing — the sweep can walk
    # a catalogue containing multi-device backends on any host
    r = tuning.tune(k, jnp.ones((4, 4, 8)), backend=SHARD_BACKEND)
    assert r.skipped is not None and "unavailable" in r.skipped
    # and the Eq.-4 grid is empty here, so nothing would be timed anyway
    assert k.tunable_space(SHARD_BACKEND).valid_points(
        jnp.ones((4, 4, 8))) == []


# --------------------------------------------------------------------------
# shard-count resolution + ring permutations (pure logic, any host)
# --------------------------------------------------------------------------
def test_resolve_num_shards_validates_and_picks_largest():
    assert resolve_num_shards(16, 4, device_count=8) == 4
    assert resolve_num_shards(16, None, device_count=8) == 8
    assert resolve_num_shards(12, None, device_count=8) == 6
    assert resolve_num_shards(6, None, device_count=4) == 3
    with pytest.raises(ValueError, match="does not divide"):
        resolve_num_shards(15, 2, device_count=8)
    with pytest.raises(ValueError, match=">= 2"):
        resolve_num_shards(16, 1, device_count=8)
    with pytest.raises(ValueError, match="exceeds device_count"):
        resolve_num_shards(16, 16, device_count=8)
    with pytest.raises(ValueError, match="no valid shard count"):
        resolve_num_shards(7, None, device_count=4)  # 7 prime, > devices


def test_resolve_shard_grid_validates_and_picks():
    # explicit grids
    assert resolve_shard_grid(16, 16, decomp="slab", shard_grid=(4, 1),
                              device_count=8) == (4, 1)
    assert resolve_shard_grid(16, 16, decomp="pencil", shard_grid=(2, 4),
                              device_count=8) == (2, 4)
    # slab auto falls back to resolve_num_shards semantics
    assert resolve_shard_grid(16, 16, decomp="slab",
                              device_count=8) == (8, 1)
    assert resolve_shard_grid(16, 16, decomp="slab", num_shards=2,
                              device_count=8) == (2, 1)
    # pencil auto: largest total first, most balanced grid first
    assert resolve_shard_grid(16, 16, decomp="pencil",
                              device_count=8) == (4, 2)
    assert resolve_shard_grid(16, 16, decomp="pencil", num_shards=4,
                              device_count=8) == (2, 2)
    with pytest.raises(ValueError, match="slab decomposition needs sy=1"):
        resolve_shard_grid(16, 16, decomp="slab", shard_grid=(2, 2),
                           device_count=8)
    with pytest.raises(ValueError, match="pencil decomposition needs"):
        resolve_shard_grid(16, 16, decomp="pencil", shard_grid=(4, 1),
                           device_count=8)
    with pytest.raises(ValueError, match="does not divide"):
        resolve_shard_grid(16, 12, decomp="pencil", shard_grid=(2, 5),
                           device_count=16)
    with pytest.raises(ValueError, match="needs 16 devices"):
        resolve_shard_grid(16, 16, decomp="pencil", shard_grid=(4, 4),
                           device_count=8)
    with pytest.raises(ValueError, match="contradicts"):
        resolve_shard_grid(16, 16, decomp="pencil", shard_grid=(2, 2),
                           num_shards=8, device_count=8)
    with pytest.raises(ValueError, match="unknown decomp"):
        resolve_shard_grid(16, 16, decomp="block", device_count=8)
    with pytest.raises(ValueError, match="no valid pencil grid"):
        resolve_shard_grid(15, 15, decomp="pencil", device_count=8)


def test_ring_perm_shapes():
    assert collectives.ring_perm(4, 1) == [(0, 1), (1, 2), (2, 3)]
    assert collectives.ring_perm(4, -1) == [(1, 0), (2, 1), (3, 2)]
    assert collectives.ring_perm(4, 1, wrap=True) == [(0, 1), (1, 2), (2, 3),
                                                      (3, 0)]
    assert collectives.ring_perm(1, 1) == []
    with pytest.raises(ValueError):
        collectives.ring_perm(0)


def test_ring_perm_wrap_covers_every_shard():
    # periodic rings keep all n pairs at any offset (mod n), including
    # negative offsets and offsets beyond the ring
    assert collectives.ring_perm(4, -1, wrap=True) == [(0, 3), (1, 0),
                                                       (2, 1), (3, 2)]
    assert collectives.ring_perm(3, 5, wrap=True) == [(0, 2), (1, 0),
                                                      (2, 1)]
    for n, offset in [(2, 1), (4, 2), (5, -2)]:
        pairs = collectives.ring_perm(n, offset, wrap=True)
        assert len(pairs) == n
        assert sorted(d for _, d in pairs) == list(range(n))


def test_halo_exchange_nd_validates_alignment():
    with pytest.raises(ValueError, match="must align"):
        collectives.halo_exchange_nd(jnp.ones((4, 4)), ("a", "b"), (2,))


# --------------------------------------------------------------------------
# hostsim: the XLA_FLAGS append/respect contract (dryrun satellite)
# --------------------------------------------------------------------------
def test_hostsim_appends_without_clobbering_user_flags():
    env = {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    merged = hostsim.merged_xla_flags(8, env)
    assert "--xla_cpu_enable_fast_math=false" in merged
    assert f"{hostsim.DEVICE_COUNT_FLAG}=8" in merged
    assert env["XLA_FLAGS"] == "--xla_cpu_enable_fast_math=false"  # pure

    hostsim.ensure_host_device_count(8, env)
    assert env["XLA_FLAGS"] == merged


def test_hostsim_respects_existing_device_count_flag():
    env = {"XLA_FLAGS": f"{hostsim.DEVICE_COUNT_FLAG}=3"}
    assert hostsim.merged_xla_flags(8, env) == env["XLA_FLAGS"]
    hostsim.ensure_host_device_count(8, env)
    assert env["XLA_FLAGS"] == f"{hostsim.DEVICE_COUNT_FLAG}=3"


def test_hostsim_empty_env():
    env = {}
    hostsim.ensure_host_device_count(4, env)
    assert env["XLA_FLAGS"] == f"{hostsim.DEVICE_COUNT_FLAG}=4"


def test_dryrun_import_does_not_clobber_user_flags():
    """Importing launch/dryrun in a fresh process must keep pre-set flags
    (the regression: it used to overwrite XLA_FLAGS wholesale)."""
    code = ("import os, repro.launch.dryrun; print(os.environ['XLA_FLAGS'])")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_cpu_enable_fast_math=false"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr
    flags = out.stdout.strip()
    assert "--xla_cpu_enable_fast_math=false" in flags
    assert f"{hostsim.DEVICE_COUNT_FLAG}=512" in flags


# --------------------------------------------------------------------------
# multi-device batteries (subprocess: needs 8 forced host devices).  The
# full battery set is the slow lane; the tier-1 lane keeps the seconds-scale
# `--only smoke` single battery.
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_backends_match_single_device_under_8_devices():
    """stencil7/babelstream/minibude bit-match and dot/HF oracle-match at
    2/4/8 shards; halo exchange round-trips; constraints honored; the
    shard_pallas composites bit-match their single-device Pallas kernels;
    the registry-wide conformance matrix validates."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selftest", "--devices",
         "8"],
        env=_subprocess_env(8), capture_output=True, text=True, timeout=480,
        cwd=REPO_ROOT)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "selftest ok" in out.stdout
    assert "bitwise equal at shards [2, 4, 8]" in out.stdout
    assert ("pencil grids [(2, 2), (4, 2), (2, 4)] and overlap variants "
            "bitwise equal") in out.stdout
    assert "one plane per shard (8 shards) bitwise equal" in out.stdout
    assert "wrap=True periodic ring and halo=2" in out.stdout
    assert "scalar is traced" in out.stdout
    assert "tune() sweeps decomp/shard_grid/overlap" in out.stdout
    assert ("shard_pallas stencil7: bitwise equal to single-device pallas"
            in out.stdout)
    assert ("shard_pallas babelstream: elementwise bitwise equal"
            in out.stdout)
    assert "shard_pallas minibude: bitwise equal" in out.stdout
    assert "shard_pallas hartree_fock: l-slab Pallas psum" in out.stdout
    assert ("shard_pallas tuning: composite tile x shard space sweeps"
            in out.stdout)
    assert "conformance:" in out.stdout and "registry cells validated" in \
        out.stdout


def test_selftest_smoke_battery_stays_in_tier1():
    """`--only smoke` is the fast lane: one sharded-oracle and one
    sharded-Pallas stencil check, bitwise, in seconds."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selftest", "--devices",
         "8", "--only", "smoke"],
        env=_subprocess_env(8), capture_output=True, text=True, timeout=240,
        cwd=REPO_ROOT)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "selftest ok (1 batteries)" in out.stdout
    assert "smoke: xla_shard + shard_pallas stencil bitwise" in out.stdout


def test_selftest_rejects_unknown_battery():
    out = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selftest", "--only",
         "no_such_battery"],
        env=_subprocess_env(8), capture_output=True, text=True, timeout=240,
        cwd=REPO_ROOT)
    assert out.returncode == 2
    assert "unknown batteries" in out.stderr


# --------------------------------------------------------------------------
# scaling benchmark: re-exec row replay + header (fast, no devices needed)
# --------------------------------------------------------------------------
def test_scaling_replays_child_rows_into_parent_rows(capsys):
    """The re-exec path must feed child CSV rows back through emit() so the
    parent's benchmarks.common.ROWS aggregates them (the regression: rows
    only streamed through stdout and ROWS stayed empty)."""
    from benchmarks import common, scaling

    before = len(common.ROWS)
    scaling._replay_child_line("scaling.x.slab.strong.s2,123.4,eff=0.5")
    scaling._replay_child_line(scaling.CSV_HEADER)   # dropped, not doubled
    scaling._replay_child_line("")                   # blank: dropped
    scaling._replay_child_line("free-form progress note")  # passes through
    rows = common.ROWS[before:]
    assert len(rows) == 1
    name, us, derived = rows[0]
    assert name == "scaling.x.slab.strong.s2" and derived == "eff=0.5"
    assert us == pytest.approx(123.4)
    out = capsys.readouterr().out
    assert "scaling.x.slab.strong.s2,123.4,eff=0.5" in out
    assert "free-form progress note" in out
    assert out.count(scaling.CSV_HEADER) == 0


def test_scaling_standalone_main_emits_header(capsys, monkeypatch, tmp_path):
    """`python -m benchmarks.scaling` must print the scaffold's CSV header
    before its rows (benchmarks.run prints one itself, so run() must not)."""
    from benchmarks import scaling

    seen = {}
    monkeypatch.setattr(scaling, "run", lambda **kw: seen.update(kw) or {})
    scaling.main(["--smoke", "--json", str(tmp_path / "s.json")])
    assert capsys.readouterr().out.splitlines()[0] == scaling.CSV_HEADER
    assert seen["smoke"] is True


def test_timed_point_drops_cached_params_invalid_for_forced_grid(
        tmp_path, monkeypatch):
    """The tuning-cache key does not encode shard settings, so a hit tuned
    under one grid can carry tile params invalid for another point's forced
    grid (by=64 from a slab does not divide a pencil's 32-wide local
    block).  The merged point must be re-validated and the hit dropped —
    never a ValueError out of the benchmark."""
    from benchmarks import scaling
    from repro.distributed import domain

    k = get_kernel("stencil7")
    u = jnp.ones((64, 64, 128), jnp.float32)
    # the constraint AND the cache key consult the live device count;
    # pretend to be the 8-device scaling child this helper runs in (before
    # building the key — TuningKey embeds the device count)
    monkeypatch.setattr(domain.jax, "device_count", lambda: 8)
    cache = tuning.TuningCache(path=str(tmp_path / "t.json"))
    key = tuning.make_key(k, u, backend="shard_pallas")
    cache.put(key, {"decomp": "slab", "shard_grid": (8, 1), "by": 64}, 1.0)
    seen = {}
    monkeypatch.setattr(
        type(k), "time_backend",
        lambda self, *a, **kw: seen.update(kw) or 0.1)
    forced = {"decomp": "pencil", "shard_grid": (2, 2)}
    _, prov = scaling._timed_point(k, (u,), "shard_pallas", cache, 1, 0,
                                   forced)
    assert prov["cached"] is False and prov["search"] is None
    assert "by" not in prov["params"] and "by" not in seen
    assert seen["decomp"] == "pencil" and seen["shard_grid"] == (2, 2)
    # a hit whose tile params fit the forced grid still merges under it
    cache.put(key, {"decomp": "slab", "shard_grid": (8, 1), "by": 16}, 1.0)
    _, prov = scaling._timed_point(k, (u,), "shard_pallas", cache, 1, 0,
                                   forced)
    assert prov["cached"] is True and prov["params"]["by"] == 16
    assert seen["by"] == 16 and seen["decomp"] == "pencil"


def test_balanced_pencil_grid_policy():
    """One picker serves the registry AND the scaling benchmark, so the
    recorded per-point shard_grid always matches what the registry would
    resolve."""
    from repro.distributed.domain import balanced_pencil_grid

    assert balanced_pencil_grid(4) == (2, 2)
    assert balanced_pencil_grid(8) == (4, 2)
    assert balanced_pencil_grid(2) is None            # no true 2-D grid
    assert balanced_pencil_grid(4, 16, 16) == (2, 2)
    assert balanced_pencil_grid(8, 16, 16) == (4, 2)
    assert balanced_pencil_grid(8, 16, 3) is None     # ny % sy != 0
    assert balanced_pencil_grid(2, 16, 16) is None
    # a short z axis may only admit the sy-major factorization
    assert balanced_pencil_grid(6, 2, 9) == (2, 3)
    assert resolve_shard_grid(2, 9, decomp="pencil",
                              device_count=6) == (2, 3)
    # the registry's auto-resolution goes through the same picker
    assert resolve_shard_grid(16, 16, decomp="pencil", num_shards=8,
                              device_count=8) == balanced_pencil_grid(8)


# --------------------------------------------------------------------------
# scaling benchmark (slow lane; the --smoke drift check also covers it)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_scaling_benchmark_smoke_writes_artifact(tmp_path):
    from benchmarks import common, scaling

    rows_before = len(common.ROWS)
    json_path = str(tmp_path / "BENCH_scaling.json")
    artifact = scaling.run(smoke=True, json_path=json_path, devices=4)

    on_disk = json.loads((tmp_path / "BENCH_scaling.json").read_text())
    assert on_disk["schema"] == "repro.scaling/v3"
    assert on_disk["num_devices"] >= 2
    by_name = {r["kernel"]: r for r in artifact["kernels"]}
    for name in ("stencil7", "babelstream.triad", "babelstream.dot"):
        rec = by_name[name]
        # v3: the per-backend dimension — xla_shard AND shard_pallas curves
        backends = {b["backend"]: b for b in rec["backends"]}
        assert set(backends) == {"xla_shard", "shard_pallas"}
        for brec in backends.values():
            assert brec["skipped"] is None, (name, brec)
            assert brec["curves"]
            for curve in brec["curves"]:
                for lane in ("strong", "weak"):
                    pts = curve[lane]["points"]
                    assert pts and all(
                        np.isfinite(p["efficiency"]) and p["efficiency"] > 0
                        for p in pts)
                    # every point records its tuning provenance (PR-2
                    # rules: params may come from the cache, the timing
                    # never does)
                    assert all(set(p["tuning"]) == {"cached", "params",
                                                    "search"} for p in pts)
    # stencil7 carries the slab-vs-pencil decomposition axis: overlap
    # on/off for the oracle lanes, a single structure for the composite
    stencil = {b["backend"]: b for b in by_name["stencil7"]["backends"]}
    xs = {(c["decomp"], c["overlap"]) for c in
          stencil["xla_shard"]["curves"]}
    assert xs == {("slab", False), ("slab", True),
                  ("pencil", False), ("pencil", True)}
    ps = {(c["decomp"], c["overlap"]) for c in
          stencil["shard_pallas"]["curves"]}
    assert ps == {("slab", None), ("pencil", None)}
    pencil_pts = [c for c in stencil["shard_pallas"]["curves"]
                  if c["decomp"] == "pencil"][0]["strong"]["points"]
    assert [tuple(p["shard_grid"]) for p in pencil_pts] == [(2, 2)]
    # HF records a reason for its missing weak curve, never a fake one
    hf = by_name["hartree_fock.twoel"]["backends"][0]
    assert "skipped" in hf["curves"][0]["weak"]
    # the re-exec child's CSV rows were replayed into the parent's ROWS
    new_rows = common.ROWS[rows_before:]
    assert any(n.startswith("scaling.stencil7.shard_pallas.pencil")
               for n, _, _ in new_rows)
    assert any(n.startswith("scaling.babelstream.dot.xla_shard")
               for n, _, _ in new_rows)
