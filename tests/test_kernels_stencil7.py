"""Seven-point stencil Pallas kernel vs oracle + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metrics import stencil7_effective_bytes
from repro.kernels.stencil7 import ops, ref


@pytest.mark.parametrize("shape,by", [
    ((8, 16, 128), 8), ((6, 32, 256), 16), ((4, 8, 128), 4),
    ((12, 24, 128), 8),
])
def test_matches_oracle_fp32(rng, shape, by):
    u = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    coeffs = ref.default_coefficients(1.0, 2.0, 3.0)
    got = ops.laplacian_pallas(u, *coeffs, by=by, interpret=True)
    want = ops.laplacian_xla(u, *coeffs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_boundary_zero(rng):
    u = jnp.asarray(rng.standard_normal((8, 16, 128)), jnp.float32)
    out = np.asarray(ops.laplacian_pallas(u, by=8, interpret=True))
    assert (out[0] == 0).all() and (out[-1] == 0).all()
    assert (out[:, 0] == 0).all() and (out[:, -1] == 0).all()
    assert (out[:, :, 0] == 0).all() and (out[:, :, -1] == 0).all()


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(-4.0, 4.0))
def test_linearity(scale):
    """Laplacian is linear: L(a*u) == a*L(u)."""
    rng = np.random.default_rng(7)
    u = jnp.asarray(rng.standard_normal((4, 8, 128)), jnp.float32)
    l1 = ops.laplacian_xla(u * scale)
    l2 = ops.laplacian_xla(u) * scale
    np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-3)


def test_constant_field_interior_zero():
    """Laplacian of a constant field vanishes on the interior."""
    u = jnp.ones((6, 8, 128), jnp.float32)
    out = np.asarray(ops.laplacian_pallas(u, by=8, interpret=True))
    np.testing.assert_allclose(out[1:-1, 1:-1, 1:-1], 0.0, atol=1e-4)


def test_eq1_byte_model():
    # paper Eq. 1
    L, isz = 512, 8
    fetch = (L ** 3 - 8 - 12 * (L - 2)) * isz
    write = (L - 2) ** 3 * isz
    assert stencil7_effective_bytes(L, isz) == fetch + write
