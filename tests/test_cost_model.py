"""PR 9 — the static performance auditor's cost model.

Covers the jaxpr traffic census (multiplicity-aware walk, Pallas blockwise
re-reads, compulsory-floor semantics), the roofline verdict and chip
detection, shape-signature round-tripping, the three performance passes on
planted fixtures (inflated traffic, wrong declared bound, drift beyond the
band), and the model-guided tuning search (ranking, dominance pruning,
partial-search cache provenance)."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers every backend)
from repro.core import conformance, tuning
from repro.core.analysis import cost
from repro.core.analysis import jaxpr_utils as JU
from repro.core.portable import registry
from repro.core.roofline import (AMD_MI300A, CPU_HOST, NVIDIA_H100, TPU_V5E,
                                 detect_chip)


def _trace(fn, *args, **kwargs):
    return JU.trace(fn, args, kwargs)


# ---------------------------------------------------------------------------
# traffic census
# ---------------------------------------------------------------------------
def test_census_elementwise_floor():
    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    t = cost.census(_trace(lambda a: a + 1.0, x))
    assert t.flops == 128
    # boundary floor: one f32[128] in, one out
    assert t.hbm_min_bytes == 2 * 128 * 4
    assert t.hbm_bytes == t.hbm_min_bytes
    assert t.inflation == 1.0
    assert t.arithmetic_intensity == pytest.approx(128 / (2 * 128 * 4))


def test_census_dot_general_flops():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    t = cost.census(_trace(jnp.dot, a, b))
    assert t.flops == 2 * 64 * 16 * 32


def test_census_scan_multiplicity():
    x = jax.ShapeDtypeStruct((256,), jnp.float32)

    def ten_adds(a):
        def body(c, _):
            return c + 1.0, None
        c, _ = jax.lax.scan(body, a, None, length=10)
        return c

    t = cost.census(_trace(ten_adds, x))
    assert t.flops == 10 * 256
    # the scan revisits the same carry: the boundary floor stays 2 arrays
    assert t.hbm_min_bytes == 2 * 256 * 4


def test_census_pallas_counts_halo_rereads():
    """stencil7's Pallas grid re-reads the z+-1 halo planes every step:
    the census must see traffic above the compulsory floor."""
    k = registry.get("stencil7")
    args, kwargs = conformance.CASES["stencil7"]()
    t = cost.census(_trace(k.backends["pallas_interpret"].fn, *args,
                           **kwargs))
    assert t.pallas_calls >= 1
    assert t.grid_steps >= 1
    assert t.reread_bytes > 0
    assert t.hbm_bytes > t.hbm_min_bytes
    assert t.inflation > 1.0


def test_census_collective_bytes():
    """psum under shard_map counts its payload, scaled by the mesh size."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    ndev = jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("x",))

    def summed(a):
        return shard_map(
            lambda blk: jax.lax.psum(jnp.sum(blk), "x"),
            mesh=mesh, in_specs=P("x"), out_specs=P())(a)

    x = jax.ShapeDtypeStruct((8 * ndev,), jnp.float32)
    t = cost.census(_trace(summed, x))
    assert t.shards == ndev
    assert t.collective_count == ndev      # one psum per shard program
    assert t.collective_bytes == 4.0 * ndev  # f32 scalar payload per shard


# ---------------------------------------------------------------------------
# roofline verdict + chips
# ---------------------------------------------------------------------------
def test_verdict_memory_vs_compute_bound():
    lo = cost.Traffic(flops=100.0, hbm_read_bytes=1e6, hbm_write_bytes=1e6,
                      hbm_min_bytes=2e6)
    v = cost.verdict(lo, CPU_HOST)
    assert v.bound == "memory"
    assert v.predicted_s == pytest.approx(2e6 / CPU_HOST.hbm_bw)
    assert 0.0 < v.attainable_frac < 1.0

    hi = cost.Traffic(flops=1e12, hbm_read_bytes=8.0, hbm_write_bytes=8.0,
                      hbm_min_bytes=16.0)
    v = cost.verdict(hi, CPU_HOST)
    assert v.bound == "compute"
    assert v.attainable_frac == pytest.approx(1.0)


def test_verdict_collective_bound_and_shards():
    t = cost.Traffic(flops=1.0, hbm_read_bytes=8.0, hbm_write_bytes=8.0,
                     hbm_min_bytes=16.0, collective_bytes=1e9, shards=4)
    v = cost.verdict(t, CPU_HOST)
    assert v.bound == "collective"
    # all three terms scale by the shard count
    assert v.collective_s == pytest.approx(1e9 / (CPU_HOST.ici_bw * 4))


def test_detect_chip_mapping():
    assert detect_chip("tpu") is TPU_V5E
    assert detect_chip("gpu") is NVIDIA_H100
    assert detect_chip("cuda", "NVIDIA H100 80GB HBM3") is NVIDIA_H100
    assert detect_chip("gpu", "AMD Instinct MI300A") is AMD_MI300A
    assert detect_chip("rocm") is AMD_MI300A
    assert detect_chip("cpu") is CPU_HOST
    # the CI-lane spec keeps its ridge in the same decade as the real chips
    assert 10 < CPU_HOST.ridge < NVIDIA_H100.ridge


# ---------------------------------------------------------------------------
# shape-signature round trip
# ---------------------------------------------------------------------------
def test_parse_shape_signature_roundtrip():
    x = jnp.ones((8, 64), jnp.float32)
    k = jnp.zeros((2,), jnp.int32)
    sig = tuning.shape_signature(x, 0.5, k=k)
    parsed = cost.parse_shape_signature(sig)
    assert parsed is not None
    args, kwargs = parsed
    assert args[0].shape == (8, 64) and args[0].dtype == np.float32
    assert args[1] == 0.5
    assert kwargs["k"].shape == (2,) and kwargs["k"].dtype == np.int32


def test_parse_shape_signature_edges():
    assert cost.parse_shape_signature("") == ((), {})
    assert cost.parse_shape_signature("not a signature !") is None
    # scalar-only and kwarg-only forms
    args, kwargs = cost.parse_shape_signature("3;flag=True")
    assert args == (3,) and kwargs == {"flag": True}


# ---------------------------------------------------------------------------
# planted fixtures: each performance pass fires
# ---------------------------------------------------------------------------
class _FakeKernel:
    def __init__(self, contract):
        self._contract = contract

    def roofline_contract(self, backend):
        return dict(self._contract)


def test_planted_inflated_traffic_fires():
    """Real traced Pallas traffic against a deliberately tight limit."""
    k = registry.get("stencil7")
    args, kwargs = conformance.CASES["stencil7"]()
    t = cost.census(_trace(k.backends["pallas_interpret"].fn, *args,
                           **kwargs))
    tight = _FakeKernel({"traffic_inflation_limit": t.inflation * 0.5})
    fs = cost.traffic_findings("stencil7", "pallas_interpret", tight, t)
    assert len(fs) == 1
    assert fs[0].code == "traffic-inflation"
    assert fs[0].detail["inflation"] == pytest.approx(t.inflation)
    # raising the declared limit absorbs it
    loose = _FakeKernel({"traffic_inflation_limit": t.inflation * 2})
    assert cost.traffic_findings("stencil7", "pallas_interpret", loose,
                                 t) == []


def test_planted_wrong_bound_fires():
    x = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
    t = cost.census(_trace(lambda a: a * 2.0, x))    # AI 0.125: memory
    v = cost.verdict(t, CPU_HOST)
    assert v.bound == "memory"
    wrong = _FakeKernel({"bound": "compute"})
    fs = cost.roofline_findings("babelstream.mul", "xla", wrong, t, v)
    assert len(fs) == 1 and fs[0].code == "bound-mismatch"
    right = _FakeKernel({"bound": "memory"})
    assert cost.roofline_findings("babelstream.mul", "xla", right, t,
                                  v) == []
    undeclared = _FakeKernel({})
    assert cost.roofline_findings("babelstream.mul", "xla", undeclared, t,
                                  v) == []


def _write_drift_cache(seconds_by_key, tmp_path):
    """Write a synthetic repro.tuning/v2 cache joinable by the drift gate."""
    platform = jax.devices()[0].platform
    entries = {}
    for (k, b, s), sec in seconds_by_key.items():
        key = tuning.TuningKey(kernel=k, backend=b, shape=s, dtype="float32",
                               platform=platform, code="x", devices=1)
        entries[key.as_str()] = {"params": {}, "seconds": sec,
                                 "search": "exhaustive"}
    path = tmp_path / "drift_cache.json"
    path.write_text(json.dumps({"schema": tuning.CACHE_SCHEMA,
                                "entries": entries}))
    return path


def test_planted_drift_beyond_band_fires(tmp_path):
    """Three well-calibrated joins + one 1000x outlier: exactly the outlier
    fires, and the summary carries the host calibration median."""
    probes = [
        ("babelstream.copy", "xla", tuning.shape_signature(
            jnp.ones((1 << 14,), jnp.float32))),
        ("babelstream.mul", "xla", tuning.shape_signature(
            jnp.ones((1 << 14,), jnp.float32))),
        ("babelstream.add", "xla", tuning.shape_signature(
            jnp.ones((1 << 14,), jnp.float32),
            jnp.ones((1 << 14,), jnp.float32))),
        ("babelstream.triad", "xla", tuning.shape_signature(
            jnp.ones((1 << 14,), jnp.float32),
            jnp.ones((1 << 14,), jnp.float32))),
    ]
    chip = detect_chip()
    preds = {}
    for k, b, s in probes:
        p = cost.predict_seconds(
            cost.Measurement(kernel=k, backend=b, shape=s, params={},
                             seconds=1.0, source="cache"), chip)
        assert p is not None and p > 0
        preds[(k, b, s)] = p
    seconds = {key: 100.0 * p for key, p in preds.items()}
    outlier = probes[-1]
    seconds[outlier] *= 1000.0
    path = _write_drift_cache(seconds, tmp_path)

    findings, summary = cost.drift_gate(cache_path=path, band=8.0, chip=chip)
    assert summary["joined"] == 4
    assert summary["calibration"] == pytest.approx(100.0, rel=0.01)
    assert len(findings) == 1
    f = findings[0]
    assert (f.kernel, f.backend) == outlier[:2]
    assert f.code == "perf-drift" and not f.waived
    assert f.detail["relative"] > 8.0


def test_drift_gate_too_few_joins_is_silent(tmp_path):
    sig = tuning.shape_signature(jnp.ones((1 << 14,), jnp.float32))
    path = _write_drift_cache(
                      {("babelstream.copy", "xla", sig): 1.0}, tmp_path)
    findings, summary = cost.drift_gate(cache_path=path, band=8.0)
    assert findings == []
    assert summary["joined"] < cost.MIN_DRIFT_JOINS
    assert summary["calibration"] is None


# ---------------------------------------------------------------------------
# the model as a tuning prior
# ---------------------------------------------------------------------------
def test_rank_points_orders_by_prediction():
    k = registry.get("stencil7")
    args, kwargs = conformance.CASES["stencil7"]()
    points = k.tunable_space("pallas_interpret").valid_points(*args,
                                                              **kwargs)
    assert len(points) >= 2
    ranked = cost.rank_points(k, "pallas_interpret", points, args, kwargs)
    assert len(ranked) == len(points)
    preds = [r["predicted_s"] for r in ranked]
    assert preds == sorted(preds)
    assert all("bound" in r for r in ranked)


def test_prune_dominated():
    ranked = [
        {"params": {"a": 1}, "predicted_s": 1.0, "hbm_bytes": 100.0,
         "parallelism": 4.0, "order": 0},
        # strictly worse on both axes than the first: pruned
        {"params": {"a": 2}, "predicted_s": 2.0, "hbm_bytes": 200.0,
         "parallelism": 2.0, "order": 1},
        # worse traffic but better parallelism: kept
        {"params": {"a": 3}, "predicted_s": 3.0, "hbm_bytes": 300.0,
         "parallelism": 8.0, "order": 2},
        # untraceable: dropped outright
        {"params": {"a": 4}, "predicted_s": float("inf"), "error": "boom",
         "hbm_bytes": float("inf"), "parallelism": 0.0, "order": 3},
    ]
    keep = cost.prune_dominated(ranked)
    kept = [r["params"]["a"] for r in keep]
    assert kept == [1, 3]


def test_model_search_provenance_and_no_exhaustive_serving(tmp_path):
    """tune(search='model') caches provenance 'model'; the entry is never
    served to an exhaustive caller; the exhaustive result replaces it."""
    k = registry.get("stencil7")
    args, kwargs = conformance.CASES["stencil7"]()
    cache = tuning.TuningCache(path=str(tmp_path / "model.json"))

    tr = tuning.tune(k, *args, backend="pallas_interpret", cache=cache,
                     iters=1, warmup=0, search="model", **kwargs)
    assert tr.skipped is None and not tr.cached
    assert tr.search == "model"
    key = tuning.make_key(k, *args, backend="pallas_interpret", **kwargs)
    entry = cache.get(key)
    assert entry is not None and entry["search"] == "model"

    # a model hit serves a second model request...
    again = tuning.tune(k, *args, backend="pallas_interpret", cache=cache,
                        iters=1, warmup=0, search="model", **kwargs)
    assert again.cached
    # ...but never an exhaustive one — that re-sweeps and overwrites
    full = tuning.tune(k, *args, backend="pallas_interpret", cache=cache,
                       iters=1, warmup=0, search="exhaustive", **kwargs)
    assert not full.cached
    assert cache.get(key)["search"] == "exhaustive"


def test_model_search_times_at_most_top_k(tmp_path):
    k = registry.get("stencil7")
    args, kwargs = conformance.CASES["stencil7"]()
    cache = tuning.TuningCache(path=str(tmp_path / "budget.json"))
    tr = tuning.tune(k, *args, backend="pallas_interpret", cache=cache,
                     iters=1, warmup=0, search="model", budget=2, **kwargs)
    assert tr.skipped is None
    assert len(tr.swept) <= 2
