"""Continuous-batching engine tests: padded-prefill correctness, greedy
equivalence with unbatched decode (both cache layouts, both driver loops),
fixed-shape/bounded-compile contracts, paged-pool admission gating, and the
slot/block/queue plumbing."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (BlockAllocator, Request, RequestQueue,
                           ServingEngine, SlotAllocator)
from repro.serving.slots import RESERVED_BLOCKS, TRASH_BLOCK
from repro.serving.trace import latency_summary, synthetic_trace
from repro.training import serve_step as SS

CFG = get_config("granite-3-8b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return T.init_params(CFG, jax.random.PRNGKey(0))


def _requests(lens, max_new=6, arrivals=None, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab_size, L)
                    .astype(np.int32),
                    max_new_tokens=max_new,
                    arrival_time=0.0 if arrivals is None else arrivals[i])
            for i, L in enumerate(lens)]


# --------------------------------------------------------------------------
# padded prefill correctness (the left-pad-attends-over-pad-0 bug)
# --------------------------------------------------------------------------
def test_leftpad_positions():
    pos = T.leftpad_positions(jnp.asarray([3, 5, 1]), 5)
    np.testing.assert_array_equal(
        np.asarray(pos),
        [[-1, -1, 0, 1, 2], [0, 1, 2, 3, 4], [-1, -1, -1, -1, 0]])


def test_padded_prefill_matches_unpadded(params):
    """Left-padded mixed-batch prefill with lengths == per-row unpadded."""
    rng = np.random.default_rng(3)
    lens = [3, 8, 5]
    S = 8
    prompts = [rng.integers(2, CFG.vocab_size, L).astype(np.int32)
               for L in lens]
    batch = np.zeros((len(lens), S), np.int32)
    for i, p in enumerate(prompts):
        batch[i, S - len(p):] = p
    last, _, _ = SS.prefill(params, CFG, jnp.asarray(batch), cache_len=32,
                            lengths=jnp.asarray(lens))
    for i, p in enumerate(prompts):
        ref, _, _ = SS.prefill(params, CFG, jnp.asarray(p)[None],
                               cache_len=32)
        np.testing.assert_allclose(np.asarray(last[i], np.float32),
                                   np.asarray(ref[0], np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_padded_prefill_sliding_window_pads_dropped(params):
    """Pads must not clobber ring-buffer slots when the window is shorter
    than the padded length (pos -1 would alias slot window-1)."""
    import dataclasses
    wcfg = dataclasses.replace(CFG, window=8)
    wparams = T.init_params(wcfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(6)
    L, S = 6, 12                          # 6 pads > window slack
    prompt = rng.integers(2, wcfg.vocab_size, L).astype(np.int32)
    batch = np.zeros((1, S), np.int32)
    batch[0, S - L:] = prompt
    last, _, _ = SS.prefill(wparams, wcfg, jnp.asarray(batch), cache_len=32,
                            lengths=jnp.asarray([L]))
    ref, _, _ = SS.prefill(wparams, wcfg, jnp.asarray(prompt)[None],
                           cache_len=32)
    np.testing.assert_allclose(np.asarray(last[0], np.float32),
                               np.asarray(ref[0], np.float32),
                               rtol=2e-4, atol=2e-4)


def test_padded_prefill_decode_positions_consistent(params):
    """Decode after masked prefill continues at the TRUE prompt length and
    matches unpadded prefill+decode of the same prompt."""
    rng = np.random.default_rng(4)
    lens = [3, 6]
    S = 6
    batch = np.zeros((2, S), np.int32)
    prompts = [rng.integers(2, CFG.vocab_size, L).astype(np.int32)
               for L in lens]
    for i, p in enumerate(prompts):
        batch[i, S - len(p):] = p
    last, caches, _ = SS.prefill(params, CFG, jnp.asarray(batch),
                                 cache_len=32, lengths=jnp.asarray(lens))
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)[:, None]     # true lengths, not S
    logits, _ = SS.decode_step(params, CFG, tok, pos, caches)
    for i, p in enumerate(prompts):
        out = SS.generate(params, CFG, jnp.asarray(p)[None],
                          max_new_tokens=2, cache_len=32)
        assert int(tok[i, 0]) == int(out[0, 0])
        assert int(jnp.argmax(logits[i])) == int(out[0, 1])


# --------------------------------------------------------------------------
# engine: greedy equivalence + fixed-shape contract
# --------------------------------------------------------------------------
def test_engine_matches_unbatched_greedy(params):
    """Ragged prompts through slot recycling == per-request unbatched
    greedy decode, token for token."""
    reqs = _requests([3, 9, 12, 5, 7], max_new=6)
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                        prefill_len=16)
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        ref = SS.generate(params, CFG, jnp.asarray(r.prompt)[None],
                          max_new_tokens=6, cache_len=48)
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(ref[0]))


def test_engine_single_compiled_shape(params):
    """Slot recycling admits queued requests with NO recompilation: one
    compiled prefill shape + one compiled decode shape for the whole trace,
    including arrivals landing mid-decode."""
    arrivals = [0.0, 0.0, 0.0, 0.05, 0.1, 0.15]
    reqs = _requests([4, 11, 6, 3, 16, 8], max_new=5, arrivals=arrivals)
    eng = ServingEngine(params, CFG, num_slots=3, cache_len=64,
                        prefill_len=16)
    done = eng.run(reqs)
    assert len(done) == 6
    assert eng.stats["prefill_calls"] == 6
    assert eng.stats["prefill_traces"] == 1, eng.stats
    assert eng.stats["decode_traces"] == 1, eng.stats


def test_engine_sampled_continuations_differ(params):
    """Per-request key streams: identical prompts in different slots/batches
    must not sample identical continuations (the PRNGKey(i)-reuse bug)."""
    rng = np.random.default_rng(9)
    prompt = rng.integers(2, CFG.vocab_size, 6).astype(np.int32)
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=12)
            for i in range(4)]
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                        prefill_len=16, temperature=1.0)
    done = eng.run(reqs)
    gens = {tuple(r.generated) for r in done}
    assert len(gens) > 1, "all requests sampled the same continuation"


def test_engine_rejects_oversized(params):
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.arange(9, dtype=np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=32))


def test_engine_rejects_stateful_archs(params):
    cfg = get_config("rwkv6-3b", smoke=True)
    with pytest.raises(NotImplementedError):
        ServingEngine(params, cfg)


# --------------------------------------------------------------------------
# engine v2: paged KV layout, bucket ladder, threaded loop, scheduler edges
# --------------------------------------------------------------------------
def _oracle_tokens(params, req, cache_len):
    ref = SS.generate(params, CFG, jnp.asarray(req.prompt)[None],
                      max_new_tokens=req.max_new_tokens, cache_len=cache_len)
    return [int(t) for t in np.asarray(ref)[0]]


def test_paged_engine_matches_unbatched_and_contiguous(params):
    """The paged pool + block tables are pure layout: greedy tokens must
    bit-match both the contiguous engine and unbatched decode."""
    def serve(layout):
        eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                            prefill_len=16, cache_layout=layout,
                            block_size=8)
        done = eng.run(_requests([3, 9, 12, 5, 16, 1], max_new=6))
        return {r.uid: list(r.generated) for r in done}, eng

    got_paged, eng = serve("paged")
    got_contig, _ = serve("contiguous")
    assert got_paged == got_contig
    for r in _requests([3, 9, 12, 5, 16, 1], max_new=6):
        assert got_paged[r.uid] == _oracle_tokens(params, r, 48)
    # every page returned, every table row parked on the trash page
    assert eng.balloc.available() == eng.balloc.capacity()
    assert np.all(eng.block_tables == TRASH_BLOCK)


def test_paged_pool_admission_gating(params):
    """A pool smaller than the slot count's worth of rows serializes
    admissions on free pages (FIFO head-of-line) without changing tokens."""
    # 6 pages of 8 = room for at most two of these requests' reservations
    # (12 + 5 -> 2 + 1 pages, 9 + 5 -> 2 pages, ...), far below 4 slots
    eng = ServingEngine(params, CFG, num_slots=4, cache_len=16,
                        prefill_len=8, cache_layout="paged", block_size=8,
                        num_blocks=RESERVED_BLOCKS + 2)
    reqs = _requests([3, 8, 5, 2, 7], max_new=6)
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        assert list(r.generated) == _oracle_tokens(params, r, 16)
    assert eng.balloc.available() == eng.balloc.capacity()


def test_paged_request_larger_than_pool_rejected(params):
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=16,
                        prefill_len=8, cache_layout="paged", block_size=2,
                        num_blocks=RESERVED_BLOCKS + 3)   # 6 positions max
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.arange(2, 8, dtype=np.int32),
                           max_new_tokens=4))             # needs 5 pages


def test_prefill_bucket_ladder_bounds_compiles(params):
    """One compiled prefill shape per ladder rung actually used, one decode
    shape total — never a shape per prompt length."""
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                        prefill_buckets=(4, 8, 16))
    assert eng.prefill_len == 16
    done = eng.run(_requests([3, 4, 7, 2], max_new=4))    # buckets 4 + 8
    assert len(done) == 4
    assert eng.stats["prefill_traces"] == 2, eng.stats
    assert eng.stats["decode_traces"] == 1, eng.stats
    done = eng.run(_requests([12, 6], max_new=4))         # adds bucket 16
    assert len(done) == 2
    assert eng.stats["prefill_traces"] == 3, eng.stats
    assert eng.stats["decode_traces"] == 1, eng.stats
    # bucket choice is padding only: tokens still match unbatched decode
    for r in done:
        assert list(r.generated) == _oracle_tokens(params, r, 48)


def test_threaded_loop_matches_sync(params):
    """run_threaded (injector + admission threads, bounded backpressure
    queue) produces bitwise the sync loop's greedy tokens."""
    def serve(threaded):
        eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                            prefill_buckets=(8, 16), cache_layout="paged",
                            block_size=8)
        reqs = _requests([3, 9, 12, 5, 7], max_new=6,
                         arrivals=[0.0, 0.0, 0.01, 0.02, 0.03])
        done = eng.run_threaded(reqs) if threaded else eng.run(reqs)
        assert eng.stats["requests_finished"] == 5
        return {r.uid: list(r.generated) for r in done}

    assert serve(threaded=True) == serve(threaded=False)


def test_threaded_vs_sync_subprocess(params):
    """Tier-1 end-to-end check in a fresh interpreter: the threaded and
    synchronous loops serve the same trace to bitwise-identical tokens."""
    code = """
import numpy as np, jax
from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serving import ServingEngine, Request

cfg = get_config("granite-3-8b", smoke=True)
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(5)
def reqs():
    return [Request(uid=i, prompt=p.copy(), max_new_tokens=5,
                    arrival_time=0.005 * i)
            for i, p in enumerate(prompts)]
prompts = [rng.integers(2, cfg.vocab_size, L).astype(np.int32)
           for L in (3, 9, 12, 5)]
out = {}
for threaded in (False, True):
    eng = ServingEngine(params, cfg, num_slots=2, cache_len=32,
                        prefill_buckets=(8, 16), cache_layout="paged",
                        block_size=8)
    done = eng.run_threaded(reqs()) if threaded else eng.run(reqs())
    out[threaded] = {r.uid: list(r.generated) for r in done}
assert len(out[False]) == 4 and out[True] == out[False], out
print("THREADED_BITWISE_OK")
"""
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "THREADED_BITWISE_OK" in proc.stdout


def test_exact_fit_prompt(params):
    """prompt_len == prefill_len (no pad at all) must serve, and one token
    longer must be rejected."""
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    reqs = _requests([8], max_new=4)
    done = eng.run(reqs)
    assert len(done) == 1
    assert list(done[0].generated) == _oracle_tokens(params, done[0], 32)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=9, prompt=np.arange(2, 11, dtype=np.int32),
                           max_new_tokens=4))


def test_eos_on_prefill_token(params):
    """A request whose very first sampled token is EOS finishes at prefill:
    slot freed immediately, exactly one generated token."""
    req = _requests([5], max_new=8)[0]
    first = _oracle_tokens(params, req, 32)[0]
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8, cache_layout="paged", block_size=8)
    req = _requests([5], max_new=8)[0]
    req.eos_id = first
    done = eng.run([req])
    assert len(done) == 1 and done[0].generated == [first]
    assert eng.stats["decode_steps"] == 0
    assert eng.slots.available() == 2
    assert eng.balloc.available() == eng.balloc.capacity()


def test_finish_and_admit_same_step(params):
    """A request can finish in the same step() call that admits another:
    slot bookkeeping and tokens both stay exact."""
    reqs = _requests([3, 5], max_new=3)
    # far enough out that A's prefill (which advances the admission clock)
    # can't make B ready inside the first step
    reqs[1].arrival_time = 50.0
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    for r in reqs:
        eng.submit(r)
    fin = eng.step(now=0.0)   # admits A only (B "arrives" at 50); A at 2/3
    assert fin == [] and eng.active_count() == 1
    fin = eng.step(now=50.0)  # admits B AND finishes A in its decode half
    assert [r.uid for r in fin] == [0]
    assert eng.active_count() == 1
    while eng.active_count():
        fin += eng.step(now=50.0)
    assert {r.uid for r in fin} == {0, 1}
    for r in reqs:
        assert list(r.generated) == _oracle_tokens(params, r, 32)


def test_admission_clock_recomputed_per_admit(params):
    """Two requests admitted in one step() must not share a stale clock:
    the second's t_admitted includes the first's prefill duration."""
    reqs = _requests([5, 7], max_new=2)
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    for r in reqs:
        eng.submit(r)
    eng.step(now=0.0)
    assert reqs[0].t_admitted == 0.0
    assert reqs[1].t_admitted > reqs[0].t_admitted


# --------------------------------------------------------------------------
# plumbing: slots, queue, trace
# --------------------------------------------------------------------------
def test_slot_allocator_cycle():
    sa = SlotAllocator(2)
    a, b = sa.alloc(), sa.alloc()
    assert {a, b} == {0, 1} and sa.available() == 0
    with pytest.raises(RuntimeError):
        sa.alloc()
    sa.free(a)
    assert sa.alloc() == a
    sa.free(b)
    with pytest.raises(ValueError):
        sa.free(b)


def test_queue_arrival_gating():
    q = RequestQueue()
    q.submit(Request(uid=0, prompt=np.ones(2, np.int32), max_new_tokens=1,
                     arrival_time=0.0))
    q.submit(Request(uid=1, prompt=np.ones(2, np.int32), max_new_tokens=1,
                     arrival_time=1.0))
    assert q.pop_ready(0.5).uid == 0
    assert q.pop_ready(0.5) is None      # uid 1 hasn't arrived yet
    assert q.next_arrival() == 1.0
    assert q.pop_ready(2.0).uid == 1
    assert not q


def test_synthetic_trace_and_summary():
    reqs = synthetic_trace(10, vocab_size=64, rate=100.0, seed=2)
    arrivals = [r.arrival_time for r in reqs]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    assert all(4 <= r.prompt_len <= 16 for r in reqs)
    for i, r in enumerate(reqs):
        r.t_first_token = r.arrival_time + 0.01
        r.t_done = r.arrival_time + 0.1 + 0.01 * i
    lat = latency_summary(reqs)
    assert 0.1 <= lat["p50_latency_s"] <= 0.2
    assert lat["p50_ttft_s"] == pytest.approx(0.01)
    assert lat["submitted"] == 10 and lat["unfinished"] == 0


def test_latency_summary_counts_unfinished():
    """Unfinished requests must show up in the counts, not silently vanish
    from the SLO denominator."""
    reqs = synthetic_trace(6, vocab_size=64, rate=100.0, seed=3)
    for r in reqs[:4]:                   # only 4 of 6 complete
        r.t_first_token = r.arrival_time + 0.01
        r.t_done = r.arrival_time + 0.1
    lat = latency_summary(reqs)
    assert lat["requests"] == 4
    assert lat["submitted"] == 6
    assert lat["unfinished"] == 2
    empty = latency_summary(synthetic_trace(3, vocab_size=64, seed=4))
    assert empty == {"requests": 0, "submitted": 3, "unfinished": 3}


def test_block_allocator_cycle():
    ba = BlockAllocator(num_blocks=RESERVED_BLOCKS + 4, block_size=8)
    assert ba.capacity() == 4 and ba.available() == 4
    # positions written: prompt_len + max_new - 1 (last token never cached)
    assert ba.blocks_for(1, 1) == 1      # 1 position -> 1 page
    assert ba.blocks_for(8, 1) == 1      # 8 positions, exact fit
    assert ba.blocks_for(8, 2) == 2      # 9 positions spill a page
    assert ba.blocks_for(3, 6) == 1
    a = ba.alloc(2)
    assert a == [RESERVED_BLOCKS, RESERVED_BLOCKS + 1]   # dense, low first
    assert ba.available() == 2 and ba.in_use() == 2
    with pytest.raises(RuntimeError):
        ba.alloc(3)                      # pool exhausted
    ba.free(a)
    assert ba.available() == 4
    with pytest.raises(ValueError):
        ba.free([a[0]])                  # double free
    with pytest.raises(ValueError):
        ba.free([0])                     # reserved sentinel page
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=RESERVED_BLOCKS, block_size=8)
