"""Continuous-batching engine tests: padded-prefill correctness, greedy
equivalence with unbatched decode, fixed-shape (no-recompile) contract, and
the slot/queue plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, RequestQueue, ServingEngine, SlotAllocator
from repro.serving.trace import latency_summary, synthetic_trace
from repro.training import serve_step as SS

CFG = get_config("granite-3-8b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return T.init_params(CFG, jax.random.PRNGKey(0))


def _requests(lens, max_new=6, arrivals=None, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab_size, L)
                    .astype(np.int32),
                    max_new_tokens=max_new,
                    arrival_time=0.0 if arrivals is None else arrivals[i])
            for i, L in enumerate(lens)]


# --------------------------------------------------------------------------
# padded prefill correctness (the left-pad-attends-over-pad-0 bug)
# --------------------------------------------------------------------------
def test_leftpad_positions():
    pos = T.leftpad_positions(jnp.asarray([3, 5, 1]), 5)
    np.testing.assert_array_equal(
        np.asarray(pos),
        [[-1, -1, 0, 1, 2], [0, 1, 2, 3, 4], [-1, -1, -1, -1, 0]])


def test_padded_prefill_matches_unpadded(params):
    """Left-padded mixed-batch prefill with lengths == per-row unpadded."""
    rng = np.random.default_rng(3)
    lens = [3, 8, 5]
    S = 8
    prompts = [rng.integers(2, CFG.vocab_size, L).astype(np.int32)
               for L in lens]
    batch = np.zeros((len(lens), S), np.int32)
    for i, p in enumerate(prompts):
        batch[i, S - len(p):] = p
    last, _, _ = SS.prefill(params, CFG, jnp.asarray(batch), cache_len=32,
                            lengths=jnp.asarray(lens))
    for i, p in enumerate(prompts):
        ref, _, _ = SS.prefill(params, CFG, jnp.asarray(p)[None],
                               cache_len=32)
        np.testing.assert_allclose(np.asarray(last[i], np.float32),
                                   np.asarray(ref[0], np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_padded_prefill_sliding_window_pads_dropped(params):
    """Pads must not clobber ring-buffer slots when the window is shorter
    than the padded length (pos -1 would alias slot window-1)."""
    import dataclasses
    wcfg = dataclasses.replace(CFG, window=8)
    wparams = T.init_params(wcfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(6)
    L, S = 6, 12                          # 6 pads > window slack
    prompt = rng.integers(2, wcfg.vocab_size, L).astype(np.int32)
    batch = np.zeros((1, S), np.int32)
    batch[0, S - L:] = prompt
    last, _, _ = SS.prefill(wparams, wcfg, jnp.asarray(batch), cache_len=32,
                            lengths=jnp.asarray([L]))
    ref, _, _ = SS.prefill(wparams, wcfg, jnp.asarray(prompt)[None],
                           cache_len=32)
    np.testing.assert_allclose(np.asarray(last[0], np.float32),
                               np.asarray(ref[0], np.float32),
                               rtol=2e-4, atol=2e-4)


def test_padded_prefill_decode_positions_consistent(params):
    """Decode after masked prefill continues at the TRUE prompt length and
    matches unpadded prefill+decode of the same prompt."""
    rng = np.random.default_rng(4)
    lens = [3, 6]
    S = 6
    batch = np.zeros((2, S), np.int32)
    prompts = [rng.integers(2, CFG.vocab_size, L).astype(np.int32)
               for L in lens]
    for i, p in enumerate(prompts):
        batch[i, S - len(p):] = p
    last, caches, _ = SS.prefill(params, CFG, jnp.asarray(batch),
                                 cache_len=32, lengths=jnp.asarray(lens))
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)[:, None]     # true lengths, not S
    logits, _ = SS.decode_step(params, CFG, tok, pos, caches)
    for i, p in enumerate(prompts):
        out = SS.generate(params, CFG, jnp.asarray(p)[None],
                          max_new_tokens=2, cache_len=32)
        assert int(tok[i, 0]) == int(out[0, 0])
        assert int(jnp.argmax(logits[i])) == int(out[0, 1])


# --------------------------------------------------------------------------
# engine: greedy equivalence + fixed-shape contract
# --------------------------------------------------------------------------
def test_engine_matches_unbatched_greedy(params):
    """Ragged prompts through slot recycling == per-request unbatched
    greedy decode, token for token."""
    reqs = _requests([3, 9, 12, 5, 7], max_new=6)
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                        prefill_len=16)
    done = eng.run(reqs)
    assert len(done) == 5
    for r in done:
        ref = SS.generate(params, CFG, jnp.asarray(r.prompt)[None],
                          max_new_tokens=6, cache_len=48)
        np.testing.assert_array_equal(np.asarray(r.generated),
                                      np.asarray(ref[0]))


def test_engine_single_compiled_shape(params):
    """Slot recycling admits queued requests with NO recompilation: one
    compiled prefill shape + one compiled decode shape for the whole trace,
    including arrivals landing mid-decode."""
    arrivals = [0.0, 0.0, 0.0, 0.05, 0.1, 0.15]
    reqs = _requests([4, 11, 6, 3, 16, 8], max_new=5, arrivals=arrivals)
    eng = ServingEngine(params, CFG, num_slots=3, cache_len=64,
                        prefill_len=16)
    done = eng.run(reqs)
    assert len(done) == 6
    assert eng.stats["prefill_calls"] == 6
    assert eng.stats["prefill_traces"] == 1, eng.stats
    assert eng.stats["decode_traces"] == 1, eng.stats


def test_engine_sampled_continuations_differ(params):
    """Per-request key streams: identical prompts in different slots/batches
    must not sample identical continuations (the PRNGKey(i)-reuse bug)."""
    rng = np.random.default_rng(9)
    prompt = rng.integers(2, CFG.vocab_size, 6).astype(np.int32)
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=12)
            for i in range(4)]
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=48,
                        prefill_len=16, temperature=1.0)
    done = eng.run(reqs)
    gens = {tuple(r.generated) for r in done}
    assert len(gens) > 1, "all requests sampled the same continuation"


def test_engine_rejects_oversized(params):
    eng = ServingEngine(params, CFG, num_slots=2, cache_len=32,
                        prefill_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.arange(9, dtype=np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=32))


def test_engine_rejects_stateful_archs(params):
    cfg = get_config("rwkv6-3b", smoke=True)
    with pytest.raises(NotImplementedError):
        ServingEngine(params, cfg)


# --------------------------------------------------------------------------
# plumbing: slots, queue, trace
# --------------------------------------------------------------------------
def test_slot_allocator_cycle():
    sa = SlotAllocator(2)
    a, b = sa.alloc(), sa.alloc()
    assert {a, b} == {0, 1} and sa.available() == 0
    with pytest.raises(RuntimeError):
        sa.alloc()
    sa.free(a)
    assert sa.alloc() == a
    sa.free(b)
    with pytest.raises(ValueError):
        sa.free(b)


def test_queue_arrival_gating():
    q = RequestQueue()
    q.submit(Request(uid=0, prompt=np.ones(2, np.int32), max_new_tokens=1,
                     arrival_time=0.0))
    q.submit(Request(uid=1, prompt=np.ones(2, np.int32), max_new_tokens=1,
                     arrival_time=1.0))
    assert q.pop_ready(0.5).uid == 0
    assert q.pop_ready(0.5) is None      # uid 1 hasn't arrived yet
    assert q.next_arrival() == 1.0
    assert q.pop_ready(2.0).uid == 1
    assert not q


def test_synthetic_trace_and_summary():
    reqs = synthetic_trace(10, vocab_size=64, rate=100.0, seed=2)
    arrivals = [r.arrival_time for r in reqs]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    assert all(4 <= r.prompt_len <= 16 for r in reqs)
    for i, r in enumerate(reqs):
        r.t_first_token = r.arrival_time + 0.01
        r.t_done = r.arrival_time + 0.1 + 0.01 * i
    lat = latency_summary(reqs)
    assert 0.1 <= lat["p50_latency_s"] <= 0.2
    assert lat["p50_ttft_s"] == pytest.approx(0.01)
