"""Sharding-policy invariants (hypothesis property tests) + spec checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import ShardingPolicy
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh
from repro.training.train_step import TrainConfig


@pytest.fixture(scope="module")
def policy():
    mesh = make_host_mesh()
    return ShardingPolicy(mesh, get_config("granite-3-8b", smoke=True))


def _divisible(spec: P, shape, mesh) -> bool:
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size:
            return False
    return True


@settings(max_examples=200, deadline=None)
@given(shape=st.lists(st.integers(1, 4096), min_size=0, max_size=4),
       path=st.sampled_from([
           "embed", "segments/0/attn/wq", "segments/0/moe/experts/w_up",
           "eager/0/mlp/w_down", "final_norm/scale", "unembed",
           "encoder/layers/attn/wk"]))
def test_param_spec_always_divisible(policy, shape, path):
    """THE invariant: the policy never requests an indivisible sharding."""
    spec = policy.param_spec(path, shape)
    assert _divisible(spec, shape, policy.mesh)


@settings(max_examples=100, deadline=None)
@given(shape=st.lists(st.integers(1, 2048), min_size=1, max_size=5))
def test_batch_and_cache_specs_divisible(policy, shape):
    assert _divisible(policy.batch_spec(shape), shape, policy.mesh)
    assert _divisible(policy.cache_spec("segments/0/self/k", shape), shape,
                      policy.mesh)


def test_stacked_layer_dim_never_sharded(policy):
    spec = policy.param_spec("segments/0/attn/wq", (48, 4096, 4096))
    assert spec[0] is None   # 48 divides 16 but is the scan unit


def test_expert_dim_on_model_axis():
    # need a mesh with a model axis > 1 to observe EP
    import jax as _jax
    if len(_jax.devices()) < 2:
        pytest.skip("single-device host: model axis size 1")
    mesh = make_host_mesh(model=2)
    pol = ShardingPolicy(mesh, get_config("deepseek-moe-16b", smoke=True))
    spec = pol.param_spec("segments/0/moe/experts/w_up", (27, 64, 2048, 1408))
    assert spec[1] == "model"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_state_shardings_build(arch):
    """Shardings construct for every arch's full-size state (abstract)."""
    cfg = get_config(arch)           # FULL config — shapes only, no alloc
    mesh = make_host_mesh()
    pol = ShardingPolicy(mesh, cfg)
    state = S.train_state_specs(cfg, TrainConfig(microbatches=1))
    sh = pol.tree_shardings(state)
    leaves = jax.tree.leaves(sh)
    assert leaves and all(l is not None for l in leaves)
