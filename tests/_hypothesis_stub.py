"""Fallback for ``hypothesis`` when it is not installed.

conftest.py installs this module into ``sys.modules`` as ``hypothesis`` /
``hypothesis.strategies`` so that property-test modules collect and run
everywhere.  Instead of shrinking random search, each ``@given`` test runs a
small deterministic set of examples: the strategy minimum first, then the
maximum, then pseudo-random draws seeded from the test name (stable across
runs).  ``max_examples`` is honoured but capped so the tier-1 lane stays fast.
"""

from __future__ import annotations

import inspect
import zlib
from typing import Any, List, Sequence

import numpy as np

MAX_EXAMPLES_CAP = 25
_DEFAULT_EXAMPLES = 10


class Strategy:
    def example(self, rng: np.random.Generator, edge: str = "") -> Any:
        raise NotImplementedError


class _Integers(Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng, edge=""):
        if edge == "min":
            return self.lo
        if edge == "max":
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def example(self, rng, edge=""):
        if edge == "min":
            return self.lo
        if edge == "max":
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Lists(Strategy):
    def __init__(self, elem: Strategy, min_size: int = 0, max_size: int = 10):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng, edge=""):
        if edge == "min":
            n = self.min_size
        elif edge == "max":
            n = self.max_size
        else:
            n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.example(rng) for _ in range(n)]


class _SampledFrom(Strategy):
    def __init__(self, seq: Sequence[Any]):
        self.seq = list(seq)

    def example(self, rng, edge=""):
        if edge == "min":
            return self.seq[0]
        if edge == "max":
            return self.seq[-1]
        return self.seq[int(rng.integers(len(self.seq)))]


class _Booleans(Strategy):
    def example(self, rng, edge=""):
        if edge == "min":
            return False
        if edge == "max":
            return True
        return bool(rng.integers(2))


def integers(min_value: int, max_value: int) -> Strategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **_: Any) -> Strategy:
    return _Floats(min_value, max_value)


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    return _Lists(elements, min_size, max_size)


def sampled_from(elements: Sequence[Any]) -> Strategy:
    return _SampledFrom(elements)


def booleans() -> Strategy:
    return _Booleans()


def given(*args: Any, **strategies: Strategy):
    if args:
        raise TypeError("stub @given supports keyword strategies only")

    def deco(fn):
        fixture_names: List[str] = [
            p for p in inspect.signature(fn).parameters if p not in strategies]

        def run(**fixtures):
            n = getattr(run, "_stub_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(
                zlib.adler32(fn.__qualname__.encode()))
            for i in range(n):
                edge = "min" if i == 0 else ("max" if i == 1 else "")
                drawn = {k: s.example(rng, edge)
                         for k, s in strategies.items()}
                fn(**fixtures, **drawn)

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        # pytest must see only the fixture params (no __wrapped__: pytest
        # follows it and would demand fixtures for the strategy args)
        run.__signature__ = inspect.Signature(
            [inspect.Parameter(p, inspect.Parameter.POSITIONAL_OR_KEYWORD)
             for p in fixture_names])
        return run

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline: Any = None,
             **_: Any):
    def deco(fn):
        fn._stub_max_examples = min(int(max_examples), MAX_EXAMPLES_CAP)
        return fn

    return deco
