"""Model-layer unit tests: attention, chunked attention, RWKV, SSM, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.chunked_attention import attend_chunked


def _rand(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_chunked_matches_exact(rng, causal, window):
    B, S, H, Kv, Dh = 2, 256, 8, 4, 32
    q, k, v = (_rand(rng, B, S, H, Dh), _rand(rng, B, S, Kv, Dh),
               _rand(rng, B, S, Kv, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    want = A.attend(q, k, v, pos, pos, n_kv_heads=Kv, causal=causal,
                    window=window)
    got = attend_chunked(q, k, v, pos, pos, n_kv_heads=Kv, causal=causal,
                         window=window, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_chunked_gradients_match(rng):
    B, S, H, Kv, Dh = 1, 128, 4, 2, 16
    q, k, v = (_rand(rng, B, S, H, Dh), _rand(rng, B, S, Kv, Dh),
               _rand(rng, B, S, Kv, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    def f_chunk(q):
        return jnp.sum(attend_chunked(q, k, v, pos, pos, n_kv_heads=Kv,
                                      causal=True, q_chunk=32,
                                      k_chunk=32) ** 2)

    def f_full(q):
        return jnp.sum(A.attend(q, k, v, pos, pos, n_kv_heads=Kv,
                                causal=True) ** 2)

    np.testing.assert_allclose(jax.grad(f_chunk)(q), jax.grad(f_full)(q),
                               rtol=1e-3, atol=1e-3)


def test_gqa_equals_mha_when_kv_repeated(rng):
    """GQA with repeated KV heads == MHA with explicit expansion."""
    B, S, H, Kv, Dh = 1, 16, 4, 2, 8
    q = _rand(rng, B, S, H, Dh)
    k = _rand(rng, B, S, Kv, Dh)
    v = _rand(rng, B, S, Kv, Dh)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    got = A.attend(q, k, v, pos, pos, n_kv_heads=Kv, causal=True)
    k_full = jnp.repeat(k, H // Kv, axis=2)
    v_full = jnp.repeat(v, H // Kv, axis=2)
    want = A.attend(q, k_full, v_full, pos, pos, n_kv_heads=H, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ring_buffer_cache_sliding_window(rng):
    """Decode through a ring buffer == full-cache attention with window."""
    B, S, H, Kv, Dh, W = 1, 32, 2, 2, 8, 8
    p = A.attention_init(jax.random.PRNGKey(0), 16, H, Kv, Dh, jnp.float32)
    x = _rand(rng, B, S, 16, scale=0.3)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    full, _ = A.attention_apply(p, x, n_heads=H, n_kv_heads=Kv, head_dim=Dh,
                                positions=pos, causal=True, window=W)
    ring = A.init_cache(B, W, Kv, Dh, jnp.float32)   # ring of size W
    outs = []
    for t in range(S):
        o, ring = A.attention_apply(p, x[:, t:t + 1], n_heads=H,
                                    n_kv_heads=Kv, head_dim=Dh,
                                    positions=pos[:, t:t + 1], causal=True,
                                    window=W, cache=ring)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, full, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# rwkv
# --------------------------------------------------------------------------
def test_wkv_chunked_equals_serial(rng):
    B, H, S, Dh = 2, 2, 64, 16
    r, k, v = (_rand(rng, B, H, S, Dh, scale=0.5) for _ in range(3))
    lw = -jnp.exp(jnp.clip(_rand(rng, B, H, S, Dh), -8, 1))
    u = _rand(rng, H, Dh, scale=0.5)
    ys, ss = R.wkv_serial(r, k, v, lw, u)
    for chunk in (8, 16, 32):
        yc, sc = R.wkv_chunked(r, k, v, lw, u, chunk=chunk)
        np.testing.assert_allclose(yc, ys, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(sc, ss, rtol=2e-4, atol=2e-4)


def test_wkv_state_continuation(rng):
    """Processing [a; b] == processing a then b from the carried state."""
    B, H, S, Dh = 1, 2, 32, 8
    r, k, v = (_rand(rng, B, H, S, Dh, scale=0.5) for _ in range(3))
    lw = -jnp.exp(jnp.clip(_rand(rng, B, H, S, Dh), -8, 1))
    u = _rand(rng, H, Dh, scale=0.5)
    y_all, s_all = R.wkv_serial(r, k, v, lw, u)
    y1, s1 = R.wkv_serial(r[:, :, :16], k[:, :, :16], v[:, :, :16],
                          lw[:, :, :16], u)
    y2, s2 = R.wkv_serial(r[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                          lw[:, :, 16:], u, s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 2), y_all,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, s_all, rtol=1e-5, atol=1e-5)


def test_wkv_decay_forgets(rng):
    """With strong decay, old context stops influencing outputs."""
    B, H, S, Dh = 1, 1, 8, 4
    r, k, v = (_rand(rng, B, H, S, Dh, scale=0.5) for _ in range(3))
    lw = jnp.full((B, H, S, Dh), -8.0)   # near-total per-step decay
    u = jnp.zeros((H, Dh))
    s0a = jnp.zeros((B, H, Dh, Dh))
    s0b = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, H, Dh, Dh)), jnp.float32)
    ya, _ = R.wkv_serial(r, k, v, lw, u, s0a)
    yb, _ = R.wkv_serial(r, k, v, lw, u, s0b)
    np.testing.assert_allclose(ya[:, :, 2:], yb[:, :, 2:], atol=1e-2)


# --------------------------------------------------------------------------
# ssm
# --------------------------------------------------------------------------
def test_ssm_scan_vs_stepwise(rng):
    d_model, d_inner, n = 16, 32, 4
    p = S.ssm_init(jax.random.PRNGKey(1), d_model, d_inner, n, jnp.float32)
    x = _rand(rng, 1, 24, d_model, scale=0.3)
    y_all, (state_all, conv_all) = S.ssm_apply(p, x)
    state = conv = None
    ys = []
    for t in range(24):
        y, (state, conv) = S.ssm_apply(p, x[:, t:t + 1], state=state,
                                       conv_state=conv)
        ys.append(y)
    got = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(got, y_all, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(state, state_all, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# moe
# --------------------------------------------------------------------------
def test_moe_capacity_saturation(rng):
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, 32, 16, 8, 0, "swiglu", jnp.float32)
    x = _rand(rng, 2, 16, 32, scale=0.5)
    y1, _ = M.moe_apply(p, x, n_experts=8, top_k=2, mlp_kind="swiglu",
                        capacity_factor=8.0)
    y2, _ = M.moe_apply(p, x, n_experts=8, top_k=2, mlp_kind="swiglu",
                        capacity_factor=64.0)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_moe_top1_selects_single_expert(rng):
    """With top_k=1 and huge capacity, output == the argmax expert's FFN."""
    key = jax.random.PRNGKey(0)
    E, D, F = 4, 16, 32
    p = M.moe_init(key, D, F, E, 0, "gelu", jnp.float32)
    x = _rand(rng, 1, 8, D, scale=0.5)
    y, _ = M.moe_apply(p, x, n_experts=E, top_k=1, mlp_kind="gelu",
                       capacity_factor=32.0)
    logits = x.reshape(-1, D) @ p["router"]
    eidx = np.asarray(jnp.argmax(logits, -1))
    for t in range(8):
        e = int(eidx[t])
        xe = x.reshape(-1, D)[t]
        he = jax.nn.gelu(xe @ p["experts"]["w_up"][e])
        ye = he @ p["experts"]["w_down"][e]
        np.testing.assert_allclose(y.reshape(-1, D)[t], ye, rtol=1e-4,
                                   atol=1e-4)


def test_moe_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    key = jax.random.PRNGKey(0)
    E, D = 8, 16
    p = M.moe_init(key, D, 32, E, 0, "gelu", jnp.float32)
    p = dict(p, router=jnp.zeros((D, E)))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64, D)),
                    jnp.float32)
    _, aux = M.moe_apply(p, x, n_experts=E, top_k=1, mlp_kind="gelu")
    # uniform probs = 1/E; load depends on tie-breaking — bounded sanity
    assert 0.5 <= float(aux) <= float(E)
