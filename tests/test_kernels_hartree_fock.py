"""Hartree-Fock twoel kernel vs oracle + physics property tests."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hartree_fock import ops, ref


@pytest.mark.parametrize("natoms,ngauss", [(8, 3), (16, 3), (8, 6)])
def test_matches_oracle(natoms, ngauss):
    pos = ref.helium_lattice(natoms)
    dens = ref.initial_density(natoms)
    want = ops.fock_xla(pos, dens, ngauss=ngauss)
    got = ops.fock_pallas(pos, dens, ngauss=ngauss, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fock_symmetric():
    """F must be symmetric for symmetric density (gather == scatter proof)."""
    pos = ref.helium_lattice(16)
    dens = ref.initial_density(16)
    f = np.asarray(ops.fock_xla(pos, dens))
    np.testing.assert_allclose(f, f.T, rtol=1e-4, atol=1e-5)


def test_linear_in_density():
    """F[a*D1 + b*D2] == a*F[D1] + b*F[D2] (contraction is linear)."""
    pos = ref.helium_lattice(8)
    d1 = ref.initial_density(8)
    rng = np.random.default_rng(3)
    a2 = rng.standard_normal((8, 8)) * 0.1
    d2 = jnp.asarray((a2 + a2.T) / 2, jnp.float32)
    lhs = ops.fock_xla(pos, 2.0 * d1 + 0.5 * d2)
    rhs = 2.0 * ops.fock_xla(pos, d1) + 0.5 * ops.fock_xla(pos, d2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


def test_eri_permutation_symmetry():
    """(ij|kl) == (ji|kl) == (ij|lk) == (kl|ij) — the 8-fold symmetry the
    paper's scatter kernel exploits and our gather form absorbs."""
    pos = ref.helium_lattice(6)
    basis = ref.sto_basis(3)
    eri = np.asarray(ref.eri_tensor(pos, basis))
    np.testing.assert_allclose(eri, eri.transpose(1, 0, 2, 3), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(eri, eri.transpose(0, 1, 3, 2), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(eri, eri.transpose(2, 3, 0, 1), rtol=1e-4,
                               atol=1e-6)


def test_boys_limits():
    """F0(0) = 1; F0(t) ~ 0.5*sqrt(pi/t) for large t."""
    t = jnp.asarray([0.0, 1e-9, 30.0])
    f = np.asarray(ref.boys_f0(t))
    assert abs(f[0] - 1.0) < 1e-6
    assert abs(f[1] - 1.0) < 1e-5
    assert abs(f[2] - 0.5 * np.sqrt(np.pi / 30.0)) < 1e-5
