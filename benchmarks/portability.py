"""Paper Table 5 / Eq. 4 — the performance-portability metric Phi-bar.

The paper computes e_i = portable_perf / vendor_perf per platform and
averages.  Here the portable implementation is the Pallas kernel and the
"vendor" baseline is what XLA autotunes from idiomatic jnp; platforms on
this host are {cpu-xla, cpu-interpret} (on a TPU deployment the same harness
compares pallas-TPU vs XLA-TPU — the metric machinery is identical).
Derived column: per-case e_i, then one Phi row per proxy app.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  (registers all kernel backends)
from benchmarks.common import emit, time_call
from repro.core.metrics import Efficiency, phi_bar
from repro.core.portable import registry
from repro.kernels.hartree_fock import ops as hf_ops
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import ops as mb_ops
from repro.kernels.stencil7 import ops as st_ops


def run() -> None:
    rng = np.random.default_rng(0)
    phi_terms = {}

    # stencil
    u = jnp.asarray(rng.standard_normal((64, 64, 128)), jnp.float32)
    t_ref = time_call(st_ops.laplacian_xla, u)
    t_port = time_call(st_ops.laplacian_pallas, u, by=32, interpret=True,
                       iters=3, warmup=1)
    e = Efficiency("cpu", "stencil7.fp32", 1.0 / t_port, 1.0 / t_ref)
    phi_terms["stencil7"] = [e]
    emit("phi.e.stencil7.fp32", t_port, f"e={e.e:.3f}")

    # babelstream
    n = 1 << 20
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    args = {"copy": (a,), "mul": (a,), "add": (a, b), "triad": (a, b),
            "dot": (a, b)}
    terms = []
    for op in ("copy", "mul", "add", "triad", "dot"):
        k = registry.get(f"babelstream.{op}")
        t_ref = k.time_backend(*args[op], backend="xla")
        t_port = k.time_backend(*args[op], backend="pallas_interpret",
                                iters=3, warmup=1)
        e = Efficiency("cpu", f"babelstream.{op}", 1.0 / t_port, 1.0 / t_ref)
        terms.append(e)
        emit(f"phi.e.babelstream.{op}", t_port, f"e={e.e:.3f}")
    phi_terms["babelstream"] = terms

    # minibude
    deck = mb_ops.make_deck(natpro=128, natlig=8, nposes=1024, seed=0)
    t_ref = time_call(mb_ops.fasten_xla, *deck)
    t_port = time_call(mb_ops.fasten_pallas, *deck, interpret=True, iters=3,
                       warmup=1)
    e = Efficiency("cpu", "minibude", 1.0 / t_port, 1.0 / t_ref)
    phi_terms["minibude"] = [e]
    emit("phi.e.minibude", t_port, f"e={e.e:.3f}")

    # hartree-fock
    pos = hf_ref.helium_lattice(8)
    dens = hf_ref.initial_density(8)
    t_ref = time_call(hf_ops.fock_xla, pos, dens, iters=5)
    t_port = time_call(hf_ops.fock_pallas, pos, dens, interpret=True,
                       iters=2, warmup=1)
    e = Efficiency("cpu", "hartree_fock", 1.0 / t_port, 1.0 / t_ref)
    phi_terms["hartree_fock"] = [e]
    emit("phi.e.hartree_fock", t_port, f"e={e.e:.3f}")

    for app, terms in phi_terms.items():
        emit(f"phi.{app}", 0.0, f"phi={phi_bar(terms):.3f}")


if __name__ == "__main__":
    run()
