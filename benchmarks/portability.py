"""Paper Table 5 / Eq. 4 — the performance-portability metric Phi-bar, tuned.

Registry-driven: instead of hand-rolling each kernel's timing, this module
walks ``repro.core.portable.registry``, picks the portable backend for this
host (``pallas`` on TPU, ``pallas_interpret`` elsewhere — unavailable
backends are *skipped with a reason*, never crashed into), autotunes it over
its declared block/tile space via ``repro.core.tuning`` (persistent cache:
repeat runs skip the re-search), and computes per-kernel e_i and per-app
Phi-bar from the *tuned* timing — untuned portable kernels understate Eq. 4
(Godoy et al., 2023).  Input shapes come from the ``CASES`` table below;
``smoke=True`` shrinks every case to seconds-scale sizes for the PR-time
drift lane (``python -m benchmarks.run --smoke --only portability``).

Alongside the ``name,us_per_call,derived`` CSV rows it writes a
machine-readable artifact (default ``BENCH_portability.json``):

    {
      "schema": "repro.portability/v1",
      "platform": "cpu" | "tpu" | ...,
      "smoke": bool,
      "kernels": [            // one record per registry kernel
        {"kernel": str, "app": str,            // app = proxy-app grouping
         "backend": str | null,                // portable backend timed
         "baseline_backend": str | null,       // oracle timed against
         "shape": str, "dtype": str,           // tuning-key fields
         "tuned_params": {},                   // {} = declared defaults won
         "seconds_default": float,             // at the declared defaults
         "seconds_tuned": float,
         "seconds_baseline": float,
         "e_i": float,                         // tuned portable / baseline
         "tuning_cached": bool,                // true = cache hit, no sweep
         "swept_points": int,
         "skipped": str | null}],              // reason when not measured
      "distributed_kernels": [...],            // same record shape, one per
                                               // shard_pallas composite
      "tuning_quality": {...},                 // model-vs-exhaustive regret
                                               // probe (PR 9), see
                                               // _model_search_regret
      "phi": {"per_app": {app: float}, "overall": float}
    }

``distributed_kernels`` extends the sweep to the composite ``shard_pallas``
backends (shard_map around the Pallas kernels): tuned over their
tile x shard spaces and compared against the same single-device oracle.  On
a 1-device host (the smoke drift lane) each records an availability skip;
run under forced host devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=8``) to measure them.  They never enter Phi-bar — Eq. 4 is the
single-device portability metric; the device-count axis lives in
``benchmarks/scaling.py``.

The paper notes Phi-bar can mask per-platform under-performance; the
artifact therefore always carries the raw per-kernel e_i next to the means.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  (registers all kernel backends)
from benchmarks.common import emit
from repro.core.metrics import Efficiency, phi_bar
from repro.core.portable import registry
from repro.core.tuning import TuningCache, make_key, tune
from repro.kernels.hartree_fock import ref as hf_ref
from repro.kernels.minibude import ops as mb_ops

ARTIFACT = "BENCH_portability.json"
SCHEMA = "repro.portability/v1"
#: composite backends swept into the distributed_kernels section
DIST_BACKEND = "shard_pallas"


@dataclasses.dataclass(frozen=True)
class Case:
    """Concrete inputs for one registry kernel at full and smoke sizes."""

    app: str                                   # proxy-app grouping for Phi
    make_args: Callable[[bool], Tuple[tuple, dict]]  # smoke -> (args, kwargs)
    iters: int = 3
    warmup: int = 1


def _rng():
    return np.random.default_rng(0)


def _f32(a):
    return jnp.asarray(a, jnp.float32)


def _stencil_case(smoke: bool):
    # smoke keeps ny=64 so the declared default by=64 stays admissible
    shape = (4, 64, 128) if smoke else (64, 64, 128)
    return (_f32(_rng().standard_normal(shape)),), {}


def _stream_case(smoke: bool, nargs: int):
    # smoke still needs >= 512*128 elements so the declared default
    # block_rows=512 is admissible
    n = 1 << 16 if smoke else 1 << 20
    r = _rng()
    arrays = tuple(_f32(r.standard_normal(n)) for _ in range(nargs))
    return arrays, {}


def _minibude_case(smoke: bool):
    if smoke:
        deck = mb_ops.make_deck(natpro=32, natlig=4, nposes=256, seed=0)
    else:
        deck = mb_ops.make_deck(natpro=128, natlig=8, nposes=1024, seed=0)
    return deck, {}


def _hf_case(smoke: bool):
    n = 8
    return (hf_ref.helium_lattice(n), hf_ref.initial_density(n)), {}


def _flash_case(smoke: bool):
    b, h, s, dh = (1, 2, 128, 64) if smoke else (1, 4, 512, 64)
    r = _rng()
    q = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    k = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    v = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    return (q, k, v), {}


def _wkv_case(smoke: bool):
    b, h, s, dh = (1, 2, 64, 32) if smoke else (2, 2, 128, 32)
    r = _rng()
    rr = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    kk = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    vv = _f32(r.standard_normal((b, h, s, dh)) * 0.5)
    lw = -jnp.exp(jnp.clip(_f32(r.standard_normal((b, h, s, dh))), -8, 1))
    u = _f32(r.standard_normal((h, dh)) * 0.5)
    return (rr, kk, vv, lw, u), {}


CASES: Dict[str, Case] = {
    "stencil7": Case("stencil7", _stencil_case),
    "babelstream.copy": Case("babelstream", lambda s: _stream_case(s, 1)),
    "babelstream.mul": Case("babelstream", lambda s: _stream_case(s, 1)),
    "babelstream.add": Case("babelstream", lambda s: _stream_case(s, 2)),
    "babelstream.triad": Case("babelstream", lambda s: _stream_case(s, 2)),
    "babelstream.dot": Case("babelstream", lambda s: _stream_case(s, 2)),
    "minibude.fasten": Case("minibude", _minibude_case, iters=2),
    "hartree_fock.twoel": Case("hartree_fock", _hf_case, iters=2),
    "attention.flash": Case("flash_attention", _flash_case),
    "rwkv6.wkv": Case("rwkv6", _wkv_case),
}


def _portable_backend(kernel) -> Optional[str]:
    """pallas if it can run here, else the interpret twin, else nothing."""
    for name in ("pallas", "pallas_interpret"):
        b = kernel.backends.get(name)
        if b is not None and b.is_available():
            return name
    return None


def _skip(name: str, app: str, reason: str,
          backend: Optional[str] = None) -> Dict[str, Any]:
    return {"kernel": name, "app": app, "backend": backend,
            "baseline_backend": None, "shape": "", "dtype": "",
            "tuned_params": {},
            "seconds_default": None, "seconds_tuned": None,
            "seconds_baseline": None, "e_i": None, "tuning_cached": False,
            "swept_points": 0, "skipped": reason}


def _measure_backend(kernel, case, backend: str, cache: TuningCache,
                     smoke: bool) -> Tuple[Dict[str, Any], Efficiency]:
    """Tune + time one backend of one kernel against its oracle (shared by
    the portable walk and the distributed shard_pallas section).  Returns
    the artifact record and the Efficiency term behind its ``e_i``."""
    baseline = kernel.oracle
    iters = 1 if smoke else case.iters
    warmup = 1 if smoke else case.warmup
    max_points = 2 if smoke else None
    args, kwargs = case.make_args(smoke)
    key = make_key(kernel, *args, backend=backend, **kwargs)

    t_base = kernel.time_backend(*args, backend=baseline, iters=iters,
                                 warmup=warmup, **kwargs)
    t_default = kernel.time_backend(*args, backend=backend, iters=iters,
                                    warmup=warmup, **kwargs)
    tr = tune(kernel, *args, backend=backend, cache=cache, iters=iters,
              warmup=warmup, max_points=max_points, **kwargs)
    # a cache hit only skips the *search*: its seconds were measured in
    # another session (different load/iters), so re-time at the cached
    # params — e_i must never be a ratio of cross-session timings
    t_at_best = tr.seconds
    if tr.cached:
        t_at_best = (t_default if not tr.params else
                     kernel.time_backend(*args, backend=backend, iters=iters,
                                         warmup=warmup, **tr.params,
                                         **kwargs))
    # the declared defaults are always an admissible configuration: if
    # the (possibly truncated) sweep did worse, the defaults win
    if tr.skipped is not None or t_default <= t_at_best:
        t_tuned, tuned_params = t_default, {}
    else:
        t_tuned, tuned_params = t_at_best, tr.params

    e = Efficiency(key.platform, kernel.name, 1.0 / t_tuned, 1.0 / t_base)
    return {
        "kernel": kernel.name, "app": case.app, "backend": backend,
        "baseline_backend": baseline, "shape": key.shape,
        "dtype": key.dtype,
        "tuned_params": tuned_params, "seconds_default": t_default,
        "seconds_tuned": t_tuned, "seconds_baseline": t_base,
        "e_i": e.e, "tuning_cached": tr.cached,
        "swept_points": len(tr.swept), "skipped": tr.skipped,
    }, e


#: kernel whose declared grid anchors the model-vs-exhaustive regret probe
REGRET_KERNEL = "stencil7"


def _model_search_regret(smoke: bool) -> Optional[Dict[str, Any]]:
    """Search-quality probe: how much does trusting the static cost model
    cost vs timing the whole grid?

    Runs ``tune(search="model")`` and ``tune(search="exhaustive")`` on the
    same kernel/inputs against throwaway caches, reports the timed-point
    savings and the regret ratio, and proves the provenance contract: the
    cached ``"model"`` entry is never served to an exhaustive caller.
    Both sweeps time in this process, so the ratio compares like with like.
    """
    import tempfile

    kernel = registry.get(REGRET_KERNEL)
    backend = _portable_backend(kernel)
    if backend is None:
        return {"kernel": REGRET_KERNEL, "backend": None,
                "skipped": "no portable backend available"}
    # the smoke-size case keeps the probe seconds-scale at every lane
    args, kwargs = CASES[REGRET_KERNEL].make_args(True)

    with tempfile.TemporaryDirectory() as tmp:
        cache = TuningCache(path=f"{tmp}/regret.json")
        tr_ex = tune(kernel, *args, backend=backend, cache=cache,
                     iters=1, warmup=1, search="exhaustive", **kwargs)
        tr_model = tune(kernel, *args, backend=backend,
                        cache=TuningCache(path=f"{tmp}/model.json"),
                        iters=1, warmup=1, search="model", **kwargs)
        if tr_ex.skipped or tr_model.skipped:
            return {"kernel": REGRET_KERNEL, "backend": backend,
                    "skipped": tr_ex.skipped or tr_model.skipped}
        # provenance: a cached partial-search entry must trigger a fresh
        # sweep — not a hit — when the caller asks for exhaustive
        tr_again = tune(kernel, *args, backend=backend,
                        cache=TuningCache(path=f"{tmp}/model.json"),
                        iters=1, warmup=1, search="exhaustive", **kwargs)

    return {
        "kernel": REGRET_KERNEL, "backend": backend, "skipped": None,
        "params_exhaustive": tr_ex.params, "params_model": tr_model.params,
        "seconds_exhaustive": tr_ex.seconds,
        "seconds_model": tr_model.seconds,
        "points_timed_exhaustive": len(tr_ex.swept),
        "points_timed_model": len(tr_model.swept),
        "regret": max(0.0, tr_model.seconds / tr_ex.seconds - 1.0),
        "same_point": tr_model.params == tr_ex.params,
        "model_search_provenance": tr_model.search,
        "model_hit_served_exhaustive": tr_again.cached,
    }


def run(smoke: bool = False, json_path: str = ARTIFACT,
        cache_path: Optional[str] = None) -> Dict[str, Any]:
    """Walk the registry, tune, time, and emit CSV + JSON.  Returns the
    artifact dict (also written to ``json_path``)."""
    cache = TuningCache(path=cache_path)
    records: List[Dict[str, Any]] = []
    app_terms: Dict[str, List[Efficiency]] = {}

    for name in registry.names():
        kernel = registry.get(name)
        case = CASES.get(name)
        if case is None:
            records.append(_skip(name, "-", "no benchmark case defined"))
            continue
        port = _portable_backend(kernel)
        if port is None:
            records.append(_skip(name, case.app,
                                 "no portable backend available"))
            continue
        baseline = kernel.oracle
        b = kernel.backends.get(baseline)
        if b is None or not b.is_available():
            records.append(_skip(name, case.app,
                                 f"oracle {baseline!r} unavailable"))
            continue

        rec, e = _measure_backend(kernel, case, port, cache, smoke)
        app_terms.setdefault(case.app, []).append(e)
        records.append(rec)
        # the derived field must stay comma-free (CSV scaffold contract)
        params_str = (";".join(f"{k}={v}" for k, v in
                               sorted(rec["tuned_params"].items()))
                      or "defaults")
        emit(f"phi.e.{name}", rec["seconds_tuned"],
             f"e={e.e:.3f} default_us={rec['seconds_default'] * 1e6:.1f} "
             f"tuned={params_str}{' (cache)' if rec['tuning_cached'] else ''}")

    # the composite shard_pallas backends ride the same Eq.-4 machinery
    # (tuned over their tile x shard spaces, compared against the same
    # oracle) but never enter Phi-bar: Eq. 4 is the single-device metric,
    # the device-count axis lives in benchmarks/scaling.py.  On a 1-device
    # host each records an availability skip instead of a measurement.
    dist_records: List[Dict[str, Any]] = []
    for name in registry.names():
        kernel = registry.get(name)
        b = kernel.backends.get(DIST_BACKEND)
        if b is None:
            continue
        case = CASES.get(name)
        if case is None:
            dist_records.append(_skip(name, "-", "no benchmark case defined",
                                      backend=DIST_BACKEND))
            continue
        if not b.is_available():
            dist_records.append(_skip(
                name, case.app,
                f"{DIST_BACKEND} unavailable "
                f"({jax.device_count()} device(s))", backend=DIST_BACKEND))
            continue
        try:
            rec, _ = _measure_backend(kernel, case, DIST_BACKEND, cache,
                                      smoke)
        except ValueError as exc:
            # the case shape cannot satisfy the backend's default tile /
            # shard resolution on this topology — a reasoned skip, not a
            # crashed sweep
            dist_records.append(_skip(name, case.app, str(exc),
                                      backend=DIST_BACKEND))
            continue
        dist_records.append(rec)
        params_str = (";".join(f"{k}={v}" for k, v in
                               sorted(rec["tuned_params"].items()))
                      or "defaults")
        emit(f"dist.e.{name}", rec["seconds_tuned"],
             f"e={rec['e_i']:.3f} backend={DIST_BACKEND} "
             f"tuned={params_str}")

    tuning_quality = _model_search_regret(smoke)
    if tuning_quality is not None and "regret" in tuning_quality:
        emit("tuning.model_regret", tuning_quality["seconds_model"],
             f"regret={tuning_quality['regret']:.3f} "
             f"timed={tuning_quality['points_timed_model']}"
             f"/{tuning_quality['points_timed_exhaustive']} points "
             f"kernel={tuning_quality['kernel']}")

    phi_per_app = {app: phi_bar(terms) for app, terms in app_terms.items()}
    for app, phi in sorted(phi_per_app.items()):
        emit(f"phi.{app}", 0.0, f"phi={phi:.3f}")
    all_terms = [t for terms in app_terms.values() for t in terms]
    overall = phi_bar(all_terms) if all_terms else None
    if overall is not None:
        emit("phi.overall", 0.0, f"phi={overall:.3f}")

    artifact = {
        "schema": SCHEMA,
        "platform": jax.devices()[0].platform,
        "smoke": smoke,
        "kernels": records,
        "distributed_kernels": dist_records,
        "tuning_quality": tuning_quality,
        "phi": {"per_app": phi_per_app, "overall": overall},
    }
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    return artifact


if __name__ == "__main__":
    run()
