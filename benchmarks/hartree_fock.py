"""Paper Table 4 — Hartree-Fock twoel wall-clock vs system size.

The paper reports raw kernel ms for He systems (a=64..1024, ngauss=3/6);
CPU-scaled sizes here.  Derived column: wall-clock ms (the paper's FoM).
"""

from __future__ import annotations

from benchmarks.common import emit, time_call
from repro.kernels.hartree_fock import ops, ref

CASES = [(8, 3), (16, 3), (24, 3), (8, 6)]


def run() -> None:
    for natoms, ngauss in CASES:
        pos = ref.helium_lattice(natoms)
        dens = ref.initial_density(natoms)
        t = time_call(ops.fock_xla, pos, dens, ngauss=ngauss, iters=5)
        emit(f"hartree_fock.xla.a{natoms}.g{ngauss}", t, f"{t*1e3:.2f}ms")
        t = time_call(ops.fock_pallas, pos, dens, ngauss=ngauss,
                      interpret=True, iters=2, warmup=1)
        emit(f"hartree_fock.pallas_interp.a{natoms}.g{ngauss}", t,
             f"{t*1e3:.2f}ms")


if __name__ == "__main__":
    run()
