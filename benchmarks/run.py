"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Emits ``name,us_per_call,derived`` CSV (scaffold contract).  Mapping:
    stencil          -> paper Fig. 3 (Eq. 1 bandwidth)
    babelstream      -> paper Fig. 4 (Eq. 2 bandwidth)
    minibude         -> paper Figs. 6-7 (Eq. 3 GFLOP/s)
    hartree_fock     -> paper Table 4 (wall-clock)
    portability      -> paper Table 5 (Eq. 4 Phi-bar)
    roofline_kernels -> paper Fig. 2 + Tables 2-3 (AI / bound placement)
    lm_step          -> framework-level LM step timings
    serving          -> continuous-batching engine tok/s + p50/p95 latency
                        under a Poisson-ish synthetic arrival trace
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import header

MODULES = ["stencil", "babelstream", "minibude", "hartree_fock",
           "portability", "roofline_kernels", "lm_step", "serving"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=MODULES)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES

    header()
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"benchmark modules failed: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
