"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--smoke]

Emits ``name,us_per_call,derived`` CSV (scaffold contract).  Mapping:
    stencil          -> paper Fig. 3 (Eq. 1 bandwidth)
    babelstream      -> paper Fig. 4 (Eq. 2 bandwidth)
    minibude         -> paper Figs. 6-7 (Eq. 3 GFLOP/s)
    hartree_fock     -> paper Table 4 (wall-clock)
    portability      -> paper Table 5 (Eq. 4 Phi-bar, tuned via the
                        registry sweep; writes BENCH_portability.json)
    scaling          -> weak/strong device-count scaling of the sharded
                        backends, xla_shard vs shard_pallas per kernel
                        (simulated host devices; writes BENCH_scaling.json)
    roofline_kernels -> paper Fig. 2 + Tables 2-3 (AI / bound placement)
    lm_step          -> framework-level LM step timings
    serving          -> continuous-batching engine tok/s + p50/p95 latency
                        under a Poisson-ish synthetic arrival trace
    analysis         -> registry-wide static kernel auditor (jaxpr/grid/
                        collective/recompile passes; writes
                        BENCH_analysis.json, fails on non-waived findings)

``--smoke`` shrinks every module that supports it (a ``smoke=`` parameter
on its ``run()``) to seconds-scale shapes with ``iters=1`` — the PR-time
drift lane is ``python -m benchmarks.run --smoke --only portability``.

A failing module never aborts the run mid-CSV: its traceback is buffered
and printed to stderr *after* the CSV block, and the exit code is nonzero
only once every requested module has run.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import traceback

from benchmarks.common import header

MODULES = ["stencil", "babelstream", "minibude", "hartree_fock",
           "portability", "scaling", "roofline_kernels", "lm_step",
           "serving", "analysis"]


def _run_module(name: str, smoke: bool) -> None:
    mod = __import__(f"benchmarks.{name}", fromlist=["run"])
    params = inspect.signature(mod.run).parameters
    if smoke and "smoke" in params:
        mod.run(smoke=True)
    else:
        mod.run()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="MODULE",
                    help=f"run a single module (one of {MODULES})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, iters=1 — PR-time drift check")
    args = ap.parse_args(argv)
    if args.only is not None and args.only not in MODULES:
        print(f"unknown benchmark module {args.only!r}; "
              f"known modules: {MODULES}", file=sys.stderr)
        raise SystemExit(2)
    mods = [args.only] if args.only else MODULES

    header()
    failures = []
    for name in mods:
        try:
            _run_module(name, args.smoke)
        except Exception:
            failures.append((name, traceback.format_exc()))
    if failures:
        for name, tb in failures:
            print(f"\n--- benchmark module {name!r} failed ---\n{tb}",
                  file=sys.stderr)
        print(f"benchmark modules failed: {[n for n, _ in failures]}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
