"""Render EXPERIMENTS.md §Dry-run and §Roofline from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report [--artifacts DIR]

Reads benchmarks/artifacts/dryrun*/<mesh>/<arch>__<shape>.json and emits
markdown tables to stdout (the EXPERIMENTS.md assembly script pipes these).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

HERE = os.path.dirname(__file__)
DEFAULT = os.path.join(HERE, "artifacts/dryrun")

ADVICE = {
    "compute": "raise MXU occupancy: larger per-chip tiles / fewer, bigger "
               "matmuls (already near the compute roof — good).",
    "memory": "cut HBM round-trips: bf16 attention intermediates, fused "
              "(flash) attention kernel, larger q-chunks, fewer f32 "
              "norm/softmax materializations.",
    "collective": "cut wire bytes: bf16 collectives, ZeRO-1 once-per-step "
                  "weight gather, smaller MoE dispatch groups / capacity, "
                  "overlap via microbatch pipelining.",
}


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load(art_dir):
    cells = defaultdict(dict)
    for path in glob.glob(os.path.join(art_dir, "*", "*.json")):
        r = json.load(open(path))
        cells[r["mesh"]][(r["arch"], r["shape"])] = r
    return cells


def dryrun_table(recs, mesh):
    out = [f"\n### Mesh `{mesh}`\n",
           "| arch | shape | status | peak GiB/chip | fits 16G | compile s |"
           " collectives (count) |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | SKIP | — | — | — |"
                       f" {r['reason'][:58]} |")
            continue
        colls = ", ".join(f"{k.split('-')[-1] if False else k}:"
                          f"{v['count']}"
                          for k, v in sorted(r["collectives"].items()))
        out.append(
            f"| {arch} | {shape} | OK | {fmt_bytes(r['per_chip']['peak_bytes'])}"
            f" | {'yes' if r['fits_hbm'] else '**NO**'}"
            f" | {r['compile_s']:.0f} | {colls} |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["",
           "| arch | shape | compute s | memory s | collective s |"
           " dominant | roofline frac | MODEL_FLOPS | useful ratio |"
           " next lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] != "ok":
            continue
        rs = r["roofline_s"]
        frac = rs["compute"] / max(r["bound_s"], 1e-12)
        out.append(
            f"| {arch} | {shape} | {rs['compute']:.3f} | {rs['memory']:.3f}"
            f" | {rs['collective']:.3f} | {r['dominant']}"
            f" | {frac:.1%} | {r['model_flops_total']:.2e}"
            f" | {r['useful_flops_ratio']:.3f}"
            f" | {ADVICE[r['dominant']][:72]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=DEFAULT)
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    cells = load(args.artifacts)

    if args.section in ("dryrun", "both"):
        print("## §Dry-run — lower+compile of every (arch × shape × mesh)")
        for mesh in sorted(cells):
            print(dryrun_table(cells[mesh], mesh))
    if args.section in ("roofline", "both"):
        print("\n## §Roofline — single-pod (16×16) per-chip terms")
        pod = cells.get("pod_16x16", {})
        print(roofline_table(pod))


if __name__ == "__main__":
    main()
