"""Per-instruction byte/flop breakdown of a dry-run cell — the 'profiler'.

    PYTHONPATH=src python -m benchmarks.hlo_breakdown \
        --arch granite-3-8b --shape train_4k [--variant attn_bf16] [--top 20]

Walks the compiled HLO with loop multiplicity (core/hlo_cost.py) and prints
the top HBM-traffic and collective contributors, annotated with the source
op_name metadata — this is what the hypothesis->measure loop reads.
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

import jax

from repro.configs import SHAPES, get_config
from repro.core import hlo_cost as H
from repro.distributed.sharding import ShardingPolicy
from repro.launch.dryrun import VARIANTS, build_cell
from repro.launch.mesh import make_production_mesh

import dataclasses

_OPNAME = re.compile(r'op_name="([^"]+)"')


def breakdown(arch, shape_name, variant="baseline", multi_pod=False):
    cfg = get_config(arch)
    if VARIANTS.get(variant):
        cfg = dataclasses.replace(cfg, **VARIANTS[variant])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = ShardingPolicy(mesh, cfg)
    fn, args, in_sh, out_sh, donate, _ = build_cell(cfg, shape, mesh, policy)
    kwargs = {"in_shardings": in_sh}
    if out_sh is not None:
        kwargs["out_shardings"] = out_sh
    if donate:
        kwargs["donate_argnums"] = donate
    with mesh:
        compiled = jax.jit(fn, **kwargs).lower(*args).compile()
    mod = H._Module(compiled.as_text())

    rows = []

    def walk(comp, mult, in_fusion):
        symbols = mod._symbols(comp)
        for ins in mod.computations.get(comp, []):
            c = mod.instr_cost(ins, comp, in_fusion, symbols)
            if c.hbm_bytes or c.collective_bytes:
                m = _OPNAME.search(ins.line)
                tag = m.group(1) if m else ins.name
                rows.append((c.hbm_bytes * mult, c.collective_bytes * mult,
                             ins.opcode, ins.shape[:48], tag[-90:]))
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                tc = H._TRIP_COUNT.search(ins.line)
                trip = int(tc.group(1)) if tc else 1
                if bm:
                    walk(bm.group(1), mult * trip, False)
            elif ins.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    walk(fm.group(1), mult, True)

    walk(mod.entry, 1.0, False)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--by", default="hbm", choices=["hbm", "collective"])
    args = ap.parse_args()

    rows = breakdown(args.arch, args.shape, args.variant)
    key = 0 if args.by == "hbm" else 1
    rows.sort(key=lambda r: -r[key])
    tot_h = sum(r[0] for r in rows)
    tot_c = sum(r[1] for r in rows)
    print(f"total per-chip: hbm {tot_h/2**30:.1f} GiB  "
          f"collective {tot_c/2**30:.1f} GiB")
    print(f"{'hbm GiB':>9} {'coll GiB':>9}  opcode           shape/op")
    for h, c, op, shp, tag in rows[:args.top]:
        print(f"{h/2**30:9.2f} {c/2**30:9.2f}  {op:16s} {shp:48s} {tag}")


if __name__ == "__main__":
    main()
