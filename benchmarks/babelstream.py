"""Paper Fig. 4 / Eq. 2 — BabelStream Copy/Mul/Add/Triad/Dot bandwidth."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  (registers all kernel backends)
from benchmarks.common import emit, time_call
from repro.core.metrics import babelstream_bytes
from repro.core.portable import registry

SIZE = 1 << 22          # CPU-scaled (paper: 2^25 on GPU)


def run() -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal(SIZE), jnp.float32)
    b = jnp.asarray(rng.standard_normal(SIZE), jnp.float32)
    args = {"copy": (a,), "mul": (a,), "add": (a, b), "triad": (a, b),
            "dot": (a, b)}
    for op in ("copy", "mul", "add", "triad", "dot"):
        k = registry.get(f"babelstream.{op}")
        nbytes = babelstream_bytes(op, SIZE, 4)
        t = k.time_backend(*args[op], backend="xla")
        emit(f"babelstream.{op}.xla", t, f"{nbytes / t / 1e9:.2f}GB/s")
        t = k.time_backend(*args[op], backend="pallas_interpret", iters=3,
                           warmup=1)
        emit(f"babelstream.{op}.pallas_interp", t,
             f"{nbytes / t / 1e9:.2f}GB/s")


if __name__ == "__main__":
    run()
