"""Framework-level benchmark: train/decode step timings, reduced configs.

Not a paper table — this exercises the LM substrate end to end on CPU
(dense + MoE + SSM + hybrid) so regressions in the framework itself are
visible in CI.  Derived: tokens/s on this host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.models import transformer as T
from repro.training.serve_step import decode_step
from repro.training.train_step import TrainConfig, make_train_state, train_step

ARCHS = ["granite-3-8b", "deepseek-moe-16b", "rwkv6-3b", "hymba-1.5b"]
B, S = 4, 64


def run() -> None:
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg, key)
        tcfg = TrainConfig(microbatches=2, remat=True)
        state = make_train_state(params, tcfg)
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), jnp.float32),
        }
        step = jax.jit(lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))
        t = time_call(step, state, batch, iters=5)
        emit(f"lm.train.{arch}", t, f"{B*S/t:.0f}tok/s")

        caches = T.init_caches(cfg, B, 64)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        pos = jnp.zeros((B, 1), jnp.int32)
        dec = jax.jit(lambda p, t_, po, c: decode_step(p, cfg, t_, po, c))
        t = time_call(dec, params, tok, pos, caches, iters=5)
        emit(f"lm.decode.{arch}", t, f"{B/t:.0f}tok/s")


if __name__ == "__main__":
    run()
