"""Framework-level benchmark: train/decode step timings, reduced configs.

Not a paper table — this exercises the LM substrate end to end on CPU
(dense + MoE + SSM + hybrid) so regressions in the framework itself are
visible in CI.  Derived: tokens/s on this host.

Decode rows carry one extra dimension since PR 6: the attention backend.
Each timed row states which registry backend the compiled program actually
dispatched to and the tuning provenance of its block sizes
(``exhaustive``/``coordinate`` from the tuning cache, ``miss-default`` for
declared defaults) — read at trace time from
``models/attention.dispatch_log()`` instead of silently timing whatever
dispatch picked.  Attention-free archs (rwkv) time the single ``xla`` row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.core.portable import on_tpu
from repro.models import attention as A
from repro.models import transformer as T
from repro.training.serve_step import decode_step
from repro.training.train_step import TrainConfig, make_train_state, train_step

ARCHS = ["granite-3-8b", "deepseek-moe-16b", "rwkv6-3b", "hymba-1.5b"]
B, S = 4, 64


def _decode_provenance() -> str:
    d = A.dispatch_log().get("decode", {})
    bk = d.get("backend", "xla")
    if d.get("fallback"):
        return f"attn={bk}(fallback)"
    tuning = d.get("tuning", "n/a")
    return f"attn={bk}" + (f" tuning={tuning}" if bk != "xla" else "")


def run() -> None:
    key = jax.random.PRNGKey(0)
    attn_backends = [None, "pallas" if on_tpu() else "pallas_interpret"]
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg, key)
        tcfg = TrainConfig(microbatches=2, remat=True)
        state = make_train_state(params, tcfg)
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), jnp.float32),
        }
        step = jax.jit(lambda st, b: train_step(st, b, cfg=cfg, tcfg=tcfg))
        t = time_call(step, state, batch, iters=5)
        emit(f"lm.train.{arch}", t, f"{B*S/t:.0f}tok/s")

        backends = [None] if cfg.attention_free else attn_backends
        for bk in backends:
            label = bk or "xla"
            caches = T.init_caches(cfg, B, 64)
            tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
            pos = jnp.zeros((B, 1), jnp.int32)
            A.reset_dispatch_log()
            dec = jax.jit(lambda p, t_, po, c, _bk=bk: decode_step(
                p, cfg, t_, po, c, attn_backend=_bk))
            t = time_call(dec, params, tok, pos, caches, iters=5)
            emit(f"lm.decode.{arch}[{label}]", t,
                 f"{B/t:.0f}tok/s {_decode_provenance()}")


if __name__ == "__main__":
    run()
