"""Static-auditor lane for the benchmark orchestrator (BENCH_analysis.json).

Not a timing benchmark: this module runs ``python -m repro.core.analysis``
— the registry-wide static kernel auditor — as a child process (the CLI
re-execs itself under forced host devices for the sharded cells, exactly
like ``benchmarks/scaling.py``) and republishes its ``repro.analysis/v2``
report as the orchestrator artifact.  The CSV row carries the audit
wall-clock and the finding/waiver/skip counts as the derived column, so a
drift in either shows up in the same place every other lane drifts.  Since
v2 the auditor's findings include the performance passes — traffic
inflation over a declared limit, a roofline-bound flip against a declared
contract, and measured-vs-predicted drift beyond the band — so this lane
gates on those exactly like the correctness passes.

    PYTHONPATH=src python -m benchmarks.run [--smoke] --only analysis

A non-waived finding fails the module (nonzero orchestrator exit), the
same contract as a conformance failure: the registry must stay audit-clean.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import emit, header

ARTIFACT = "BENCH_analysis.json"


def run(smoke: bool = False, json_path: str = ARTIFACT) -> dict:
    cmd = [sys.executable, "-m", "repro.core.analysis",
           "--json", os.path.abspath(json_path)]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    t0 = time.perf_counter()
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    dt = time.perf_counter() - t0
    sys.stderr.write(proc.stderr)

    if not os.path.exists(json_path):
        raise RuntimeError(
            f"auditor wrote no report (exit {proc.returncode}):\n"
            f"{proc.stdout}")
    with open(json_path) as f:
        report = json.load(f)
    s = report["summary"]
    emit("analysis.audit", dt,
         f"cells={s['cells']} findings={s['findings']} "
         f"waived={s['waived']} skips={s['skips']} "
         f"costed={len(report.get('cost', {}))} "
         f"drift_joined={s.get('drift_joined', 0)}")
    if proc.returncode or s["findings"]:
        raise RuntimeError(
            f"static audit found {s['findings']} non-waived finding(s) "
            f"(exit {proc.returncode}):\n{proc.stdout}")
    return report


if __name__ == "__main__":
    header()
    run(smoke="--smoke" in sys.argv[1:])
