"""Weak/strong device-count scaling of the science kernels (BENCH_scaling.json).

The paper's Eq.-4 methodology compares compiler backends on one device; this
module extends the axis to *device count* via the ``xla_shard`` backends the
domain-decomposition subsystem registers (``repro.distributed.domain``):

  * **strong scaling** — fixed global problem, growing shard count:
      efficiency(S) = t_1 / (S * t_S)
    against the single-device ``xla`` oracle at the same global size;
  * **weak scaling** — fixed *per-shard* problem, global size grows with S:
      efficiency(S) = t_1(base) / t_S(S * base).

Hartree-Fock has no linear weak-scaling axis (work is O(N^4) in the atom
count) and records a skip reason instead of a fake curve.

Run on CPU via simulated devices, exactly how ``launch/dryrun.py`` fakes its
512-chip topology: when the current process already pinned jax to a single
device, the module re-execs itself in a subprocess with
``--xla_force_host_platform_device_count`` appended to XLA_FLAGS
(``repro.launch.hostsim`` — a user-set value is respected, never clobbered).
CPU caveat: "devices" are threads of one host, so efficiencies here validate
the *machinery* and the shapes of the curves, not hardware scaling.

    PYTHONPATH=src python -m benchmarks.run [--smoke] --only scaling
    PYTHONPATH=src python -m benchmarks.scaling [--smoke] [--devices 8]

Artifact schema (``repro.scaling/v1``)::

    {"schema": "repro.scaling/v1", "platform": str, "smoke": bool,
     "num_devices": int,
     "kernels": [
       {"kernel": str, "backend": "xla_shard", "baseline_backend": "xla",
        "skipped": str | null,
        "strong": {"shape": str, "baseline_seconds": float,
                   "points": [{"num_shards": int, "seconds": float,
                               "speedup": float, "efficiency": float}]},
        "weak": {"base_shape": str, "baseline_seconds": float,
                 "points": [{"num_shards": int, "shape": str,
                             "seconds": float, "efficiency": float}]}
                | {"skipped": str}}]}
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Optional

from benchmarks.common import emit

ARTIFACT = "BENCH_scaling.json"
SCHEMA = "repro.scaling/v1"
DEFAULT_DEVICES = 8


# --------------------------------------------------------------------------
# problem-size catalogue (global extents divisible by every swept shard count)
# --------------------------------------------------------------------------
def _stencil_args(nz, smoke):
    import jax.numpy as jnp
    import numpy as np
    ny, nx = (16, 32) if smoke else (64, 128)
    u = np.random.default_rng(0).standard_normal((nz, ny, nx))
    return (jnp.asarray(u, jnp.float32),)


def _stream_args(n, smoke, nargs):
    import jax.numpy as jnp
    import numpy as np
    r = np.random.default_rng(0)
    return tuple(jnp.asarray(r.standard_normal(n), jnp.float32)
                 for _ in range(nargs))


def _minibude_args(nposes, smoke):
    from repro.kernels.minibude import ops as mb_ops
    natpro, natlig = (16, 4) if smoke else (64, 8)
    return mb_ops.make_deck(natpro=natpro, natlig=natlig, nposes=nposes,
                            seed=0)


def _hf_args(natoms, smoke):
    from repro.kernels.hartree_fock import ref as hf_ref
    return (hf_ref.helium_lattice(natoms), hf_ref.initial_density(natoms))


#: kernel -> (strong extent, weak per-shard extent, args factory); extents
#: are the decomposed axis (stencil z planes, stream elements, poses, atoms)
def _catalogue(smoke: bool) -> Dict[str, Dict[str, Any]]:
    return {
        "stencil7": {
            "strong": 16 if smoke else 64,
            "weak": 2 if smoke else 8,
            "make": lambda n: _stencil_args(n, smoke),
        },
        "babelstream.triad": {
            "strong": 1 << 14 if smoke else 1 << 20,
            "weak": 1 << 12 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "babelstream.dot": {
            "strong": 1 << 14 if smoke else 1 << 20,
            "weak": 1 << 12 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "minibude.fasten": {
            "strong": 128 if smoke else 1024,
            "weak": 64 if smoke else 256,
            "make": lambda n: _minibude_args(n, smoke),
        },
        "hartree_fock.twoel": {
            "strong": 8 if smoke else 16,
            "weak": None,  # O(N^4) work: no linear weak-scaling axis
            "weak_skip": "work is O(N^4) in atoms; no linear weak axis",
            "make": lambda n: _hf_args(n, smoke),
        },
    }


def _shape_sig(args) -> str:
    from repro.core.tuning import shape_signature
    return shape_signature(*args)


def _time(kernel, args, backend, iters, warmup, **kw) -> float:
    return kernel.time_backend(*args, backend=backend, iters=iters,
                               warmup=warmup, **kw)


def _measure(smoke: bool, json_path: str) -> Dict[str, Any]:
    import jax

    import repro.kernels  # noqa: F401  (registers xla_shard backends)
    from repro.core.portable import registry
    from repro.distributed.domain import SHARD_BACKEND

    dc = jax.device_count()
    shard_counts = [s for s in ((2, 4) if smoke else (2, 4, 8)) if s <= dc]
    iters, warmup = (1, 1) if smoke else (3, 1)
    records: List[Dict[str, Any]] = []

    for name, spec in _catalogue(smoke).items():
        kernel = registry.get(name)
        b = kernel.backends.get(SHARD_BACKEND)
        rec: Dict[str, Any] = {"kernel": name, "backend": SHARD_BACKEND,
                               "baseline_backend": kernel.oracle,
                               "skipped": None}
        if b is None or not b.is_available():
            rec["skipped"] = (f"{SHARD_BACKEND} unavailable "
                              f"({dc} device(s))")
            records.append(rec)
            continue

        # strong: fixed global problem, shards grow
        args = spec["make"](spec["strong"])
        t1 = _time(kernel, args, kernel.oracle, iters, warmup)
        points = []
        for s in shard_counts:
            ts = _time(kernel, args, SHARD_BACKEND, iters, warmup,
                       num_shards=s)
            eff = t1 / (s * ts)
            points.append({"num_shards": s, "seconds": ts,
                           "speedup": t1 / ts, "efficiency": eff})
            emit(f"scaling.{name}.strong.s{s}", ts,
                 f"eff={eff:.3f} speedup={t1 / ts:.2f}x")
        rec["strong"] = {"shape": _shape_sig(args), "baseline_seconds": t1,
                         "points": points}

        # weak: fixed per-shard problem, global grows with shards
        if spec["weak"] is None:
            rec["weak"] = {"skipped": spec["weak_skip"]}
        else:
            base_args = spec["make"](spec["weak"])
            t1w = _time(kernel, base_args, kernel.oracle, iters, warmup)
            points = []
            for s in shard_counts:
                args_s = spec["make"](spec["weak"] * s)
                ts = _time(kernel, args_s, SHARD_BACKEND, iters, warmup,
                           num_shards=s)
                eff = t1w / ts
                points.append({"num_shards": s, "shape": _shape_sig(args_s),
                               "seconds": ts, "efficiency": eff})
                emit(f"scaling.{name}.weak.s{s}", ts, f"eff={eff:.3f}")
            rec["weak"] = {"base_shape": _shape_sig(base_args),
                           "baseline_seconds": t1w, "points": points}
        records.append(rec)

    artifact = {
        "schema": SCHEMA,
        "platform": jax.devices()[0].platform,
        "smoke": smoke,
        "num_devices": dc,
        "kernels": records,
    }
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    return artifact


# --------------------------------------------------------------------------
# entry points: re-exec under simulated devices when pinned to one
# --------------------------------------------------------------------------
def run(smoke: bool = False, json_path: str = ARTIFACT,
        devices: int = DEFAULT_DEVICES) -> Dict[str, Any]:
    """Measure in-process when >= 2 devices are visible; otherwise re-exec
    this module in a subprocess with the host-device-count flag appended
    (jax reads XLA_FLAGS once, at backend init — too late for *this*
    process).  Returns the artifact dict (also written to ``json_path``)."""
    import jax
    if jax.device_count() >= 2:
        return _measure(smoke=smoke, json_path=json_path)
    if os.environ.get("REPRO_SCALING_CHILD"):
        # we *are* the re-exec and still see one device: the user's own
        # XLA_FLAGS pins the topology — fail loudly instead of forking again
        raise RuntimeError(
            "scaling needs >= 2 devices but XLA_FLAGS pins a 1-device "
            "topology; unset --xla_force_host_platform_device_count or "
            "raise it")

    from repro.launch.hostsim import merged_xla_flags
    env = dict(os.environ)
    env["XLA_FLAGS"] = merged_xla_flags(devices, env)
    env["REPRO_SCALING_CHILD"] = "1"
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    # the child runs from the repo root (so `-m benchmarks.scaling`
    # resolves); absolutize the artifact path against OUR cwd first or the
    # parent would read a missing/stale file after the child succeeded
    json_path = os.path.abspath(json_path)
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--json", json_path,
           "--devices", str(devices)]
    if smoke:
        cmd.append("--smoke")
    # child CSV rows stream through to our stdout (same scaffold contract)
    proc = subprocess.run(
        cmd, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode:
        raise RuntimeError(
            f"scaling subprocess failed with exit code {proc.returncode}")
    with open(json_path) as f:
        return json.load(f)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=ARTIFACT)
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, json_path=args.json, devices=args.devices)


if __name__ == "__main__":
    main()
