"""Weak/strong device-count scaling of the science kernels (BENCH_scaling.json).

The paper's Eq.-4 methodology compares compiler backends on one device; this
module extends the axis to *device count* via the ``xla_shard`` backends the
domain-decomposition subsystem registers (``repro.distributed.domain``):

  * **strong scaling** — fixed global problem, growing shard count:
      efficiency(S) = t_1 / (S * t_S)
    against the single-device ``xla`` oracle at the same global size;
  * **weak scaling** — fixed *per-shard* problem, global size grows with S:
      efficiency(S) = t_1(base) / t_S(S * base).

stencil7 is measured once per *decomposition variant* — 1-D z slabs and 2-D
``(sz, sy)`` pencils, each with and without halo/compute overlap — because
the decomposition shape governs the surface-to-volume halo traffic that
bounds a memory-bound stencil's efficiency.  Every timed point consults the
PR-2 tuning cache first (Eq.-4 times *best* configurations, not defaults):
cached parameters are merged under the point's forced shard settings and
re-timed fresh — cached seconds never enter a ratio — and the artifact
records the tuning provenance per point.

Hartree-Fock has no linear weak-scaling axis (work is O(N^4) in the atom
count) and records a skip reason instead of a fake curve.

Run on CPU via simulated devices, exactly how ``launch/dryrun.py`` fakes its
512-chip topology: when the current process already pinned jax to a single
device, the module re-execs itself in a subprocess with
``--xla_force_host_platform_device_count`` appended to XLA_FLAGS
(``repro.launch.hostsim`` — a user-set value is respected, never clobbered).
The child's CSV rows are replayed into ``benchmarks.common.ROWS`` in the
parent, so orchestrated runs (``benchmarks.run``) see them like any other
module's.  CPU caveat: "devices" are threads of one host, so efficiencies
here validate the *machinery* and the shapes of the curves, not hardware
scaling.

    PYTHONPATH=src python -m benchmarks.run [--smoke] --only scaling
    PYTHONPATH=src python -m benchmarks.scaling [--smoke] [--devices 8]

Artifact schema (``repro.scaling/v2``; v1 had a single implicit slab curve
per kernel and no tuning provenance)::

    {"schema": "repro.scaling/v2", "platform": str, "smoke": bool,
     "num_devices": int,
     "kernels": [
       {"kernel": str, "backend": "xla_shard", "baseline_backend": "xla",
        "skipped": str | null,
        "curves": [
          {"decomp": "slab" | "pencil", "overlap": bool,
           "strong": {"shape": str, "baseline_seconds": float,
                      "baseline_tuning": TUNING,
                      "points": [{"num_shards": int,
                                  "shard_grid": [sz, sy] | null,
                                  "seconds": float, "speedup": float,
                                  "efficiency": float, "tuning": TUNING}]},
           "weak": {"base_shape": str, "baseline_seconds": float,
                    "baseline_tuning": TUNING,
                    "points": [{"num_shards": int,
                                "shard_grid": [sz, sy] | null, "shape": str,
                                "seconds": float, "efficiency": float,
                                "tuning": TUNING}]}
                   | {"skipped": str}}]}]}

    TUNING = {"cached": bool, "params": {...}, "search": str | null}
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Tuple

from benchmarks.common import emit, header

ARTIFACT = "BENCH_scaling.json"
SCHEMA = "repro.scaling/v2"
DEFAULT_DEVICES = 8
CSV_HEADER = "name,us_per_call,derived"


# --------------------------------------------------------------------------
# problem-size catalogue (global extents divisible by every swept shard count)
# --------------------------------------------------------------------------
def _stencil_args(nz, smoke, ny_mult=1):
    import jax.numpy as jnp
    import numpy as np
    ny, nx = (16, 32) if smoke else (64, 128)
    u = np.random.default_rng(0).standard_normal((nz, ny * ny_mult, nx))
    return (jnp.asarray(u, jnp.float32),)


def _stream_args(n, smoke, nargs):
    import jax.numpy as jnp
    import numpy as np
    r = np.random.default_rng(0)
    return tuple(jnp.asarray(r.standard_normal(n), jnp.float32)
                 for _ in range(nargs))


def _minibude_args(nposes, smoke):
    from repro.kernels.minibude import ops as mb_ops
    natpro, natlig = (16, 4) if smoke else (64, 8)
    return mb_ops.make_deck(natpro=natpro, natlig=natlig, nposes=nposes,
                            seed=0)


def _hf_args(natoms, smoke):
    from repro.kernels.hartree_fock import ref as hf_ref
    return (hf_ref.helium_lattice(natoms), hf_ref.initial_density(natoms))


#: kernel -> (strong extent, weak per-shard extent, args factory); extents
#: are the decomposed axis (stencil z planes, stream elements, poses, atoms).
#: stencil7 additionally declares its decomposition variants and a 2-D weak
#: factory (weak pencils grow z by sz and y by sy, keeping the per-shard
#: block fixed).
def _catalogue(smoke: bool) -> Dict[str, Dict[str, Any]]:
    return {
        "stencil7": {
            "strong": 16 if smoke else 64,
            "weak": 2 if smoke else 8,
            "make": lambda n: _stencil_args(n, smoke),
            "make_grid": lambda n, sy: _stencil_args(n, smoke, ny_mult=sy),
            "curves": [("slab", False), ("slab", True),
                       ("pencil", False), ("pencil", True)],
        },
        "babelstream.triad": {
            "strong": 1 << 14 if smoke else 1 << 20,
            "weak": 1 << 12 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "babelstream.dot": {
            "strong": 1 << 14 if smoke else 1 << 20,
            "weak": 1 << 12 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "minibude.fasten": {
            "strong": 128 if smoke else 1024,
            "weak": 64 if smoke else 256,
            "make": lambda n: _minibude_args(n, smoke),
        },
        "hartree_fock.twoel": {
            "strong": 8 if smoke else 16,
            "weak": None,  # O(N^4) work: no linear weak-scaling axis
            "weak_skip": "work is O(N^4) in atoms; no linear weak axis",
            "make": lambda n: _hf_args(n, smoke),
        },
    }


def _shape_sig(args) -> str:
    from repro.core.tuning import shape_signature
    return shape_signature(*args)


# --------------------------------------------------------------------------
# timing: every point consults the tuning cache, re-times fresh, and records
# provenance (the Eq.-4 "best configuration" rule from benchmarks/portability)
# --------------------------------------------------------------------------
def _timed_point(kernel, args, backend, cache, iters, warmup,
                 forced: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
    """Median seconds at the cache's best params (merged *under* the forced
    shard settings — the sweep axis always wins), plus the provenance
    record.  Cached seconds are historical (another session, another load):
    only the *parameters* are reused; the timing is always fresh."""
    from repro.core import tuning

    hit = cache.get(tuning.make_key(kernel, *args, backend=backend))
    cached = tuning.params_from_cache(hit["params"]) if hit else {}
    params = {**cached, **forced}
    secs = kernel.time_backend(*args, backend=backend, iters=iters,
                               warmup=warmup, **params)
    provenance = {"cached": hit is not None,
                  "params": dict(params),
                  "search": hit.get("search", "exhaustive") if hit else None}
    return secs, provenance


def _curve_label(decomp: str, overlap: bool) -> str:
    return decomp + ("+ov" if overlap else "")


def _measure(smoke: bool, json_path: str) -> Dict[str, Any]:
    import jax

    import repro.kernels  # noqa: F401  (registers xla_shard backends)
    from repro.core.portable import registry
    from repro.core.tuning import TuningCache
    from repro.distributed.domain import (SHARD_BACKEND,
                                          balanced_pencil_grid)

    dc = jax.device_count()
    cache = TuningCache()
    shard_counts = [s for s in ((2, 4) if smoke else (2, 4, 8)) if s <= dc]
    iters, warmup = (1, 1) if smoke else (3, 1)
    records: List[Dict[str, Any]] = []

    for name, spec in _catalogue(smoke).items():
        kernel = registry.get(name)
        b = kernel.backends.get(SHARD_BACKEND)
        rec: Dict[str, Any] = {"kernel": name, "backend": SHARD_BACKEND,
                               "baseline_backend": kernel.oracle,
                               "skipped": None}
        if b is None or not b.is_available():
            rec["skipped"] = (f"{SHARD_BACKEND} unavailable "
                              f"({dc} device(s))")
            records.append(rec)
            continue

        curves = spec.get("curves") or [("slab", False)]
        strong_args = spec["make"](spec["strong"])
        t1, t1_prov = _timed_point(kernel, strong_args, kernel.oracle, cache,
                                   iters, warmup, {})
        weak_base = None
        if spec["weak"] is not None:
            weak_base = spec["make"](spec["weak"])
            t1w, t1w_prov = _timed_point(kernel, weak_base, kernel.oracle,
                                         cache, iters, warmup, {})

        rec["curves"] = []
        for decomp, overlap in curves:
            label = _curve_label(decomp, overlap)

            def _point_plan(s, args):
                """(shard_grid, forced kwargs) for S total shards, or None
                when this decomposition cannot use S shards here.  ``args``
                is the *fixed* global problem (strong lane); weak lanes
                pass ``None`` and get the shape-agnostic grid — their
                global extents are built *from* the grid, so they divide
                by construction."""
                if "curves" not in spec:       # 1-D kernels: num_shards
                    return None, {"num_shards": s}
                if decomp == "slab":
                    grid = (s, 1)
                    if args is not None and args[0].shape[0] % s:
                        grid = None
                elif args is not None:
                    grid = balanced_pencil_grid(s, args[0].shape[0],
                                                args[0].shape[1])
                else:
                    grid = balanced_pencil_grid(s)
                if grid is None:
                    return None, None
                return grid, {"decomp": decomp, "shard_grid": grid,
                              "overlap": overlap}

            # strong: fixed global problem, shards grow
            points = []
            for s in shard_counts:
                grid, forced = _point_plan(s, strong_args)
                if forced is None:
                    continue
                ts, prov = _timed_point(kernel, strong_args, SHARD_BACKEND,
                                        cache, iters, warmup, forced)
                eff = t1 / (s * ts)
                points.append({"num_shards": s,
                               "shard_grid": list(grid) if grid else None,
                               "seconds": ts, "speedup": t1 / ts,
                               "efficiency": eff, "tuning": prov})
                emit(f"scaling.{name}.{label}.strong.s{s}", ts,
                     f"eff={eff:.3f} speedup={t1 / ts:.2f}x")
            curve: Dict[str, Any] = {
                "decomp": decomp, "overlap": overlap,
                "strong": {"shape": _shape_sig(strong_args),
                           "baseline_seconds": t1,
                           "baseline_tuning": t1_prov, "points": points}}

            # weak: fixed per-shard problem, global grows with shards
            if spec["weak"] is None:
                curve["weak"] = {"skipped": spec["weak_skip"]}
            else:
                points = []
                for s in shard_counts:
                    grid, forced = _point_plan(s, None)
                    if forced is None:
                        continue
                    if grid is not None and grid[1] > 1:
                        args_s = spec["make_grid"](spec["weak"] * grid[0],
                                                   grid[1])
                    else:
                        args_s = spec["make"](spec["weak"] * s)
                    ts, prov = _timed_point(kernel, args_s, SHARD_BACKEND,
                                            cache, iters, warmup, forced)
                    eff = t1w / ts
                    points.append({"num_shards": s,
                                   "shard_grid": list(grid) if grid else None,
                                   "shape": _shape_sig(args_s),
                                   "seconds": ts, "efficiency": eff,
                                   "tuning": prov})
                    emit(f"scaling.{name}.{label}.weak.s{s}", ts,
                         f"eff={eff:.3f}")
                curve["weak"] = {"base_shape": _shape_sig(weak_base),
                                 "baseline_seconds": t1w,
                                 "baseline_tuning": t1w_prov,
                                 "points": points}
            rec["curves"].append(curve)
        records.append(rec)

    artifact = {
        "schema": SCHEMA,
        "platform": jax.devices()[0].platform,
        "smoke": smoke,
        "num_devices": dc,
        "kernels": records,
    }
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    return artifact


# --------------------------------------------------------------------------
# entry points: re-exec under simulated devices when pinned to one
# --------------------------------------------------------------------------
def _replay_child_line(line: str) -> None:
    """Feed one line of child stdout back through ``emit`` so the parent's
    ``benchmarks.common.ROWS`` sees the child's CSV rows (the scaffold
    aggregates ROWS, not raw stdout).  Header lines are dropped (the parent
    context already printed one); anything non-CSV passes through."""
    if not line or line == CSV_HEADER:
        return
    parts = line.split(",", 2)
    if len(parts) == 3:
        try:
            us = float(parts[1])
        except ValueError:
            pass
        else:
            emit(parts[0], us / 1e6, parts[2])
            return
    print(line, flush=True)


def run(smoke: bool = False, json_path: str = ARTIFACT,
        devices: int = DEFAULT_DEVICES) -> Dict[str, Any]:
    """Measure in-process when >= 2 devices are visible; otherwise re-exec
    this module in a subprocess with the host-device-count flag appended
    (jax reads XLA_FLAGS once, at backend init — too late for *this*
    process).  Returns the artifact dict (also written to ``json_path``)."""
    import jax
    if jax.device_count() >= 2:
        return _measure(smoke=smoke, json_path=json_path)
    if os.environ.get("REPRO_SCALING_CHILD"):
        # we *are* the re-exec and still see one device: the user's own
        # XLA_FLAGS pins the topology — fail loudly instead of forking again
        raise RuntimeError(
            "scaling needs >= 2 devices but XLA_FLAGS pins a 1-device "
            "topology; unset --xla_force_host_platform_device_count or "
            "raise it")

    from repro.launch.hostsim import merged_xla_flags
    env = dict(os.environ)
    env["XLA_FLAGS"] = merged_xla_flags(devices, env)
    env["REPRO_SCALING_CHILD"] = "1"
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    # the child runs from the repo root (so `-m benchmarks.scaling`
    # resolves); absolutize the artifact path against OUR cwd first or the
    # parent would read a missing/stale file after the child succeeded
    json_path = os.path.abspath(json_path)
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--json", json_path,
           "--devices", str(devices)]
    if smoke:
        cmd.append("--smoke")
    # child CSV rows are replayed line-by-line into OUR emit/ROWS (not just
    # streamed to stdout); stderr passes through untouched
    proc = subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.stdout is not None
    for line in proc.stdout:
        _replay_child_line(line.rstrip("\n"))
    if proc.wait():
        raise RuntimeError(
            f"scaling subprocess failed with exit code {proc.returncode}")
    with open(json_path) as f:
        return json.load(f)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=ARTIFACT)
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES)
    args = ap.parse_args(argv)
    # standalone runs get the scaffold's CSV header line (benchmarks.run
    # prints its own before dispatching, so run() itself must not)
    header()
    run(smoke=args.smoke, json_path=args.json, devices=args.devices)


if __name__ == "__main__":
    main()
