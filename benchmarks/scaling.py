"""Weak/strong device-count scaling of the science kernels (BENCH_scaling.json).

The paper's Eq.-4 methodology compares compiler backends on one device; this
module extends the axis to *device count* via the sharded backends the
distributed subsystem registers — the oracle-arithmetic ``xla_shard``
decompositions (``repro.distributed.domain``) AND the composite
``shard_pallas`` backends (``repro.distributed.shard_pallas``: the unchanged
Pallas kernels inside ``shard_map``, interpret mode off-TPU), so the curves
compare the two portability stories shard-for-shard:

  * **strong scaling** — fixed global problem, growing shard count:
      efficiency(S) = t_1 / (S * t_S)
    against the single-device ``xla`` oracle at the same global size;
  * **weak scaling** — fixed *per-shard* problem, global size grows with S:
      efficiency(S) = t_1(base) / t_S(S * base).

stencil7 is measured once per *decomposition variant* — 1-D z slabs and 2-D
``(sz, sy)`` pencils, the ``xla_shard`` lanes each with and without
halo/compute overlap — because the decomposition shape governs the
surface-to-volume halo traffic that bounds a memory-bound stencil's
efficiency.  Every timed point consults the PR-2 tuning cache first (Eq.-4
times *best* configurations, not defaults): cached parameters are merged
under the point's forced shard settings and re-timed fresh — cached seconds
never enter a ratio — and the artifact records the tuning provenance per
point.

Hartree-Fock has no linear weak-scaling axis (work is O(N^4) in the atom
count) and records a skip reason instead of a fake curve.

Run on CPU via simulated devices, exactly how ``launch/dryrun.py`` fakes its
512-chip topology: when the current process already pinned jax to a single
device, the module re-execs itself in a subprocess with
``--xla_force_host_platform_device_count`` appended to XLA_FLAGS
(``repro.launch.hostsim`` — a user-set value is respected, never clobbered).
The child's CSV rows are replayed into ``benchmarks.common.ROWS`` in the
parent, so orchestrated runs (``benchmarks.run``) see them like any other
module's.  CPU caveat: "devices" are threads of one host — and the
``shard_pallas`` kernels run in interpret mode there — so efficiencies here
validate the *machinery* and the shapes of the curves, not hardware scaling.

    PYTHONPATH=src python -m benchmarks.run [--smoke] --only scaling
    PYTHONPATH=src python -m benchmarks.scaling [--smoke] [--devices 8]

Artifact schema (``repro.scaling/v3``; v2 had a single implicit backend per
kernel — v3 hoists a ``backends`` list so the ``xla_shard`` and
``shard_pallas`` curves sit side by side; v1 additionally lacked
decomposition curves and tuning provenance)::

    {"schema": "repro.scaling/v3", "platform": str, "smoke": bool,
     "num_devices": int,
     "kernels": [
       {"kernel": str, "baseline_backend": "xla",
        "backends": [
          {"backend": "xla_shard" | "shard_pallas", "skipped": str | null,
           "curves": [
             {"decomp": "slab" | "pencil", "overlap": bool | null,
              "strong": {"shape": str, "baseline_seconds": float,
                         "baseline_tuning": TUNING,
                         "points": [{"num_shards": int,
                                     "shard_grid": [sz, sy] | null,
                                     "seconds": float, "speedup": float,
                                     "efficiency": float,
                                     "tuning": TUNING}]},
              "weak": {"base_shape": str, "baseline_seconds": float,
                       "baseline_tuning": TUNING,
                       "points": [{"num_shards": int,
                                   "shard_grid": [sz, sy] | null,
                                   "shape": str, "seconds": float,
                                   "efficiency": float, "tuning": TUNING}]}
                      | {"skipped": str}}]}]}]}

    TUNING = {"cached": bool, "params": {...}, "search": str | null}

``overlap`` is null for ``shard_pallas`` curves: the composite has a single
structure (the halo-padded local block feeds one Pallas call — that is what
keeps it bitwise equal to the single-device kernel), so there is no
halo/compute-overlap axis to sweep.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Tuple

from benchmarks.common import emit, header

ARTIFACT = "BENCH_scaling.json"
SCHEMA = "repro.scaling/v3"
DEFAULT_DEVICES = 8
CSV_HEADER = "name,us_per_call,derived"


# --------------------------------------------------------------------------
# problem-size catalogue (global extents divisible by every swept shard count)
# --------------------------------------------------------------------------
def _stencil_args(nz, smoke, ny_mult=1):
    import jax.numpy as jnp
    import numpy as np
    # nx is the 128-lane width the Pallas kernel requires, so the
    # shard_pallas curves share the exact shapes the xla_shard curves time
    ny, nx = (16, 128) if smoke else (64, 128)
    u = np.random.default_rng(0).standard_normal((nz, ny * ny_mult, nx))
    return (jnp.asarray(u, jnp.float32),)


def _stream_args(n, smoke, nargs):
    import jax.numpy as jnp
    import numpy as np
    r = np.random.default_rng(0)
    return tuple(jnp.asarray(r.standard_normal(n), jnp.float32)
                 for _ in range(nargs))


def _minibude_args(nposes, smoke):
    from repro.kernels.minibude import ops as mb_ops
    natpro, natlig = (16, 4) if smoke else (64, 8)
    return mb_ops.make_deck(natpro=natpro, natlig=natlig, nposes=nposes,
                            seed=0)


def _hf_args(natoms, smoke):
    from repro.kernels.hartree_fock import ref as hf_ref
    return (hf_ref.helium_lattice(natoms), hf_ref.initial_density(natoms))


#: kernel -> (strong extent, weak per-shard extent, args factory); extents
#: are the decomposed axis (stencil z planes, stream elements, poses, atoms),
#: sized so every swept shard count divides them AND the per-shard blocks
#: admit the shard_pallas tile grids (>= 128*128 stream elements and >= 64
#: poses per shard).  stencil7 additionally declares its decomposition
#: variants per backend and a 2-D weak factory (weak pencils grow z by sz
#: and y by sy, keeping the per-shard block fixed); the xla_shard lanes
#: carry the halo/compute-overlap axis, the shard_pallas composite has a
#: single structure (overlap = None in the artifact).
def _catalogue(smoke: bool) -> Dict[str, Dict[str, Any]]:
    return {
        "stencil7": {
            "strong": 16 if smoke else 64,
            "weak": 2 if smoke else 8,
            "make": lambda n: _stencil_args(n, smoke),
            "make_grid": lambda n, sy: _stencil_args(n, smoke, ny_mult=sy),
            "curves": {
                "xla_shard": [("slab", False), ("slab", True),
                              ("pencil", False), ("pencil", True)],
                "shard_pallas": [("slab", None), ("pencil", None)],
            },
        },
        "babelstream.triad": {
            "strong": 1 << 16 if smoke else 1 << 20,
            "weak": 1 << 14 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "babelstream.dot": {
            "strong": 1 << 16 if smoke else 1 << 20,
            "weak": 1 << 14 if smoke else 1 << 17,
            "make": lambda n: _stream_args(n, smoke, 2),
        },
        "minibude.fasten": {
            "strong": 256 if smoke else 1024,
            "weak": 64 if smoke else 256,
            "make": lambda n: _minibude_args(n, smoke),
        },
        "hartree_fock.twoel": {
            "strong": 8 if smoke else 16,
            "weak": None,  # O(N^4) work: no linear weak-scaling axis
            "weak_skip": "work is O(N^4) in atoms; no linear weak axis",
            "make": lambda n: _hf_args(n, smoke),
        },
    }


def _shape_sig(args) -> str:
    from repro.core.tuning import shape_signature
    return shape_signature(*args)


# --------------------------------------------------------------------------
# timing: every point consults the tuning cache, re-times fresh, and records
# provenance (the Eq.-4 "best configuration" rule from benchmarks/portability)
# --------------------------------------------------------------------------
def _timed_point(kernel, args, backend, cache, iters, warmup,
                 forced: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
    """Median seconds at the cache's best params (merged *under* the forced
    shard settings — the sweep axis always wins), plus the provenance
    record.  Cached seconds are historical (another session, another load):
    only the *parameters* are reused; the timing is always fresh.

    The cache key does not encode shard settings, so an entry tuned under a
    different grid can carry tile params (``by`` / ``block_rows``) that are
    invalid for *this* point's forced grid (e.g. ``by=64`` tuned on a slab
    does not divide a pencil's 32-wide local block).  The merged point is
    therefore re-validated against the backend's declared constraint and
    falls back to the declared defaults when it fails — a dropped cache
    hit, never a crashed benchmark.
    """
    from repro.core import tuning

    hit = cache.get(tuning.make_key(kernel, *args, backend=backend))
    cached = tuning.params_from_cache(hit["params"]) if hit else {}
    merged = {**cached, **forced}
    space = kernel.tunable_space(backend)
    if cached and space is not None and space.constraint is not None:
        point = {k: merged[k] for k in space.params if k in merged}
        if set(point) == set(space.params) and not space.constraint(
                point, *args):
            hit, merged = None, dict(forced)  # incompatible hit: dropped
    provenance = {"cached": hit is not None,
                  "params": dict(merged),
                  "search": hit.get("search", "exhaustive") if hit else None}
    secs = kernel.time_backend(*args, backend=backend, iters=iters,
                               warmup=warmup, **merged)
    return secs, provenance


def _curve_label(decomp: str, overlap) -> str:
    return decomp + ("+ov" if overlap else "")


def _measure(smoke: bool, json_path: str) -> Dict[str, Any]:
    import jax

    import repro.kernels  # noqa: F401  (registers the sharded backends)
    from repro.core.portable import registry
    from repro.core.tuning import TuningCache
    from repro.distributed.domain import (SHARD_BACKEND,
                                          balanced_pencil_grid)
    from repro.distributed.shard_pallas import PALLAS_SHARD_BACKEND

    backends = (SHARD_BACKEND, PALLAS_SHARD_BACKEND)
    dc = jax.device_count()
    cache = TuningCache()
    shard_counts = [s for s in ((2, 4) if smoke else (2, 4, 8)) if s <= dc]
    iters, warmup = (1, 1) if smoke else (3, 1)
    records: List[Dict[str, Any]] = []

    for name, spec in _catalogue(smoke).items():
        kernel = registry.get(name)
        rec: Dict[str, Any] = {"kernel": name,
                               "baseline_backend": kernel.oracle,
                               "backends": []}
        records.append(rec)
        if not any(kernel.backends.get(bk) is not None
                   and kernel.backends[bk].is_available()
                   for bk in backends):
            for bk in backends:
                rec["backends"].append(
                    {"backend": bk, "curves": [],
                     "skipped": f"{bk} unavailable ({dc} device(s))"})
            continue

        # baselines are per kernel (shared by every backend's curves)
        strong_args = spec["make"](spec["strong"])
        t1, t1_prov = _timed_point(kernel, strong_args, kernel.oracle, cache,
                                   iters, warmup, {})
        weak_base = None
        if spec["weak"] is not None:
            weak_base = spec["make"](spec["weak"])
            t1w, t1w_prov = _timed_point(kernel, weak_base, kernel.oracle,
                                         cache, iters, warmup, {})

        for backend in backends:
            b = kernel.backends.get(backend)
            brec: Dict[str, Any] = {"backend": backend, "skipped": None,
                                    "curves": []}
            rec["backends"].append(brec)
            if b is None or not b.is_available():
                brec["skipped"] = f"{backend} unavailable ({dc} device(s))"
                continue
            curves = (spec["curves"][backend] if "curves" in spec
                      else [("slab", False if backend == SHARD_BACKEND
                             else None)])

            for decomp, overlap in curves:
                label = _curve_label(decomp, overlap)

                def _point_plan(s, args):
                    """(shard_grid, forced kwargs) for S total shards, or
                    None when this decomposition cannot use S shards here.
                    ``args`` is the *fixed* global problem (strong lane);
                    weak lanes pass ``None`` and get the shape-agnostic
                    grid — their global extents are built *from* the grid,
                    so they divide by construction."""
                    if "curves" not in spec:   # 1-D kernels: num_shards
                        return None, {"num_shards": s}
                    if decomp == "slab":
                        grid = (s, 1)
                        if args is not None and args[0].shape[0] % s:
                            grid = None
                    elif args is not None:
                        grid = balanced_pencil_grid(s, args[0].shape[0],
                                                    args[0].shape[1])
                    else:
                        grid = balanced_pencil_grid(s)
                    if grid is None:
                        return None, None
                    forced = {"decomp": decomp, "shard_grid": grid}
                    if overlap is not None:   # shard_pallas has no axis
                        forced["overlap"] = overlap
                    return grid, forced

                # strong: fixed global problem, shards grow
                points = []
                for s in shard_counts:
                    grid, forced = _point_plan(s, strong_args)
                    if forced is None:
                        continue
                    ts, prov = _timed_point(kernel, strong_args, backend,
                                            cache, iters, warmup, forced)
                    eff = t1 / (s * ts)
                    points.append({"num_shards": s,
                                   "shard_grid": list(grid) if grid else
                                   None,
                                   "seconds": ts, "speedup": t1 / ts,
                                   "efficiency": eff, "tuning": prov})
                    emit(f"scaling.{name}.{backend}.{label}.strong.s{s}",
                         ts, f"eff={eff:.3f} speedup={t1 / ts:.2f}x")
                curve: Dict[str, Any] = {
                    "decomp": decomp, "overlap": overlap,
                    "strong": {"shape": _shape_sig(strong_args),
                               "baseline_seconds": t1,
                               "baseline_tuning": t1_prov,
                               "points": points}}

                # weak: fixed per-shard problem, global grows with shards
                if spec["weak"] is None:
                    curve["weak"] = {"skipped": spec["weak_skip"]}
                else:
                    points = []
                    for s in shard_counts:
                        grid, forced = _point_plan(s, None)
                        if forced is None:
                            continue
                        if grid is not None and grid[1] > 1:
                            args_s = spec["make_grid"](
                                spec["weak"] * grid[0], grid[1])
                        else:
                            args_s = spec["make"](spec["weak"] * s)
                        ts, prov = _timed_point(kernel, args_s, backend,
                                                cache, iters, warmup,
                                                forced)
                        eff = t1w / ts
                        points.append({"num_shards": s,
                                       "shard_grid": list(grid) if grid
                                       else None,
                                       "shape": _shape_sig(args_s),
                                       "seconds": ts, "efficiency": eff,
                                       "tuning": prov})
                        emit(f"scaling.{name}.{backend}.{label}.weak.s{s}",
                             ts, f"eff={eff:.3f}")
                    curve["weak"] = {"base_shape": _shape_sig(weak_base),
                                     "baseline_seconds": t1w,
                                     "baseline_tuning": t1w_prov,
                                     "points": points}
                brec["curves"].append(curve)

    artifact = {
        "schema": SCHEMA,
        "platform": jax.devices()[0].platform,
        "smoke": smoke,
        "num_devices": dc,
        "kernels": records,
    }
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    return artifact


# --------------------------------------------------------------------------
# entry points: re-exec under simulated devices when pinned to one
# --------------------------------------------------------------------------
def _replay_child_line(line: str) -> None:
    """Feed one line of child stdout back through ``emit`` so the parent's
    ``benchmarks.common.ROWS`` sees the child's CSV rows (the scaffold
    aggregates ROWS, not raw stdout).  Header lines are dropped (the parent
    context already printed one); anything non-CSV passes through."""
    if not line or line == CSV_HEADER:
        return
    parts = line.split(",", 2)
    if len(parts) == 3:
        try:
            us = float(parts[1])
        except ValueError:
            pass
        else:
            emit(parts[0], us / 1e6, parts[2])
            return
    print(line, flush=True)


def run(smoke: bool = False, json_path: str = ARTIFACT,
        devices: int = DEFAULT_DEVICES) -> Dict[str, Any]:
    """Measure in-process when >= 2 devices are visible; otherwise re-exec
    this module in a subprocess with the host-device-count flag appended
    (jax reads XLA_FLAGS once, at backend init — too late for *this*
    process).  Returns the artifact dict (also written to ``json_path``)."""
    import jax
    if jax.device_count() >= 2:
        return _measure(smoke=smoke, json_path=json_path)
    if os.environ.get("REPRO_SCALING_CHILD"):
        # we *are* the re-exec and still see one device: the user's own
        # XLA_FLAGS pins the topology — fail loudly instead of forking again
        raise RuntimeError(
            "scaling needs >= 2 devices but XLA_FLAGS pins a 1-device "
            "topology; unset --xla_force_host_platform_device_count or "
            "raise it")

    from repro.launch.hostsim import merged_xla_flags
    env = dict(os.environ)
    env["XLA_FLAGS"] = merged_xla_flags(devices, env)
    env["REPRO_SCALING_CHILD"] = "1"
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    # the child runs from the repo root (so `-m benchmarks.scaling`
    # resolves); absolutize the artifact path against OUR cwd first or the
    # parent would read a missing/stale file after the child succeeded
    json_path = os.path.abspath(json_path)
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--json", json_path,
           "--devices", str(devices)]
    if smoke:
        cmd.append("--smoke")
    # child CSV rows are replayed line-by-line into OUR emit/ROWS (not just
    # streamed to stdout); stderr passes through untouched
    proc = subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.stdout is not None
    for line in proc.stdout:
        _replay_child_line(line.rstrip("\n"))
    if proc.wait():
        raise RuntimeError(
            f"scaling subprocess failed with exit code {proc.returncode}")
    with open(json_path) as f:
        return json.load(f)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=ARTIFACT)
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES)
    args = ap.parse_args(argv)
    # standalone runs get the scaffold's CSV header line (benchmarks.run
    # prints its own before dispatching, so run() itself must not)
    header()
    run(smoke=args.smoke, json_path=args.json, devices=args.devices)


if __name__ == "__main__":
    main()
