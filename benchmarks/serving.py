"""Serving-engine benchmark: throughput + SLO latency, per attention backend.

Drives the fixed-shape continuous-batching engine with a Poisson-ish
synthetic arrival trace (repro/serving/trace.py) on a smoke-size model,
once per attention backend — the plain-XLA oracle first (the before), then
the Pallas registry path (compiled on TPU, interpret elsewhere — the
after).  Each backend emits one row:

    serving[<backend>],<us_per_decode_step>,<tok/s + TTFT/latency/ITL
    p50/p95/p99 + attn dispatch provenance>

The dispatch provenance comes from ``models/attention.dispatch_log()``,
captured at trace time while the engine compiles its two programs: which
registry backend each program actually dispatched to and whether its block
sizes came from the tuning cache (``exhaustive``/``coordinate``) or the
declared defaults (``miss-default``).

Since PR 8 the whole run records through ``repro.core.telemetry``: every
request's lifecycle (enqueue -> slot-assign -> prefill span -> first-token
-> per-step decode spans -> finish), queue-depth/slot-occupancy gauges,
attention dispatch events, and — via the ``jax.monitoring`` bridge — an XLA
compile-event counter per row, the runtime twin of the static auditor's
``recompile`` pass.  The trace is exported next to the artifact as a JSONL
event log (``BENCH_serving_trace.jsonl`` — feed it to ``python -m
repro.core.telemetry summarize``) and a Chrome/Perfetto-loadable
``BENCH_serving_trace.json``.

A small warmup trace triggers the two compiles (one prefill shape, one
decode shape) before timing; the measured run must not retrace — the row is
annotated `RETRACED` if it does, since that invalidates the timing (the
``jax_compile_events`` column counts the expected warmup compiles; extra
compiles during the timed run are the recompile-storm signal).  A
machine-readable artifact is written to ``BENCH_serving.json`` (schema
``repro.serving/v3``; v2 lacked the p99/inter-token-latency SLO columns,
the compile counter, and the telemetry block; v1 was the single pre-PR-6
CSV row).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import telemetry as tel
from repro.core.portable import on_tpu
from repro.core.telemetry.jaxmon import COMPILE_COUNTER
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import ServingEngine, latency_summary, synthetic_trace

ARCH = "granite-3-8b"
NUM_SLOTS = 4
CACHE_LEN = 64
PREFILL_LEN = 16
RATE_RPS = 50.0
MAX_NEW = 16
ARTIFACT = "BENCH_serving.json"
SCHEMA = "repro.serving/v3"


def _prov(log: Dict[str, Dict[str, Any]], kind: str) -> str:
    d = log.get(kind, {})
    bk = d.get("backend", "?")
    if d.get("fallback"):
        return f"{kind}={bk}(fallback)"
    tuning = d.get("tuning", "?")
    return f"{kind}={bk}" + (f"/{tuning}" if bk != "xla" else "")


def _compile_count() -> float:
    return tel.snapshot().get("counters", {}).get(COMPILE_COUNTER, 0.0)


def _ms(lat: Dict[str, float], key: str) -> Optional[float]:
    v = lat.get(key)
    return v * 1e3 if v is not None else None


def _one_backend(params, cfg, backend: str, n_requests: int
                 ) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    A.reset_dispatch_log()
    compiles_before = _compile_count()
    eng = ServingEngine(params, cfg, num_slots=NUM_SLOTS,
                        cache_len=CACHE_LEN, prefill_len=PREFILL_LEN,
                        attn_backend=backend)

    warm = synthetic_trace(NUM_SLOTS, vocab_size=cfg.vocab_size, rate=1e6,
                           max_prompt=PREFILL_LEN, max_new_tokens=4,
                           seed=7, uid_base=10_000)
    eng.run(warm)
    # both programs are compiled now; the dispatch log holds what each
    # traced — snapshot before the timed run (which must not retrace)
    log = A.dispatch_log()
    traces_before = (eng.stats["prefill_traces"], eng.stats["decode_traces"])
    steps_before = eng.stats["decode_steps"]
    toks_before = eng.stats["tokens_generated"]
    compiles_warm = _compile_count()

    trace = synthetic_trace(n_requests, vocab_size=cfg.vocab_size,
                            rate=RATE_RPS, max_prompt=PREFILL_LEN,
                            max_new_tokens=MAX_NEW, seed=1)
    t0 = time.perf_counter()
    done = eng.run(trace)
    wall = time.perf_counter() - t0
    compiles_after = _compile_count()

    steps = eng.stats["decode_steps"] - steps_before
    toks = eng.stats["tokens_generated"] - toks_before
    lat = latency_summary(done)
    retraced = (eng.stats["prefill_traces"],
                eng.stats["decode_traces"]) != traces_before

    # this row's telemetry: drain the ring so per-row events never evict
    # each other across backends, summarize the spans, count compiles
    rec = tel.recorder()
    row_events = rec.drain() if rec is not None else []
    row_tel = {
        "spans": tel.summarize_events(row_events),
        "jax_compile_events": compiles_after - compiles_before,
        "jax_compile_events_timed": compiles_after - compiles_warm,
    }

    def fmt(key):
        v = _ms(lat, key)
        return f"{v:.1f}" if v is not None else "n/a"

    derived = (f"{toks / wall:.1f} tok/s "
               f"ttft p50 {fmt('p50_ttft_s')} "
               f"p95 {fmt('p95_ttft_s')} p99 {fmt('p99_ttft_s')} ms "
               f"itl p50 {fmt('p50_itl_s')} "
               f"p95 {fmt('p95_itl_s')} p99 {fmt('p99_itl_s')} ms "
               f"lat p50 {fmt('p50_latency_s')} "
               f"p95 {fmt('p95_latency_s')} p99 {fmt('p99_latency_s')} ms "
               f"({n_requests} reqs @ {RATE_RPS:.0f} rps "
               f"slots={NUM_SLOTS}) "
               f"compiles={row_tel['jax_compile_events']:.0f} "
               f"{_prov(log, 'prefill')} {_prov(log, 'decode')}"
               + (" RETRACED" if retraced else ""))
    emit(f"serving[{backend}]", wall / max(steps, 1), derived)
    row = {
        "backend": backend,
        "resolved": dict(eng.attn_backends),
        "tok_s": toks / wall,
        "us_per_decode_step": wall / max(steps, 1) * 1e6,
        "ttft_p50_ms": _ms(lat, "p50_ttft_s"),
        "ttft_p95_ms": _ms(lat, "p95_ttft_s"),
        "ttft_p99_ms": _ms(lat, "p99_ttft_s"),
        "itl_p50_ms": _ms(lat, "p50_itl_s"),
        "itl_p95_ms": _ms(lat, "p95_itl_s"),
        "itl_p99_ms": _ms(lat, "p99_itl_s"),
        "latency_p50_ms": _ms(lat, "p50_latency_s"),
        "latency_p95_ms": _ms(lat, "p95_latency_s"),
        "latency_p99_ms": _ms(lat, "p99_latency_s"),
        "requests": n_requests,
        "retraced": retraced,
        "jax_compile_events": row_tel["jax_compile_events"],
        "telemetry": row_tel,
        "dispatch": log,
    }
    return row, row_events


def run(smoke: bool = False, json_path: str = ARTIFACT) -> Dict[str, Any]:
    cfg = get_config(ARCH, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # record the whole run; respect an env-configured recorder
    # (REPRO_TELEMETRY=jsonl:... keeps its exit flush), else enable an
    # in-memory one for the duration of the benchmark
    owned = not tel.enabled()
    if owned:
        tel.configure("on")

    try:
        # before: the status-quo plain-XLA path; after: the registry Pallas
        # kernels (compiled on TPU, interpret mode on a CPU host — relative
        # numbers only there, see benchmarks/common.py)
        backends = ["xla", "pallas" if on_tpu() else "pallas_interpret"]
        n_requests = 8 if smoke else 24

        rows = []
        events: List[Dict[str, Any]] = []
        for bk in backends:
            row, row_events = _one_backend(params, cfg, bk, n_requests)
            rows.append(row)
            events.extend(row_events)

        rec = tel.recorder()
        events.extend(rec.drain() if rec is not None else [])
        stem = json_path[:-5] if json_path.endswith(".json") else json_path
        trace_jsonl = f"{stem}_trace.jsonl"
        trace_chrome = f"{stem}_trace.json"
        meta = {"benchmark": "serving", "arch": ARCH, "schema_of": SCHEMA}
        if rec is not None:
            snap = rec.snapshot()     # counters/gauges survive the drains
            snap["span_summary"] = tel.summarize_events(events)
            tel.write_jsonl(trace_jsonl, events, meta=meta,
                            footer_data=snap)
            tel.write_chrome_trace(trace_chrome, events, meta=meta)
        else:  # pragma: no cover - recorder always on here
            snap = {}

        artifact = {
            "schema": SCHEMA,
            "arch": ARCH,
            "smoke": bool(smoke),
            "platform": jax.devices()[0].platform,
            "num_slots": NUM_SLOTS,
            "cache_len": CACHE_LEN,
            "prefill_len": PREFILL_LEN,
            "jax_compile_events": snap.get("counters", {}).get(
                COMPILE_COUNTER, 0.0),
            "telemetry": snap,
            "trace_jsonl": trace_jsonl,
            "trace_chrome": trace_chrome,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        return artifact
    finally:
        if owned:
            tel.configure("off")


if __name__ == "__main__":
    run()
